"""comet-verify: clean-tree runs, seeded mutants, and the VMEM property.

Structure:

* CLEAN — every pass over the real tree / real lowerings must produce
  ZERO diagnostics (the zero-suppression baseline the PR establishes).
* MUTANTS — a seeded harness corrupts orders, kernel models and source
  snippets; the analyzer must kill (diagnose) every mutant. A mutant
  that survives is a hole in the checker, not a flaky test.
* PROPERTIES — candidate_plans never emits a VMEM-overflowing tiling;
  legalize_plan is a fixed point; Plan.validate/PlanCache round-trips.
"""
import dataclasses
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.core.adaptive as A
from repro.analysis.verify import conventions as C
from repro.analysis.verify import kernel_check as K
from repro.analysis.verify import schedule_check as S
from repro.analysis.verify.diagnostics import (Diagnostic, Report,
                                               parse_ignores)
from repro.core.schedule import lower_model_graph, overlap_order

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HW = A.TPU_V5E
MIX = A.MoEShape(M=8192, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
PLAN = A.legalize_plan(A.Plan("comet", 2, 4, "pallas_fused",
                              fused_combine=True), MIX.N, MIX.ep)


def rules_of(diags):
    return {d.rule for d in diags}


# ---------------------------------------------------------------------------
# Diagnostics core
# ---------------------------------------------------------------------------


def test_report_rendering_and_json():
    r = Report([Diagnostic("kernel", "vmem-overflow", "error",
                           "kernel:x", "too big", "shrink"),
                Diagnostic("conventions", "mesh-entry", "warning",
                           "a.py:3", "meh")])
    assert not r.ok and len(r.errors) == 1
    text = r.text()
    assert "kernel/vmem-overflow" in text and "[fix: shrink]" in text
    j = json.loads(r.to_json())
    assert j["errors"] == 1 and not j["ok"]
    assert j["diagnostics"][0]["rule"] == "vmem-overflow"
    assert Report().ok and "clean" in Report().text()


def test_bad_severity_rejected():
    with pytest.raises(ValueError):
        Diagnostic("kernel", "r", "fatal", "x", "m")


def test_ignore_requires_justification():
    src = ("x = 1  # verify: ignore[mesh-entry] -- annotation-only import\n"
           "y = 2  # verify: ignore[mutable-global]\n")
    ignores, bad = parse_ignores(src)
    assert 1 in ignores and ignores[1][0] == "mesh-entry"
    assert bad == [(2, "mutable-global")]


# ---------------------------------------------------------------------------
# CLEAN runs
# ---------------------------------------------------------------------------


def test_clean_tree_conventions():
    diags = C.lint_tree(os.path.join(REPO, "src", "repro"))
    assert diags == [], "\n".join(str(d) for d in diags)


def test_clean_builtin_kernels():
    diags = K.check_builtin_kernels()
    assert diags == [], "\n".join(str(d) for d in diags)


def test_clean_model_archs_schedule():
    diags = S.check_model_archs()
    assert diags == [], "\n".join(str(d) for d in diags)


def test_clean_legalize_fixed_point():
    assert K.check_legalize_fixed_point() == []


def test_verify_cli_clean():
    import subprocess
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "verify.py"),
         "--all", "--json"], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    j = json.loads(out.stdout)
    assert j["ok"] and j["diagnostics"] == []


# ---------------------------------------------------------------------------
# Seeded mutants — executed-segment order (reads/writes hazards)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FakeSeg:
    name: str
    reads: tuple
    writes: tuple


def _exec_program():
    # attn -> router -> gemm -> comb -> next attn, two blocks
    return [
        FakeSeg("L0.attn", ("x0",), ("h0",)),
        FakeSeg("L0.router", ("h0",), ("d0",)),
        FakeSeg("L0.gemm", ("d0",), ("e0",)),
        FakeSeg("L0.comb", ("e0",), ("x1",)),
        FakeSeg("L1.attn", ("x1",), ("h1",)),
        FakeSeg("L1.router", ("h1",), ("d1",)),
        FakeSeg("L1.gemm", ("d1",), ("e1",)),
        FakeSeg("L1.comb", ("e1",), ("x2",)),
    ]


def _swap(segs, a, b):
    out = list(segs)
    ia = [s.name for s in out].index(a)
    ib = [s.name for s in out].index(b)
    out[ia], out[ib] = out[ib], out[ia]
    return out


def test_exec_clean_orders_pass():
    p = _exec_program()
    assert S.check_exec_order(p, p) == []
    # independent values permute freely: a second block reading its own
    # inputs may interleave anywhere
    q = [FakeSeg("a", ("u",), ("v",)), FakeSeg("b", ("x",), ("y",))]
    assert S.check_exec_order(q, [q[1], q[0]]) == []


def test_mutant_exec_raw_swap():
    p = _exec_program()
    bad = _swap(p, "L0.router", "L0.attn")       # router before its input
    assert "raw-hazard" in rules_of(S.check_exec_order(p, bad))


def test_mutant_exec_cross_block_raw():
    p = _exec_program()
    bad = _swap(p, "L1.attn", "L0.comb")         # attn before x1 exists
    assert "raw-hazard" in rules_of(S.check_exec_order(p, bad))


def test_mutant_exec_war_swap():
    p = _exec_program() + [FakeSeg("L0.rewrite", (), ("h0",))]
    bad = _swap(p, "L0.rewrite", "L0.router")    # clobbers h0 pre-read
    assert "war-hazard" in rules_of(S.check_exec_order(p, bad))


def test_mutant_exec_waw_swap():
    p = [FakeSeg("w1", (), ("v",)), FakeSeg("w2", (), ("v",)),
         FakeSeg("r", ("v",), ())]
    bad = [p[1], p[0], p[2]]                     # stale writer wins
    assert "waw-hazard" in rules_of(S.check_exec_order(p, bad))


def test_mutant_exec_dropped_segment():
    p = _exec_program()
    assert "not-a-permutation" in rules_of(S.check_exec_order(p, p[:-1]))


def test_mutant_exec_duplicated_segment():
    p = _exec_program()
    assert "not-a-permutation" in rules_of(
        S.check_exec_order(p, p + [p[0]]))


def test_mutant_exec_duplicate_names_in_program():
    p = _exec_program() + [FakeSeg("L0.attn", (), ())]
    assert "duplicate-name" in rules_of(S.check_exec_order(p, p))


def test_assert_exec_order_safe_raises():
    p = _exec_program()
    with pytest.raises(RuntimeError, match="hazard"):
        S.assert_exec_order_safe(p, _swap(p, "L0.gemm", "L0.comb"))


# ---------------------------------------------------------------------------
# Seeded mutants — cost-IR graph orders (structural ring rules)
# ---------------------------------------------------------------------------


def _graph(training=False, n_slices=1):
    return lower_model_graph(HW, MIX, PLAN, d_model=MIX.N, n_blocks=2,
                             n_slices=n_slices, training=training)


def _expect():
    from repro.core.schedule import comet_ring_counts
    cnt = comet_ring_counts(MIX.ep, PLAN.ring_group, PLAN.n_col_blocks)
    return {"n_steps": cnt["n_steps"], "n_col": PLAN.n_col_blocks}


def _sid(g, name):
    return next(s.sid for s in g.segments if s.name == name)


def _swap_order(order, sa, sb):
    order = list(order)
    ia, ib = order.index(sa), order.index(sb)
    order[ia], order[ib] = order[ib], order[ia]
    return order


@pytest.mark.parametrize("training", [False, True])
@pytest.mark.parametrize("ns", [1, 2])
def test_graph_clean_orders_pass(training, ns):
    g = _graph(training, ns)
    assert S.check_graph_order(g, overlap_order(g), expect=_expect()) == []


def test_mutant_graph_gemm_before_disp():
    g = _graph()
    bad = _swap_order(overlap_order(g), _sid(g, "L0.s0.disp1"),
                      _sid(g, "L0.s0.gemm1"))
    assert "recv-before-compute" in rules_of(
        S.check_graph_order(g, bad, expect=_expect()))


def test_mutant_graph_comb_before_gemm():
    g = _graph()
    bad = _swap_order(overlap_order(g), _sid(g, "L0.s0.gemm0"),
                      _sid(g, "L0.s0.comb0.0"))
    rules = rules_of(S.check_graph_order(g, bad, expect=_expect()))
    assert "send-after-produce" in rules


def test_mutant_graph_disp_fifo_overtake():
    g = _graph()
    order = overlap_order(g)
    d1, d2 = _sid(g, "L0.s0.disp1"), _sid(g, "L0.s0.disp2")
    bad = _swap_order(order, d1, d2)       # step 2 recv overtakes step 1
    rules = rules_of(S.check_graph_order(g, bad, expect=_expect()))
    assert "link-fifo" in rules


def test_mutant_graph_router_after_gemm():
    g = _graph()
    bad = _swap_order(overlap_order(g), _sid(g, "L0.s0.router"),
                      _sid(g, "L0.s0.gemm0"))
    assert "raw-hazard" in rules_of(
        S.check_graph_order(g, bad, expect=_expect()))


def test_mutant_graph_attn_before_prev_combine():
    g = _graph()
    order = overlap_order(g)
    a1 = _sid(g, "L1.s0.attn")
    last_comb = max((s.sid for s in g.segments
                     if s.name.startswith("L0.s0.comb")),
                    key=lambda sid: order.index(sid))
    bad = _swap_order(order, last_comb, a1)
    assert "raw-hazard" in rules_of(
        S.check_graph_order(g, bad, expect=_expect()))


def test_mutant_graph_dropped_hop():
    g = _graph()
    victim = _sid(g, "L0.s0.disp1")
    keep = [s for s in g.segments if s.sid != victim]
    # renumber: order must be a permutation of the REMAINING sids —
    # check_graph_order indexes segments by position, so rebuild sids
    remap = {s.sid: i for i, s in enumerate(keep)}
    g.segments = [dataclasses.replace(
        s, sid=remap[s.sid],
        deps=tuple(remap[d] for d in s.deps if d in remap)) for s in keep]
    rules = rules_of(S.check_graph_order(g, list(range(len(keep))),
                                         expect=_expect()))
    assert "missing-segment" in rules


def test_mutant_graph_wrong_resource():
    g = _graph()
    order = overlap_order(g)
    g.segments = [dataclasses.replace(s, resource="compute")
                  if s.name == "L0.s0.disp1" else s for s in g.segments]
    assert "wrong-resource" in rules_of(
        S.check_graph_order(g, order, expect=_expect()))


def test_mutant_graph_flush_before_bgemm():
    g = _graph(training=True)
    bad = _swap_order(overlap_order(g), _sid(g, "L0.s0.bgemm1"),
                      _sid(g, "L0.s0.flush1"))
    assert "flush-before-producer" in rules_of(
        S.check_graph_order(g, bad, expect=_expect()))


def test_mutant_graph_flush_grows_dependent():
    g = _graph(training=True)
    order = overlap_order(g)
    fl = _sid(g, "L0.s0.flush1")
    dependent = next(s for s in g.segments
                     if order.index(s.sid) > order.index(fl)
                     and s.sid > fl)
    g.segments = [dataclasses.replace(s, deps=tuple(s.deps) + (fl,))
                  if s.sid == dependent.sid else s for s in g.segments]
    assert "flush-has-dependent" in rules_of(
        S.check_graph_order(g, order, expect=_expect()))


def test_mutant_graph_not_a_permutation():
    g = _graph()
    order = overlap_order(g)
    assert "not-a-permutation" in rules_of(
        S.check_graph_order(g, order[:-1], expect=_expect()))


# ---------------------------------------------------------------------------
# Seeded mutants — kernel models
# ---------------------------------------------------------------------------


def test_mutant_kernel_oversized_tile():
    m = K.fused_mlp_model(bn=0, d=8192, N=8192)     # full-width at d=8k
    assert "vmem-overflow" in rules_of(K.check_vmem(m, HW.vmem_bytes))


def test_mutant_kernel_index_map_off_by_one():
    m = K.grouped_gemm_model()
    blocks = tuple(
        dataclasses.replace(b, index_map=lambda e, mm, n, k: (e, mm + 1, k))
        if b.name == "lhs" else b for b in m.blocks)
    bad = dataclasses.replace(m, blocks=blocks)
    assert "index-out-of-bounds" in rules_of(K.check_index_maps(bad))


def test_mutant_kernel_negative_offset():
    m = K.rmsnorm_model()
    blocks = tuple(dataclasses.replace(b, index_map=lambda i: (i - 1, 0))
                   if b.name == "x" else b for b in m.blocks)
    assert "index-out-of-bounds" in rules_of(
        K.check_index_maps(dataclasses.replace(m, blocks=blocks)))


def test_mutant_kernel_wrong_axis_order():
    # n_major traversal wired with expert_major maps: grid axis 0 (nt)
    # lands in the expert slot and runs off the expert dimension
    m = K.grouped_gemm_model(order="n_major")
    blocks = tuple(
        dataclasses.replace(b, index_map=lambda n, e, mm, k: (n, mm, k))
        if b.name == "lhs" else b for b in m.blocks)
    bad = dataclasses.replace(m, blocks=blocks)
    assert "index-out-of-bounds" in rules_of(K.check_index_maps(bad))


def test_mutant_kernel_grid_too_small():
    m = K.grouped_gemm_model()
    bad = dataclasses.replace(m, grid=(m.grid[0], m.grid[1] - 1,
                                       m.grid[2], m.grid[3]))
    assert "uncovered-output-tile" in rules_of(K.check_index_maps(bad))


def test_mutant_kernel_index_map_arity():
    m = K.rmsnorm_model()
    blocks = tuple(dataclasses.replace(b, index_map=lambda i: (i,))
                   if b.name == "x" else b for b in m.blocks)
    assert "index-map-arity" in rules_of(
        K.check_index_maps(dataclasses.replace(m, blocks=blocks)))


def test_mutant_kernel_bf16_accum():
    m = dataclasses.replace(K.grouped_gemm_model(),
                            accum_dtype="bfloat16")
    assert "accum-dtype" in rules_of(K.check_accum_dtypes(m))


# ---------------------------------------------------------------------------
# Seeded mutants — convention linter snippets
# ---------------------------------------------------------------------------


def test_mutant_lint_shard_map_import():
    src = "from jax.experimental.shard_map import shard_map\n"
    assert "mesh-entry" in rules_of(C.lint_source("core/x.py", src))


def test_mutant_lint_use_mesh_attribute():
    src = "import jax\n\n\ndef f(m):\n    return jax.sharding.use_mesh(m)\n"
    assert "mesh-entry" in rules_of(C.lint_source("launch/x.py", src))


def test_mutant_lint_mesh_constructor():
    src = ("from jax.sharding import Mesh\n\n\ndef f(d):\n"
           "    return Mesh(d, ('x',))\n")
    assert "mesh-entry" in rules_of(C.lint_source("training/x.py", src))


def test_lint_mesh_annotation_is_legal():
    src = ("from jax.sharding import Mesh\n\n\ndef f(m: Mesh) -> Mesh:\n"
           "    return m\n")
    assert C.lint_source("launch/x.py", src) == []


def test_mutant_lint_mutable_module_dict():
    src = "_CACHE = {}\n"
    assert "mutable-global" in rules_of(C.lint_source("core/x.py", src))
    # same accumulator OUTSIDE a hot dir is tolerated
    assert C.lint_source("configs/x.py", src) == []


def test_mutant_lint_global_stmt():
    src = "_N = 0\n\n\ndef bump():\n    global _N\n    _N += 1\n"
    assert "mutable-global" in rules_of(C.lint_source("serving/x.py", src))


def test_mutant_lint_serving_assert():
    src = "def admit(n):\n    assert n >= 0\n    return n\n"
    assert "serving-assert" in rules_of(
        C.lint_source("serving/engine2.py", src))
    # the same assert in kernels/ is fine (shape guards at trace time)
    assert C.lint_source("kernels/x.py", src) == []


def test_mutant_lint_inline_knob_mod():
    src = "def pick(d, plan):\n    return d % plan.n_col_blocks == 0\n"
    assert "knob-legalize" in rules_of(
        C.lint_source("core/transport2.py", src))


def test_mutant_lint_bad_ignore_reported():
    src = "def admit(n):\n    assert n  # verify: ignore[serving-assert]\n"
    rules = rules_of(C.lint_source("serving/x.py", src))
    assert "bad-ignore" in rules and "serving-assert" in rules


def test_lint_justified_ignore_suppresses():
    src = ("def admit(n):\n"
           "    assert n  # verify: ignore[serving-assert] -- test-only "
           "shim, never deployed\n")
    assert C.lint_source("serving/x.py", src) == []


# ---------------------------------------------------------------------------
# Properties — the candidate_plans VMEM gate and Plan validation
# ---------------------------------------------------------------------------

BIG = A.MoEShape(M=4096, N=16384, K=4096, E=16, topk=2, ep=8, etp=1)


def test_candidate_plans_never_overflow_vmem():
    for s in (MIX, BIG,
              A.MoEShape(M=8192, N=2048, K=1408, E=64, topk=4, ep=8,
                         etp=1)):
        for p in A.candidate_plans(s, include_graph=True):
            assert K.plan_vmem_ok(s, p, HW), (s.N, s.K, p)


def test_candidate_plans_filter_actually_bites():
    # at d_model=16k no pallas_fused tiling fits the v5e budget: the gate
    # must remove them all, and disabling it must bring them back
    fused = [p for p in A.candidate_plans(BIG)
             if p.gemm_impl == "pallas_fused"]
    assert fused == []
    nogate = dataclasses.replace(HW, vmem_bytes=0)
    assert any(p.gemm_impl == "pallas_fused"
               for p in A.candidate_plans(BIG, hw=nogate))


def test_candidate_plans_xla_survives_big_shapes():
    # the gate never strands a shape without candidates
    assert any(p.gemm_impl == "xla" for p in A.candidate_plans(BIG))
    assert any(p.impl == "comet" for p in A.candidate_plans(BIG))


def test_tuner_on_big_shape_picks_legal_plan():
    plan = A.tune_plan(BIG, HW)
    assert K.plan_vmem_ok(BIG, plan, HW)
    assert plan.validate(BIG.N, BIG.ep) == []


def test_plan_validate_ranges():
    assert A.Plan().validate() == []
    assert A.Plan(impl="warp").validate()
    assert A.Plan(n_col_blocks=0).validate()
    assert A.Plan(n_col_blocks=A.MAX_COL_BLOCKS + 1).validate()
    assert A.Plan(ring_group=0).validate()
    assert A.Plan(gemm_impl="cuda").validate()
    assert A.Plan(phase="serve").validate()
    assert A.Plan(schedule="overlap").validate()      # needs n_slices >= 2
    assert A.Plan(n_slices=3).validate()              # per-layer w/ slices
    assert A.Plan("comet", 2, 4, schedule="overlap",
                  n_slices=2).validate() == []


def test_plan_validate_geometry():
    assert A.Plan("comet", 2, 4).validate(4096, 8) == []
    assert A.Plan("comet", 3, 4).validate(4096, 8)    # 3 doesn't divide 8
    assert A.Plan("comet", 2, 5).validate(4096, 8)    # 5 doesn't divide d


def test_plan_cache_put_rejects_illegal():
    pc = A.PlanCache()
    with pytest.raises(ValueError, match="illegal"):
        pc.put(MIX, HW, A.Plan("comet", 3, 4), save=False)
    pc.put(MIX, HW, A.Plan("comet", 2, 4), save=False)


def test_plan_cache_load_skips_illegal_entries(tmp_path):
    path = str(tmp_path / "plans.json")
    good = A.Plan("comet", 2, 4, "pallas_fused")
    key_good = A.PlanCache.key(MIX, HW)
    key_bad = A.PlanCache.key(dataclasses.replace(MIX, M=1024), HW)
    with open(path, "w") as f:
        json.dump({"version": A.PLAN_CACHE_VERSION, "plans": {
            key_good: good.to_json(),
            key_bad: dict(A.Plan("comet", 2, 4).to_json(),
                          n_col_blocks=A.MAX_COL_BLOCKS + 1),
        }}, f)
    with pytest.warns(UserWarning, match="illegal"):
        pc = A.PlanCache(path)
    assert pc.plans == {key_good: good}


def test_plan_cache_load_legalizes_handwritten_knobs(tmp_path):
    """A statically-fine entry whose knobs just aren't pre-legalized (a
    hand-written or pre-v3 cache) loads as the legalized schedule instead
    of being dropped — resolve_plan has always run the legalized knobs."""
    path = str(tmp_path / "plans.json")
    key = A.PlanCache.key(MIX, HW)
    with open(path, "w") as f:
        json.dump({"version": A.PLAN_CACHE_VERSION, "plans": {
            key: A.Plan("comet", 3, 4).to_json(),       # 3 ∤ ep=8 -> rg 2
        }}, f)
    pc = A.PlanCache(path)
    loaded = pc.plans[key]
    assert loaded.ring_group == A.legalize_ring_group(MIX.ep, 3) == 2
    assert loaded.validate(MIX.N, MIX.ep) == []


def test_load_plan_cache_memoizes_by_mtime(tmp_path):
    path = str(tmp_path / "plans.json")
    A.PlanCache(path).put(MIX, HW, A.Plan("comet", 2, 4))
    # force distinct mtimes: the memo key is (path, mtime)
    os.utime(path, (1_000_000_000, 1_000_000_000))
    pc1 = A.load_plan_cache(path)
    assert A.load_plan_cache(path) is pc1
    A.PlanCache(path).put(MIX, HW, A.Plan("comet", 4, 4))
    os.utime(path, (1_000_000_100, 1_000_000_100))
    pc2 = A.load_plan_cache(path)
    assert pc2 is not pc1
    assert pc2.get(MIX, HW).ring_group == 4


def test_legalize_fixed_point_direct():
    for d_model in (1536, 4096):
        for ep in (4, 8):
            for n in range(1, 10):
                p1 = A.legalize_plan(A.Plan("comet", n, n), d_model, ep)
                assert A.legalize_plan(p1, d_model, ep) == p1


def test_forward_scheduled_hook_rejects_corrupt_order(monkeypatch):
    """End-to-end: corrupt exec_order's output and the debug assertion in
    forward_scheduled must refuse to interpret the trace."""
    jax = pytest.importorskip("jax")
    import numpy as np
    import repro.core.schedule as SCH
    import repro.models.lm as LM
    from repro.configs.base import get_config

    cfg = get_config("qwen2-0.5b-smoke")
    cfg = dataclasses.replace(cfg, block_schedule="sequential")
    params = LM.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": np.zeros((2, 16), dtype=np.int32)}

    real = SCH.exec_order

    def corrupt(segs, mode):
        out = list(real(segs, mode))
        out[0], out[-1] = out[-1], out[0]
        return out

    monkeypatch.setattr(SCH, "exec_order", corrupt)
    monkeypatch.setenv("REPRO_VERIFY_SCHEDULE", "1")
    with pytest.raises(RuntimeError, match="hazard"):
        LM.forward_scheduled(cfg, params, batch)
