"""Disaggregated prefill/decode serving (serving/disagg.py): paged-page
KV migration between a PrefillWorker and a DecodeWorker, Router
scheduling (FIFO dispatch, backpressure, route hints), bit-exactness vs
the shared single engine, TTFT decoupling at equal total slots, and
exactly-once delivery across the handoff boundary under single-worker
crashes. Uses the non-MoE qwen2 smoke arch so greedy decode is
batch-composition independent (bit-exact comparisons), plus the mamba2
smoke arch for SSM-state (non-paged per-slot state) migration."""
import tempfile

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serving import (DecodeWorker, EngineConfig, FaultInjector,
                           FaultPlan, PrefillWorker, RejectedRequest,
                           RejectReason, RequestSpec, RequestStatus, Router,
                           ServeEngine)
from repro.serving.paged_cache import (AllocatorError, BlockAllocator,
                                       pages_for)


@pytest.fixture(scope="module")
def params():
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=2, seed=0, chunk=4)
    return eng.params


def make_ec(**kw):
    kw.setdefault("max_seq", 64)
    kw.setdefault("chunk", 4)
    kw.setdefault("page_size", 8)
    kw.setdefault("disagg", True)
    kw.setdefault("prefill_workers", 1)
    kw.setdefault("decode_workers", 1)
    kw.setdefault("prefill_slots", 2)
    kw.setdefault("decode_slots", 2)
    return EngineConfig(**kw)


def make_router(params, **kw):
    cfg = get_config("qwen2-0.5b-smoke")
    return make_ec(**kw).build(cfg, params=params)


def make_shared(params, **kw):
    cfg = get_config("qwen2-0.5b-smoke")
    kw.setdefault("max_seq", 64)
    kw.setdefault("batch_size", 4)
    kw.setdefault("chunk", 4)
    kw.setdefault("page_size", 8)
    return EngineConfig(**kw).build(cfg, params=params)


PROMPTS = [[3, 1, 4, 1, 5], [2, 7, 1], [9, 10, 11, 12, 13, 14, 15, 16, 17],
           [6, 5]]


def run_all(eng, prompts, max_new=4, **submit_kw):
    rids = [eng.submit(p, max_new=max_new, **submit_kw) for p in prompts]
    eng.run()
    return {r: list(eng.finished[r].tokens) for r in rids}


# ---------------------------------------------------------------------------
# Allocator page migration (pure allocator, no model)
# ---------------------------------------------------------------------------


def _alloc(n_pages=9, page_size=8, max_blocks=8):
    return BlockAllocator(n_pages, page_size, max_blocks)


def test_export_frees_pages_and_returns_them():
    a = _alloc()
    got = a.allocate(0, 20)                       # 3 pages
    free_before = a.free_pages
    pages = a.export_pages(0)
    assert pages == got
    assert a.free_pages == free_before + 3        # capacity back at handoff
    assert a.owned(0) == []


def test_double_export_raises():
    a = _alloc()
    a.allocate(0, 8)
    a.export_pages(0)
    with pytest.raises(AllocatorError):
        a.export_pages(0)


def test_import_allocates_matching_count():
    src, dst = _alloc(), _alloc()
    pages = src.allocate(0, 17)                   # 3 pages
    table = pages + [0] * 5
    src.export_pages(0)
    got = dst.import_pages(1, pages, table)
    assert len(got) == 3 and dst.owned(1) == got


def test_import_torn_handoff_raises():
    src, dst = _alloc(), _alloc()
    pages = src.allocate(0, 17)
    src.export_pages(0)
    bad = list(pages)
    bad[1] = bad[1] + 1 if bad[1] + 1 not in bad else bad[1] + 2
    with pytest.raises(AllocatorError):           # table disagrees w/ pages
        dst.import_pages(1, pages, bad + [0] * 5)
    with pytest.raises(AllocatorError):           # null page in payload
        dst.import_pages(1, [0] + pages[1:], [0] + pages[1:] + [0] * 5)
    with pytest.raises(AllocatorError):           # empty handoff
        dst.import_pages(1, [], [0] * 8)


# ---------------------------------------------------------------------------
# Engine-level handoff: export on one engine, migrate into another
# ---------------------------------------------------------------------------


def test_export_migrate_continues_bit_exact(params):
    """Prefill on worker A, export, import into worker B, decode there:
    the resulting stream must equal the single shared engine's."""
    cfg = get_config("qwen2-0.5b-smoke")
    ref = run_all(make_shared(params), PROMPTS[:1], max_new=5)
    a = PrefillWorker(cfg, params=params, max_seq=64, batch_size=2,
                      chunk=4, page_size=8)
    b = DecodeWorker(cfg, params=params, max_seq=64, batch_size=2,
                     chunk=4, page_size=8)
    b.emitted = a.emitted                         # shared watermark
    rid = a.submit(PROMPTS[0], max_new=5)
    while not a.outbox:                           # _after_phases auto-exports
        a.step()                                  # each finished prefill
    hand = a.outbox.pop()
    assert not any(a.live) and a.handoffs_out == 1
    assert hand.n_content_pages == pages_for(len(PROMPTS[0]), a.page_size)
    assert b.can_import(hand) and b.migrate(hand)
    while b.pending:
        b.step()
    assert list(b.finished[rid].tokens) == ref[0]
    assert b.prefill_tokens == 0                  # pages moved, no re-prefill


def test_prefill_worker_cannot_decode_or_migrate(params):
    cfg = get_config("qwen2-0.5b-smoke")
    a = PrefillWorker(cfg, params=params, max_seq=64, batch_size=2,
                      chunk=4, page_size=8)
    assert a.decode is None
    with pytest.raises(RuntimeError):
        a.migrate(None)
    b = DecodeWorker(cfg, params=params, max_seq=64, batch_size=2,
                     chunk=4, page_size=8)
    assert b.prefill is None
    with pytest.raises(RuntimeError):             # decode role takes no
        b.submit(PROMPTS[0], max_new=2)           # direct submissions


# ---------------------------------------------------------------------------
# Router topology: parity, scheduling, accounting
# ---------------------------------------------------------------------------


def test_router_parity_vs_shared_engine(params):
    ref = run_all(make_shared(params), PROMPTS, max_new=4)
    router = make_router(params)
    got = run_all(router, PROMPTS, max_new=4)
    assert got == ref
    assert all(router.finished[r].status == RequestStatus.OK for r in got)


def test_router_generate_parity(params):
    ref = make_shared(params).generate(PROMPTS, max_new=4)
    got = make_router(params).generate(PROMPTS, max_new=4)
    assert np.array_equal(np.asarray(ref.tokens), np.asarray(got.tokens))
    assert got.statuses == ["ok"] * len(PROMPTS)


def test_router_eos_parity(params):
    """eos fired mid-stream on the decode worker truncates exactly like
    the shared engine (eos taken from the reference's generated run)."""
    ref_full = run_all(make_shared(params), PROMPTS[:1], max_new=6)
    eos = ref_full[0][2]                          # stop after 3 tokens
    ref = run_all(make_shared(params), PROMPTS[:1], max_new=6, eos_id=eos)
    got = run_all(make_router(params), PROMPTS[:1], max_new=6, eos_id=eos)
    assert got == ref and len(got[0]) <= 3


def test_migration_accounting_no_reprefill(params):
    router = make_router(params)
    run_all(router, PROMPTS, max_new=4)
    s = router.summary()
    assert s["migrations"] == len(PROMPTS)
    assert s["pages_moved"] == sum(pages_for(len(p), router.page_size)
                                   for p in PROMPTS)
    assert all(w.prefill_tokens == 0 for w in router.decodes)
    assert all(w.decode_tokens == 0 for w in router.prefills)
    assert router.prefill_tokens == sum(len(p) for p in PROMPTS)


def test_backpressure_single_decode_slot(params):
    """decode_slots=1 forces handoffs to wait in the ready queue; FIFO
    order and bit-exactness must survive the backpressure."""
    ref = run_all(make_shared(params), PROMPTS, max_new=4)
    router = make_router(params, decode_slots=1)
    got = run_all(router, PROMPTS, max_new=4)
    assert got == ref
    assert router.summary()["migrations"] == len(PROMPTS)


def test_multi_worker_spread_with_route_hints(params):
    """2x1 prefill -> 2x1 decode: route hints pin prompts to distinct
    prefill workers; every stream still matches the shared engine."""
    ref = run_all(make_shared(params), PROMPTS, max_new=4)
    router = make_router(params, prefill_workers=2, decode_workers=2,
                         prefill_slots=1, decode_slots=1)
    rids = [router.submit(RequestSpec(tuple(p), max_new=4, route_hint=i))
            for i, p in enumerate(PROMPTS)]
    router.run()
    assert {r: list(router.finished[r].tokens) for r in rids} == ref
    assert all(w.prefill_tokens > 0 for w in router.prefills)
    assert sum(w.decode_tokens > 0 for w in router.decodes) >= 1


def test_router_rejections_match_engine_reasons(params):
    router = make_router(params)
    for prompt, kw, reason in [
            ([], {}, RejectReason.EMPTY_PROMPT),
            ([1, 2, 3], {"max_new": 62}, RejectReason.TOO_LONG),
            ("text", {}, RejectReason.INVALID),
    ]:
        with pytest.raises(RejectedRequest) as ei:
            router.submit(prompt, **kw)
        assert ei.value.reason == reason
        assert ei.value.request.status == RequestStatus.REJECTED
    # still serviceable afterwards
    got = run_all(router, PROMPTS[:1], max_new=3)
    assert len(next(iter(got.values()))) == 3


def test_router_over_capacity_uses_tightest_pool(params):
    router = make_router(params, n_pages=5)       # 4 usable pages
    with pytest.raises(RejectedRequest) as ei:
        router.submit(list(range(1, 35)), max_new=8)   # 6 pages > 4
    assert ei.value.reason == RejectReason.OVER_CAPACITY


def test_router_bounded_queue_and_shed(params):
    router = make_router(params, max_queue=2, shed_policy="reject")
    rids = [router.submit(p, max_new=2) for p in PROMPTS[:2]]
    # workers haven't stepped: both sit in the router queue
    with pytest.raises(RejectedRequest) as ei:
        router.submit(PROMPTS[2], max_new=2)
    assert ei.value.reason == RejectReason.QUEUE_FULL
    router.run()
    assert all(router.finished[r].status == RequestStatus.OK for r in rids)


def test_router_cancel_queued_and_running(params):
    router = make_router(params)
    r0 = router.submit(PROMPTS[0], max_new=16)
    r1 = router.submit(PROMPTS[1], max_new=16)
    assert router.cancel(r1)                      # still router-queued
    assert router.finished[r1].status == RequestStatus.CANCELLED
    for _ in range(3):
        router.step()
    assert router.cancel(r0)                      # live on a worker
    router.run()
    assert router.finished[r0].status == RequestStatus.CANCELLED
    assert not router.cancel(r0)                  # already terminal


def test_engineconfig_disagg_requires_paging():
    with pytest.raises(ValueError):
        EngineConfig(disagg=True, page_size=0)


# ---------------------------------------------------------------------------
# TTFT decoupling at equal total slots (virtual tick clock)
# ---------------------------------------------------------------------------


class Ticks:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _ttft_trace(build, prompts, arrivals, max_new):
    clock = Ticks()
    eng = build(clock)
    rids, nxt = [], 0
    while nxt < len(prompts) or eng.pending:
        while nxt < len(prompts) and arrivals[nxt] <= clock.t:
            rids.append(eng.submit(prompts[nxt], max_new=max_new))
            nxt += 1
        if not eng.pending and nxt < len(prompts):
            rids.append(eng.submit(prompts[nxt], max_new=max_new))
            nxt += 1
        eng.step()
        clock.t += 1.0
    toks = {r: list(eng.finished[r].tokens) for r in rids}
    ttfts = [eng.finished[r].ttft_s for r in rids]
    return eng, toks, ttfts


@pytest.mark.slow
def test_disagg_ttft_below_shared_on_poisson_trace(params):
    """The paper point of the topology: on a prefill-heavy mixed trace at
    EQUAL total slots, prefill admission no longer waits on decode slot
    turnover, so mean TTFT (in deterministic scheduler ticks) drops
    strictly below the shared engine's — with bit-exact streams."""
    cfg = get_config("qwen2-0.5b-smoke")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(10)]
    arrivals = np.cumsum(rng.exponential(1.5, size=len(prompts))).astype(int)
    shared_ec = EngineConfig(max_seq=64, batch_size=4, chunk=4, page_size=8)
    _, ref, tt_shared = _ttft_trace(
        lambda c: shared_ec.build(cfg, params=params, clock=c),
        prompts, arrivals, max_new=8)
    _, got, tt_dis = _ttft_trace(
        lambda c: make_ec().build(cfg, params=params, clock=c),
        prompts, arrivals, max_new=8)
    assert got == ref
    assert float(np.mean(tt_dis)) < float(np.mean(tt_shared))


# ---------------------------------------------------------------------------
# Exactly-once across the handoff boundary under single-worker crashes
# ---------------------------------------------------------------------------


def _crash_run(params, crash_workers, emissions, **ec_kw):
    with tempfile.TemporaryDirectory(prefix="repro_disagg_t_") as snap:
        ec = make_ec(snapshot_dir=snap, snapshot_every=2, max_restarts=16,
                     recover=True, **ec_kw)
        plan = FaultPlan(crash_workers=crash_workers)
        inj = {t: FaultInjector(plan, role=t) for t in ec.worker_targets()}
        router = ec.build(
            get_config("qwen2-0.5b-smoke"), params=params, faults=inj,
            on_token=lambda r, i, t: emissions.append((r, i, t)))
        toks = run_all(router, PROMPTS, max_new=4)
        injected = sum(i.counts["crash"] for i in inj.values())
    return router, toks, injected


def _check_exactly_once(emissions, toks):
    seen, dup = set(), 0
    for r, i, _ in emissions:
        dup += (r, i) in seen
        seen.add((r, i))
    lost = sum((r, i) not in seen
               for r, t in toks.items() for i in range(len(t)))
    assert dup == 0 and lost == 0


@pytest.mark.slow
def test_decode_worker_crash_exactly_once(params):
    ref = run_all(make_router(params), PROMPTS, max_new=4)
    emissions = []
    router, toks, injected = _crash_run(params, {4: ("decode", 0)},
                                        emissions)
    assert injected == 1 and router.recoveries == router.failures == 1
    assert toks == ref
    assert all(router.finished[r].status == RequestStatus.OK for r in toks)
    _check_exactly_once(emissions, toks)


@pytest.mark.slow
def test_prefill_worker_crash_exactly_once(params):
    """A prefill loss replays prefill from the restored snapshot; any
    duplicate handoff of an already-migrated request is deduped by rid
    at the router, so decode never sees the same stream twice."""
    ref = run_all(make_router(params), PROMPTS, max_new=4)
    emissions = []
    router, toks, injected = _crash_run(params, {3: ("prefill", 0)},
                                        emissions)
    assert injected == 1 and router.recoveries == router.failures == 1
    assert toks == ref
    _check_exactly_once(emissions, toks)


@pytest.mark.slow
def test_both_roles_crash_exactly_once(params):
    ref = run_all(make_router(params), PROMPTS, max_new=4)
    emissions = []
    router, toks, injected = _crash_run(
        params, {3: ("prefill", 0), 6: ("decode", 0)}, emissions)
    assert injected == 2 and router.recoveries == 2
    assert toks == ref
    _check_exactly_once(emissions, toks)


# ---------------------------------------------------------------------------
# SSM per-slot state migration (non-paged recurrent state rides the
# handoff alongside the paged KV pages)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ssm_state_migration_parity():
    cfg = get_config("mamba2-780m-smoke")
    shared = EngineConfig(max_seq=64, batch_size=4, chunk=4,
                          page_size=8).build(cfg)
    ref = run_all(shared, PROMPTS[:2], max_new=4)
    router = make_ec().build(cfg, params=shared.params)
    got = run_all(router, PROMPTS[:2], max_new=4)
    assert got == ref
    assert router.summary()["migrations"] == 2
