"""The HLO static cost model (roofline source of truth) against XLA's own
cost_analysis on programs where XLA is correct (no while loops), and against
hand-computed collective traffic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import (HLOCostModel, analyze_text,
                                     parse_instr_line, shape_numel_bytes)


def compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def xla_cost(c):
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


# ---------------------------------------------------------------------------
# parser units
# ---------------------------------------------------------------------------

def test_shape_numel_bytes():
    assert shape_numel_bytes("f32[4,8]{1,0}") == (32, 128)
    assert shape_numel_bytes("bf16[10]") == (10, 20)
    assert shape_numel_bytes("(f32[2]{0}, s32[])") == (3, 12)
    assert shape_numel_bytes("pred[]") == (1, 1)


def test_parse_instr_with_index_comments_in_tuple_type():
    line = ('  %while.5 = (s32[], f32[2,2]{1,0}, /*index=2*/f32[4]{0}) '
            'while(%tuple), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"24"}}')
    ins = parse_instr_line(line)
    assert ins is not None
    assert ins.op == "while"
    assert ins.name == "while.5"
    assert ins.numel == 1 + 4 + 4


def test_parse_root_dot():
    line = ('  ROOT %dot.1 = f32[64,128]{1,0} dot(%a, %b), '
            'lhs_contracting_dims={1}, rhs_contracting_dims={0}')
    ins = parse_instr_line(line)
    assert ins.op == "dot" and ins.operands == ["a", "b"]


# ---------------------------------------------------------------------------
# flops: scan trip-count correctness (the bug this module exists to fix)
# ---------------------------------------------------------------------------

def test_scan_flops_match_unrolled():
    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=24)
        return y

    def f_unroll(x):
        for _ in range(24):
            x = x @ x
        return x

    x = jnp.zeros((128, 128))
    ours_scan = analyze_text(compiled(f_scan, x).as_text())
    xla_unroll_flops, _ = xla_cost(compiled(f_unroll, x))
    expected = 24 * 2 * 128 ** 3
    np.testing.assert_allclose(ours_scan.mxu_flops, expected, rtol=0.01)
    np.testing.assert_allclose(ours_scan.mxu_flops, xla_unroll_flops,
                               rtol=0.01)


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jnp.zeros((64, 64))
    cost = analyze_text(compiled(f, x).as_text())
    np.testing.assert_allclose(cost.mxu_flops, 15 * 2 * 64 ** 3, rtol=0.01)


def test_dot_flops_batched_and_contracted():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    a = jnp.zeros((4, 32, 64))
    b = jnp.zeros((4, 64, 16))
    cost = analyze_text(compiled(f, a, b).as_text())
    np.testing.assert_allclose(cost.mxu_flops, 2 * 4 * 32 * 64 * 16, rtol=0.01)


def test_unrolled_bytes_close_to_xla():
    def f(x):
        for _ in range(4):
            x = jnp.tanh(x @ x)
        return x
    x = jnp.zeros((128, 128))
    c = compiled(f, x)
    _, xla_bytes = xla_cost(c)
    ours = analyze_text(c.as_text())
    assert 0.5 * xla_bytes <= ours.bytes <= 2.0 * xla_bytes


# ---------------------------------------------------------------------------
# collectives (8 simulated devices in-process is not possible here since the
# main test process keeps 1 device; use replica_groups parsing directly)
# ---------------------------------------------------------------------------

def test_collective_ring_formulas():
    hlo = """
HloModule test

ENTRY %main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  %ar = f32[256]{0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[1024]{0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%ag), replica_groups=[2,4]<=[8], dimensions={0}, to_apply=%add
  %a2a = f32[256]{0} all-to-all(%rs), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %cp = f32[256]{0} collective-permute(%a2a), source_target_pairs={{0,1},{1,0}}
}
"""
    cost = analyze_text(hlo)
    B = 256 * 4
    assert cost.coll_per_op["all-reduce"] == pytest.approx(2 * 3 / 4 * B)
    assert cost.coll_per_op["all-gather"] == pytest.approx(3 * B)
    assert cost.coll_per_op["reduce-scatter"] == pytest.approx(3 / 4 * 4 * B)
    assert cost.coll_per_op["all-to-all"] == pytest.approx(3 / 4 * B)
    assert cost.coll_per_op["collective-permute"] == pytest.approx(B)


def test_async_start_done_counted_once():
    hlo = """
HloModule t

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %s = f32[64]{0} all-reduce-start(%p0), replica_groups=[1,4]<=[4], to_apply=%add
  ROOT %d = f32[64]{0} all-reduce-done(%s)
}
"""
    cost = analyze_text(hlo)
    assert cost.coll_counts.get("all-reduce") == 1
    assert cost.ici_bytes == pytest.approx(2 * 3 / 4 * 256)


def test_fusable_regions_skip_bytes_keep_flops():
    def f(q, k):
        with jax.named_scope("__fusable__flash"):
            s = q @ k
            return jnp.tanh(s) @ k
    q = jnp.zeros((128, 128))
    cost = analyze_text(compiled(f, q, q).as_text())
    assert cost.mxu_flops >= 2 * 2 * 128 ** 3 * 0.99
    assert cost.bytes < 128 * 128 * 4 * 4      # boundary-ish only


def test_dynamic_update_slice_counts_update_only():
    """KV-cache insert with a donated buffer (the decode-path contract): a
    1-token DUS into a big cache must cost O(token), not O(cache)."""
    def f(cache, tok):
        return jax.lax.dynamic_update_slice_in_dim(cache, tok, 5, axis=0)
    cache = jnp.zeros((4096, 64))
    tok = jnp.ones((1, 64))
    c = jax.jit(f, donate_argnums=0).lower(cache, tok).compile()
    cost = analyze_text(c.as_text())
    assert cost.bytes < 64 * 4 * 64            # ~2x update bytes, not 1MB
