"""Block-schedule IR (PR 6): scheduler legality, whole-graph cost model,
and — the acceptance bar — bit-parity of scheduled execution against the
sequential baseline across the arch grid, forward AND fwd+bwd."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import adaptive as A
from repro.core import schedule as SCH
from repro.models import lm

HW = A.TPU_V5E
MIXTRAL = A.MoEShape(M=8192, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
PLAN = A.Plan("comet", ring_group=2, n_col_blocks=4,
              gemm_impl="pallas_fused", fused_combine=True)


# ---------------------------------------------------------------------------
# scheduler legality unit suite
# ---------------------------------------------------------------------------

def test_graph_rejects_unknown_kind_and_forward_deps():
    g = SCH.ScheduleGraph()
    with pytest.raises(ValueError, match="unknown segment kind"):
        g.add("x", "not_a_kind", 0)
    a = g.add("a", "attn", 0)
    with pytest.raises(ValueError, match="earlier segment"):
        g.add("b", "router", 0, deps=[a + 1])   # dep on a future sid


def test_validate_order_catches_violations():
    g = SCH.ScheduleGraph()
    a = g.add("a", "attn", 0, cost_s=1.0)
    r = g.add("r", "router", 0, deps=[a], cost_s=1.0)
    assert SCH.validate_order(g, [a, r]) == []
    errs = SCH.validate_order(g, [r, a])        # dep after use
    assert errs and "must precede" in errs[0]
    assert SCH.validate_order(g, [a])           # not a permutation
    assert SCH.validate_order(g, [a, a])


@pytest.mark.parametrize("training", [False, True])
@pytest.mark.parametrize("ns", [1, 2, 4])
def test_overlap_order_is_legal_on_lowered_graphs(training, ns):
    g = SCH.lower_model_graph(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                              n_blocks=3, n_slices=ns, training=training)
    order = SCH.overlap_order(g)
    assert SCH.validate_order(g, order) == []
    # and the evaluated schedule never beats physics: total >= the busiest
    # single resource
    t = SCH.schedule_time(g, order)
    assert t["total"] >= max(v for k, v in t.items()
                             if k.startswith("busy_")) - 1e-12


def test_next_block_attn_depends_on_prev_combine_per_slice():
    """The TRUE cross-layer dependency: attn of block i+1 (slice j) must
    wait for the LAST combine of block i in the SAME slice — and nothing
    earlier. The lowering must encode exactly that edge."""
    g = SCH.lower_model_graph(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                              n_blocks=2, n_slices=2)
    segs = {s.name: s for s in g.segments}
    for j in range(2):
        attn1 = segs[f"L1.s{j}.attn"]
        assert len(attn1.deps) == 1
        dep = g.segments[attn1.deps[0]]
        assert dep.kind == "combine_hop" and dep.block == 0
        assert dep.slice_id == j
        # it is the last combine of that slice in block 0
        combines = [s for s in g.segments if s.kind == "combine_hop"
                    and s.block == 0 and s.slice_id == j]
        assert dep.sid == max(s.sid for s in combines)


def test_wgrad_flush_floats_freely():
    """PR 3's deferred dW: flush segments must have NO dependents, so the
    scheduler can sink them into any later bubble."""
    g = SCH.lower_model_graph(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                              n_blocks=2, training=True)
    flushes = {s.sid for s in g.segments if s.kind == "wgrad_flush"}
    assert flushes
    for s in g.segments:
        assert not (flushes & set(s.deps)), \
            f"{s.name} depends on a wgrad_flush"


@pytest.mark.parametrize("training", [False, True])
@pytest.mark.parametrize("ns", [1, 2])
def test_race_detector_clean_on_lowered_graphs(training, ns):
    """PR 8's independent hazard re-derivation (analysis/verify): every
    overlap order the scheduler emits must satisfy the STRUCTURALLY
    re-derived ring rules — deps are never consulted, so a lowering bug
    and a scheduler bug cannot cancel out."""
    from repro.analysis.verify import schedule_check as V
    plan = A.legalize_plan(PLAN, MIXTRAL.N, MIXTRAL.ep)
    diags = V.check_lowered(HW, MIXTRAL, plan, d_model=MIXTRAL.N,
                            n_blocks=3, n_slices=ns, training=training)
    assert diags == [], "\n".join(str(d) for d in diags)


def test_race_detector_guards_scheduled_execution(monkeypatch):
    """forward_scheduled runs the race detector at trace time by default
    (REPRO_VERIFY_SCHEDULE=0 opts out): a corrupted emission order must
    be refused before any segment is interpreted."""
    cfg, params, batch = _arch_setup("qwen2-0.5b-smoke")
    cfg = dataclasses.replace(cfg, block_schedule="overlap")
    real = SCH.exec_order

    def corrupt(segs, mode):
        out = list(real(segs, mode))
        out[0], out[-1] = out[-1], out[0]
        return out

    monkeypatch.delenv("REPRO_VERIFY_SCHEDULE", raising=False)
    monkeypatch.setattr(SCH, "exec_order", corrupt)
    with pytest.raises(RuntimeError, match="hazard"):
        lm.forward_scheduled(cfg, params, batch)


@pytest.mark.parametrize("training", [False, True])
def test_scheduled_no_worse_and_barriers_no_better(training):
    g = SCH.lower_model_graph(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                              n_blocks=2, n_slices=2, training=training)
    seq = SCH.sequential_order(g)
    t_sched = SCH.schedule_time(g, SCH.overlap_order(g))["total"]
    t_free = SCH.schedule_time(g, seq)["total"]
    t_barrier = SCH.schedule_time(g, seq, layer_barriers=True)["total"]
    assert t_sched <= t_free + 1e-12       # scheduler never legalizes worse
    assert t_barrier >= t_free - 1e-12     # barriers only ever add time


# ---------------------------------------------------------------------------
# whole-graph cost model: the PR 6 figure's inequality, at test scale
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("training", [False, True])
def test_whole_graph_scheduled_strictly_below_baseline(training):
    base = SCH.graph_step_time(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                               training=training, scheduled=False)
    sched = min(
        SCH.graph_step_time(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                            n_slices=ns, training=training)["total"]
        for ns in (1, 2, 4))
    assert sched < base["total"]
    # lump terms are charged identically: the win comes from the order
    assert base["lump_s"] == pytest.approx(
        SCH.graph_step_time(HW, MIXTRAL, PLAN, d_model=MIXTRAL.N,
                            n_slices=2, training=training)["lump_s"])


def test_ring_counts_match_transport():
    """The cost lowering's segment counts must never drift from the real
    ring's loop structure in core/transport.py."""
    from repro.core.transport import comet_ring_segments
    for ep in (2, 4, 8):
        for rg in (1, 2, 4):
            for n_col in (1, 2, 4):
                assert (SCH.comet_ring_counts(ep, rg, n_col)
                        == comet_ring_segments(ep, rg, n_col)), \
                    (ep, rg, n_col)


def test_adaptive_graph_terms():
    bub = A.ring_bubble_time(HW, MIXTRAL, PLAN)
    fill = A.cross_layer_fill_time(HW, MIXTRAL, PLAN, n_slices=2)
    fill_t = A.cross_layer_fill_time(HW, MIXTRAL, PLAN, n_slices=2,
                                     training=True)
    assert bub > 0.0          # the ring does leave compute idle
    assert 0.0 < fill <= bub * 2 + 1e-9
    assert fill_t > 0.0       # wgrad flushes + attn give bwd fill too


def test_tuner_ranks_graph_candidates():
    cands = list(A.candidate_plans(MIXTRAL, include_graph=True))
    graph = [p for p in cands if p.schedule == "overlap"]
    assert graph and all(p.n_slices in (2, 4) for p in graph)
    assert all(p.impl == "comet" for p in graph)
    plan = A.tune_plan(MIXTRAL, HW, candidates=cands)
    # at the paper shape the scheduled variant strictly dominates its own
    # per-layer base, so the tuner must pick a whole-graph plan
    assert plan.schedule == "overlap"
    m = A.phase_measure(HW, MIXTRAL, "train")
    assert m(plan) <= m(dataclasses.replace(plan, schedule="", n_slices=1))


def test_plan_cache_v6_roundtrip_and_compat(tmp_path):
    p6 = A.Plan("comet_hier", 2, 4, "pallas_fused", fused_combine=True,
                schedule="overlap", n_slices=4, intra_group=4,
                wire_dtype="bf16")
    assert A.Plan.from_json(p6.to_json()) == p6
    # a v5 cache entry (no intra_group / wire_dtype keys) must load as a
    # flat-topology plan with the defaults
    v5 = {k: v for k, v in p6.to_json().items()
          if k not in ("intra_group", "wire_dtype")}
    p = A.Plan.from_json(v5)
    assert p.intra_group == 1 and p.wire_dtype == "fp32"
    # a v4 entry (additionally no schedule / n_slices) still loads
    v4 = {k: v for k, v in v5.items() if k not in ("schedule", "n_slices")}
    p = A.Plan.from_json(v4)
    assert p.schedule == "" and p.n_slices == 1
    assert p.intra_group == 1 and p.wire_dtype == "fp32"
    assert A.PLAN_CACHE_VERSION == 6


# ---------------------------------------------------------------------------
# executed IR: exec_order legality + bit-parity across the arch grid
# ---------------------------------------------------------------------------

def test_exec_order_respects_dataflow():
    @dataclasses.dataclass(frozen=True)
    class S:
        name: str
        kind: str
        block: int
        reads: tuple
        writes: tuple

    segs = [S("a", "attn", 0, ("x",), ("h",)),
            S("b", "residual", 0, ("x", "h"), ("x2",)),
            S("c", "moe", 0, ("x2",), ("y",)),
            S("d", "attn", 1, ("y",), ("h2",))]
    out = SCH.exec_order(segs, "overlap")
    pos = {s.name: i for i, s in enumerate(out)}
    assert sorted(pos) == ["a", "b", "c", "d"]
    assert pos["a"] < pos["b"] < pos["c"] < pos["d"]   # RAW chain
    with pytest.raises(ValueError, match="unknown schedule mode"):
        SCH.exec_order(segs, "bogus")


def test_exec_order_war_hazard():
    """A segment overwriting a value a prior segment still reads must not
    hoist above that reader."""
    @dataclasses.dataclass(frozen=True)
    class S:
        name: str
        kind: str
        block: int
        reads: tuple
        writes: tuple

    segs = [S("w0", "attn", 0, (), ("v",)),
            S("rd", "moe", 0, ("v",), ("y",)),
            S("w1", "norm", 1, (), ("v",))]     # cheap, tempting to hoist
    out = SCH.exec_order(segs, "overlap")
    pos = {s.name: i for i, s in enumerate(out)}
    assert pos["rd"] < pos["w1"]


# the scheduled-forward grid: one arch per block family the IR must cover
PARITY_ARCHS = [
    "qwen2-0.5b-smoke",               # attn-only dense
    "granite-moe-3b-a800m-smoke",     # MoE (+ shared expert path)
    "granite-moe-bigmac-smoke",       # MoE with descend-ascend wire
    "mamba2-780m-smoke",              # SSM
    pytest.param("jamba-v0.1-52b-smoke",
                 marks=pytest.mark.slow),   # mixed attn/SSM/MoE hybrid
]


def _arch_setup(name):
    cfg = get_config(name)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    return cfg, params, {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("name", PARITY_ARCHS)
def test_scheduled_forward_bit_parity(name):
    cfg, params, batch = _arch_setup(name)
    c_seq = dataclasses.replace(cfg, block_schedule="sequential")
    c_ovl = dataclasses.replace(cfg, block_schedule="overlap")
    h0, a0, _ = lm.forward(cfg, params, batch)          # scan path
    h1, a1, _ = lm.forward(c_seq, params, batch)
    h2, a2, _ = lm.forward(c_ovl, params, batch)
    # scheduled emission is a pure permutation: BITWISE identical
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    # and the IR path agrees with the scan/unroll reference numerically
    assert np.allclose(np.asarray(h0), np.asarray(h1), atol=1e-4)


@pytest.mark.parametrize("name", PARITY_ARCHS)
def test_scheduled_backward_bit_parity(name):
    cfg, params, batch = _arch_setup(name)

    def grads(c):
        return jax.grad(lambda p: lm.loss_fn(c, p, batch)[0])(params)

    g1 = grads(dataclasses.replace(cfg, block_schedule="sequential"))
    g2 = grads(dataclasses.replace(cfg, block_schedule="overlap"))
    flat1 = jax.tree_util.tree_leaves(g1)
    flat2 = jax.tree_util.tree_leaves(g2)
    assert len(flat1) == len(flat2)
    for x, y in zip(flat1, flat2):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_train_step_schedule_knob():
    """launch.build_train_step threads schedule= into the config so the
    scheduled path is what jit traces."""
    import inspect

    from repro.launch.train_step import build_train_step
    assert "schedule" in inspect.signature(build_train_step).parameters
    cfg, params, batch = _arch_setup("granite-moe-3b-a800m-smoke")
    c = dataclasses.replace(cfg, block_schedule="overlap")
    h, aux, _ = lm.forward(c, params, batch)
    assert h.shape == (2, 16, cfg.d_model)
