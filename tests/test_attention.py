"""Attention math: chunked online-softmax vs dense oracle, position-array
masking (sequence-sharded case), GQA head mapping, rope."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import apply_rope

KEY = jax.random.PRNGKey(2)


def qkv(B=2, S=128, H=4, Hkv=2, hd=32, Sk=None):
    Sk = Sk or S
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qb,kb", [(32, 32), (64, 128), (128, 64)])
def test_chunked_matches_dense(causal, qb, kb):
    q, k, v = qkv(S=256)
    want = A.dense_attention(q, k, v, causal)
    got = A.chunked_attention(q, k, v, causal, q_block=qb, kv_block=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_chunked_kv_mask_matches_dense(causal):
    """The serving pad mask through the flash path: chunked_attention with
    kv_mask must equal the dense oracle (valid rows; left-pad pattern)."""
    q, k, v = qkv(S=256)
    valid = np.zeros((2, 256), bool)
    valid[0, 37:] = True                         # row 0: 37 left pads
    valid[1, :] = True                           # row 1: no pads
    kv_mask = jnp.asarray(valid)
    want = A.dense_attention(q, k, v, causal, kv_mask=kv_mask)
    got = A.chunked_attention(q, k, v, causal, q_block=64, kv_block=64,
                              kv_mask=kv_mask)
    # compare only fully-valid kv rows' outputs for valid queries (masked
    # queries' outputs are don't-care)
    np.testing.assert_allclose(np.asarray(got)[0, 37:],
                               np.asarray(want)[0, 37:],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got)[1], np.asarray(want)[1],
                               rtol=1e-5, atol=1e-5)


def test_chunked_gqa_ratios():
    for H, Hkv in [(8, 8), (8, 2), (8, 1)]:
        q, k, v = qkv(H=H, Hkv=Hkv, S=128)
        want = A.dense_attention(q, k, v, True)
        got = A.chunked_attention(q, k, v, True, 64, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_position_array_masking_equals_offset():
    """Sequence-sharded path: masking by absolute position arrays must equal
    computing the full sequence and slicing (the shard_map correctness
    contract)."""
    B, S, H, hd = 1, 128, 2, 16
    q, k, v = qkv(B=B, S=S, H=H, Hkv=H, hd=hd)
    full = A.dense_attention(q, k, v, causal=True)
    shards = 4
    Sl = S // shards
    for r in range(shards):
        q_loc = q[:, r * Sl:(r + 1) * Sl]
        qp = jnp.broadcast_to(jnp.arange(r * Sl, (r + 1) * Sl)[None], (B, Sl))
        kp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        got = A.chunked_attention(q_loc, k, v, True, 32, 32,
                                  q_pos=qp, kv_pos=kp)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(full[:, r * Sl:(r + 1) * Sl]),
                                   rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_dense_prefix():
    """decode at position t == row t of the causal dense attention."""
    B, S, H, hd = 2, 64, 4, 16
    q, k, v = qkv(B=B, S=S, H=H, Hkv=H, hd=hd)
    full = A.dense_attention(q, k, v, causal=True)
    for t in [0, 7, 63]:
        got = A.decode_attention(q[:, t:t + 1], k, v, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=1e-5, atol=1e-5)


def test_update_cache_inserts():
    B, S, Hkv, hd = 1, 16, 2, 8
    kc = jnp.zeros((B, S, Hkv, hd))
    vc = jnp.zeros((B, S, Hkv, hd))
    knew = jnp.ones((B, 1, Hkv, hd))
    vnew = 2 * jnp.ones((B, 1, Hkv, hd))
    kc, vc = A.update_cache(kc, vc, knew, vnew, jnp.int32(5))
    assert float(kc[0, 5].sum()) == Hkv * hd
    assert float(vc[0, 5].sum()) == 2 * Hkv * hd
    assert float(kc.sum()) == Hkv * hd                  # only one slot written


def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 32, 2, 16
    x = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    pos = jnp.arange(S)[None, :]
    r = apply_rope(x, pos, 10000.0)
    # rotation preserves per-head norms
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-4)
    # dot(q_i, k_j) depends only on i - j: shift both by a constant
    q, k = x, jax.random.normal(jax.random.PRNGKey(3), x.shape)
    r1 = (apply_rope(q, pos, 1e4)[0, 10, 0] @ apply_rope(k, pos, 1e4)[0, 4, 0])
    r2 = (apply_rope(q, pos + 7, 1e4)[0, 10, 0] @
          apply_rope(k, pos + 7, 1e4)[0, 4, 0])
    np.testing.assert_allclose(float(r1), float(r2), rtol=1e-4)


def test_expand_kv_mapping():
    """blocks._attn_core kv_map: global q head h uses kv head h // rep."""
    from repro.models.blocks import _attn_core
    from repro.configs.base import AttnConfig
    a = AttnConfig(n_heads=8, n_kv_heads=2, head_dim=16, q_block=64,
                   kv_block=64)
    B, S = 1, 64
    q = jax.random.normal(KEY, (B, S, 8, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, 2, 16), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o, kc, vc = _attn_core(a, True, False, False, False, None,
                           q, k, v, qp, qp, None)
    want = A.dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(k))
