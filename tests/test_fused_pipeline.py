"""Fused shared-tensor kernel pipeline (PR 2): the VMEM-resident fused
expert MLP vs the unfused ``"xla"`` backend, sort-based dispatch vs the seed
one-hot reference (bit-exact), the kernel-backed combine and its VJP, the
streaming per-block comet combine, and the v2 plan-cache schema."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import adaptive as A
from repro.core import routing as R
from repro.core import transport as T
from repro.core.moe_layer import moe_ffn


def _with_gemm(mcfg, name):
    """PR 3: the backend is an explicit config field threaded through the
    layer (no module-global switching)."""
    return dataclasses.replace(mcfg, gemm_impl=name)
from repro.kernels import ops, ref
from repro.parallel.mesh import AxisCtx

KEY = jax.random.PRNGKey(0)

# bf16 interpret runs are pure dtype variants of the fp32 coverage; the
# kernels-interpret CI job runs them (no -m filter) — keep tier-1 fast
BF16_SLOW = pytest.param(jnp.bfloat16, marks=pytest.mark.slow)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


def _expert_w(E, d, f, activation, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = {"w_up": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                  * 0.1).astype(dtype),
         "w_down": (jax.random.normal(ks[2], (E, f, d), jnp.float32)
                    * 0.1).astype(dtype)}
    if activation in ("swiglu", "geglu"):
        w["w_gate"] = (jax.random.normal(ks[0], (E, d, f), jnp.float32)
                       * 0.1).astype(dtype)
    return w


# ---------------------------------------------------------------------------
# fused_mlp kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,R,d,f", [
    (2, 128, 64, 128),         # exact tiles
    (3, 37, 19, 29),           # odd/unpadded on every dim
    (1, 130, 64, 200),         # padding on R and f
    (4, 16, 8, 520),           # f crosses the default bf chunk
])
@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_fused_mlp_matches_ref(E, R, d, f, activation, dtype):
    rows = jax.random.normal(KEY, (E, R, d), jnp.float32).astype(dtype)
    w = _expert_w(E, d, f, activation, dtype)
    got = ops.fused_mlp(rows, w, activation, interpret=True)
    want = ref.fused_mlp_ref(rows, w.get("w_gate"), w["w_up"], w["w_down"],
                             activation)
    assert got.shape == (E, R, d)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("activation", ["geglu", "relu2"])
def test_fused_mlp_other_activations(activation):
    rows = jax.random.normal(KEY, (2, 24, 16), jnp.float32)
    w = _expert_w(2, 16, 40, activation)
    got = ops.fused_mlp(rows, w, activation, interpret=True)
    want = ref.fused_mlp_ref(rows, w.get("w_gate"), w["w_up"], w["w_down"],
                             activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_mlp_orders_and_col_slice():
    """n_major traversal changes tile completion order, not values; a
    col-sliced call equals the corresponding slice of the full output —
    transport_comet's N-decomposed early return."""
    rows = jax.random.normal(KEY, (3, 40, 32), jnp.float32)
    w = _expert_w(3, 32, 48, "swiglu")
    full_em = ops.fused_mlp(rows, w, "swiglu", order="expert_major",
                            interpret=True)
    full_nm = ops.fused_mlp(rows, w, "swiglu", order="n_major", bn=16,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(full_em), np.asarray(full_nm),
                               rtol=1e-5, atol=1e-6)
    for start, width in ((0, 8), (8, 8), (5, 11)):
        blk = ops.fused_mlp(rows, w, "swiglu", col_slice=(start, width),
                            order="n_major", interpret=True)
        np.testing.assert_allclose(np.asarray(blk),
                                   np.asarray(full_em)[..., start:start + width],
                                   rtol=1e-5, atol=1e-6)


def test_fused_mlp_grads_match_ref():
    """The custom VJP (backward = oracle VJP) must agree with jnp autodiff."""
    rows = jax.random.normal(KEY, (2, 8, 16), jnp.float32)
    w = _expert_w(2, 16, 24, "swiglu")

    def loss_kernel(w_):
        return jnp.sum(ops.fused_mlp(rows, w_, "swiglu", interpret=True) ** 2)

    def loss_ref(w_):
        return jnp.sum(ref.fused_mlp_ref(rows, w_["w_gate"], w_["w_up"],
                                         w_["w_down"], "swiglu") ** 2)

    g = jax.grad(loss_kernel)(w)
    g_ref = jax.grad(loss_ref)(w)
    for k in w:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sort-based dispatch vs the seed one-hot implementation (bit-exact)
# ---------------------------------------------------------------------------

def _build_dispatch_onehot(x, idx, E, C):
    """The seed implementation, verbatim: O(T·k·E) one-hot cumsum ranking
    plus a (T*k, d) jnp.repeat materialization."""
    T, k = idx.shape
    d = x.shape[-1]
    flat_e = idx.reshape(-1)
    oh = (flat_e[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + jnp.minimum(pos, C - 1), E * C)
    x_rep = jnp.repeat(x, k, axis=0)
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(x_rep, mode="drop")
    return buf.reshape(E, C, d), flat_e, pos, keep


@pytest.mark.parametrize("T,E,k,factor", [
    pytest.param(64, 8, 2, 8.0, marks=pytest.mark.slow),   # no-drop
    (37, 6, 3, 0.5),           # capacity drops, odd T
    pytest.param(128, 16, 1, 1.0, marks=pytest.mark.slow),
    (16, 4, 4, 0.25),          # heavy drops
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sort_dispatch_bit_exact_vs_onehot(T, E, k, factor, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    d = 16
    x = jax.random.normal(k1, (T, d), jnp.float32)
    scores = jax.random.normal(k2, (T, E), jnp.float32)
    _, idx = jax.lax.top_k(scores, k)
    C = R.capacity(T, k, E, factor)
    buf, info = R.build_dispatch(x, idx, E, C)
    buf_ref, flat_e, pos, keep = _build_dispatch_onehot(x, idx, E, C)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(buf_ref))
    np.testing.assert_array_equal(np.asarray(info.flat_e), np.asarray(flat_e))
    np.testing.assert_array_equal(np.asarray(info.pos), np.asarray(pos))
    np.testing.assert_array_equal(np.asarray(info.keep), np.asarray(keep))


# ---------------------------------------------------------------------------
# kernel-backed combine: values + gradients
# ---------------------------------------------------------------------------

def test_combine_kernel_matches_jnp_and_grads():
    T, E, k, d, C = 37, 6, 2, 16, 8
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (T, d), jnp.float32)
    _, idx = jax.lax.top_k(jax.random.normal(key, (T, E)), k)
    _, info = R.build_dispatch(x, idx, E, C)
    w = jax.nn.softmax(jax.random.normal(key, (T, k)), axis=-1)
    recv = jax.random.normal(key, (E * C, d), jnp.float32)

    def jnp_ref(rv, ww):
        rows = rv[(info.flat_e) * C + jnp.minimum(info.pos, C - 1)]
        rows = jnp.where(info.keep[:, None], rows, 0).reshape(T, k, d)
        return jnp.einsum("tkd,tk->td", rows, ww)

    y = R.combine(recv, info, w, E_loc=E, C=C, rot=None, ep=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp_ref(recv, w)),
                               rtol=1e-5, atol=1e-6)
    g = jax.grad(lambda rv, ww: jnp.sum(
        R.combine(rv, info, ww, E, C, None, 1) ** 2), argnums=(0, 1))(recv, w)
    g_ref = jax.grad(lambda rv, ww: jnp.sum(jnp_ref(rv, ww) ** 2),
                     argnums=(0, 1))(recv, w)
    for a, b in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# layer-level: pallas_fused backend == xla backend, all transports
# ---------------------------------------------------------------------------

def _problem(activation="swiglu", E=8, d=64, f=33, B=2, S=16, k=2,
             capacity_factor=None, dtype=jnp.float32, seed=0):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, d_model=d, activation=activation)
    mcfg = dataclasses.replace(
        cfg.moe, num_experts=E, d_expert=f, top_k=k,
        capacity_factor=capacity_factor if capacity_factor else float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    full = _expert_w(E, d, f, activation, dtype, seed)
    params = {"router": jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1,
              "experts": {kk: v[None] for kk, v in full.items()}}
    x = (jax.random.normal(ks[4], (B, S, d), jnp.float32)).astype(dtype)
    return cfg, mcfg, params, x


@pytest.mark.parametrize("impl", ["naive", "comet", "coarse", "bcast"])
@pytest.mark.parametrize("activation", ["swiglu",
                                        pytest.param(
                                            "gelu",
                                            marks=pytest.mark.slow)])
def test_fused_backend_matches_xla(impl, activation):
    cfg, mcfg, params, x = _problem(activation)
    m = dataclasses.replace(mcfg, impl=impl)
    y_ref, aux_ref = moe_ffn(cfg, m, params, x, AxisCtx())
    y, aux = moe_ffn(cfg, _with_gemm(m, "pallas_fused"), params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_fused_backend_matches_xla_capacity_drop():
    cfg, mcfg, params, x = _problem(capacity_factor=0.5)
    m = dataclasses.replace(mcfg, impl="comet")
    y_ref, _ = moe_ffn(cfg, m, params, x, AxisCtx())
    y, _ = moe_ffn(cfg, _with_gemm(m, "pallas_fused"), params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_fused_backend_matches_xla_bf16():
    cfg, mcfg, params, x = _problem(dtype=jnp.bfloat16)
    m = dataclasses.replace(mcfg, impl="naive")
    y_ref, _ = moe_ffn(cfg, m, params, x, AxisCtx())
    y, _ = moe_ffn(cfg, _with_gemm(m, "pallas_fused"), params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# streaming per-block combine (fused_combine plan knob)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_col", [1, 2, 4])
@pytest.mark.parametrize("gemm", ["xla", "pallas_fused"])
def test_fused_combine_matches_monolithic(n_col, gemm):
    cfg, mcfg, params, x = _problem()
    m0 = dataclasses.replace(mcfg, impl="comet", n_col_blocks=n_col,
                             gemm_impl=gemm)
    m1 = dataclasses.replace(m0, fused_combine=True)
    y0, _ = moe_ffn(cfg, m0, params, x, AxisCtx(), n_col=n_col)
    y1, _ = moe_ffn(cfg, m1, params, x, AxisCtx(), n_col=n_col)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-6, atol=1e-7)


def test_transport_comet_blocks_concat_equals_full():
    """The streaming-block interface concatenates to exactly the full-width
    transport output (single-device fallback path)."""
    cfg, mcfg, params, x = _problem()
    d = cfg.d_model
    E = mcfg.num_experts
    Tn = x.shape[0] * x.shape[1]
    xt = x.reshape(Tn, d)
    idx, wts, _ = R.router(xt, params["router"], mcfg)
    C = R.capacity(Tn, mcfg.top_k, E, mcfg.capacity_factor)
    buf, info = R.build_dispatch(xt, idx, E, C)
    w_local = {k: v[0] for k, v in params["experts"].items()}
    send = buf.reshape(1, E, C, d)
    blocks, rot = T.transport_comet_blocks(AxisCtx(), send, w_local,
                                           cfg.activation, n_col_blocks=4)
    full, rot2 = T.transport_comet(AxisCtx(), send, w_local, cfg.activation,
                                   n_col_blocks=4)
    assert rot is None and rot2 is None
    assert len(blocks) == 4
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(blocks, axis=-1)), np.asarray(full))


# ---------------------------------------------------------------------------
# plan schema v2: search space, cost model, cache round-trip + v1 compat
# ---------------------------------------------------------------------------

def test_candidate_space_includes_fused_knobs():
    s = A.MoEShape(M=4096, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
    cands = list(A.candidate_plans(s))
    assert {p.gemm_impl for p in cands} == {"xla", "pallas_fused"}
    assert {p.fused_combine for p in cands if p.impl == "comet"} \
        == {False, True}
    assert all(not p.fused_combine for p in cands if p.impl != "comet")


def test_modeled_fused_terms_rank_sanely():
    """Fused hidden traffic beats unfused at n_col=1 (pure saving); the
    streaming combine is never modeled slower than staging."""
    s = A.MoEShape(M=16384, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    for hw in (A.TPU_V5E, A.H100_NVL):
        base = A.Plan("comet", 1, 1, "xla")
        fused = A.Plan("comet", 1, 1, "pallas_fused")
        assert A.modeled_plan_time(hw, s, fused) \
            < A.modeled_plan_time(hw, s, base)
        nc = A.Plan("comet", 1, 4, "xla")
        nc_fc = A.Plan("comet", 1, 4, "xla", fused_combine=True)
        assert A.modeled_plan_time(hw, s, nc_fc) \
            <= A.modeled_plan_time(hw, s, nc)


def test_hot_path_hbm_bytes_fused_strictly_lower():
    """Acceptance: modeled hot-path HBM bytes for the fused schedule
    (n_col=1 — early completion from the kernel's n_major traversal) are
    strictly below the unfused schedule at the paper's layer shapes, at
    every unfused N-decomposition."""
    from benchmarks.figures import PAPER_MODELS
    for m in PAPER_MODELS.values():
        s = A.MoEShape(M=8192, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                       ep=8, etp=1)
        fused = A.hot_path_hbm_bytes(
            s, A.Plan("comet", 1, 1, "pallas_fused", fused_combine=True))
        for n_col in (1, 2, 4):
            unfused = A.hot_path_hbm_bytes(
                s, A.Plan("comet", 1, n_col, "xla"))
            assert fused < unfused, (m, n_col)


def test_hot_path_hbm_bytes_fused_counts_weight_rereads():
    """Honesty check: at n_col > 1 the fused backend's per-column-block
    GEMM1 recompute re-streams the layer-0 weights — the model must charge
    for it (fused bytes grow with n_col)."""
    s = A.MoEShape(M=8192, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
    b1 = A.hot_path_hbm_bytes(s, A.Plan("comet", 1, 1, "pallas_fused"))
    b4 = A.hot_path_hbm_bytes(s, A.Plan("comet", 1, 4, "pallas_fused"))
    assert b4 > b1


def test_plan_cache_v3_roundtrip_with_fused_fields(tmp_path):
    """tune_plan over the grown search space persists pallas_fused +
    fused_combine + the v3 fwd+bwd ranking fields and reloads them
    identically (acceptance criterion)."""
    path = str(tmp_path / "plans.json")
    s = A.MoEShape(M=16384, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    cache = A.PlanCache(path)
    # restrict the space to the fused backend so the persisted entry
    # carries the full fused+v3 field set (the open-space winner is
    # backend-dependent: the fused backward pays the VMEM recompute)
    cands = [p for p in A.candidate_plans(s)
             if p.gemm_impl == "pallas_fused"]
    plan = A.tune_plan(s, A.TPU_V5E, cache, candidates=cands)
    assert plan.gemm_impl == "pallas_fused"
    assert plan.impl == "comet"                 # overlap still wins fwd+bwd
    assert plan.objective == "fwd_bwd" and plan.t_bwd_s > 0
    with open(path) as f:
        raw = json.load(f)
    assert raw["version"] == A.PLAN_CACHE_VERSION == 6
    entry = raw["plans"][A.PlanCache.key(s, A.TPU_V5E)]
    assert "fused_combine" in entry and "gemm_impl" in entry
    assert "t_bwd_s" in entry and "objective" in entry
    re = A.PlanCache(path)
    assert re.get(s, A.TPU_V5E) == plan


def test_plan_cache_v1_backward_compat(tmp_path):
    """A PR-1 (v1) cache file — no fused_combine field — loads cleanly with
    the new fields defaulted (objective records the fwd-only ranking)."""
    path = str(tmp_path / "v1.json")
    s = A.MoEShape(M=1024, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    key = A.PlanCache.key(s, A.TPU_V5E)
    with open(path, "w") as f:
        json.dump({"version": 1,
                   "plans": {key: {"impl": "comet", "ring_group": 2,
                                   "n_col_blocks": 4, "gemm_impl": "xla",
                                   "measured_s": 1e-3,
                                   "source": "measured"}}}, f)
    cache = A.PlanCache(path)
    plan = cache.get(s, A.TPU_V5E)
    assert plan is not None and plan.fused_combine is False
    assert plan.ring_group == 2 and plan.n_col_blocks == 4
    assert plan.objective == "fwd" and plan.t_bwd_s == 0.0
    cache.save()                                # rewrites at the current version
    with open(path) as f:
        assert json.load(f)["version"] == A.PLAN_CACHE_VERSION


def test_fused_plan_applies_in_moe_layer(tmp_path):
    """A cached pallas_fused + fused_combine plan resolves inside moe_ffn
    and produces the xla-backend result."""
    cfg, mcfg, params, x = _problem(d=128, f=64)
    path = str(tmp_path / "plans.json")
    toks = x.shape[0] * x.shape[1]
    s = A.plan_shape(mcfg, cfg.d_model, toks, 1, 1)
    cache = A.PlanCache(path)
    cache.put(s, A.TPU_V5E,
              A.Plan("comet", 1, 1, "pallas_fused", True,
                     measured_s=1e-6, source="measured"))
    m2 = dataclasses.replace(mcfg, impl="naive", plan_cache=path)
    y, _ = moe_ffn(cfg, m2, params, x, AxisCtx())
    y_ref, _ = moe_ffn(cfg, dataclasses.replace(mcfg, impl="comet"),
                       params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# coarse capacity reuse (multi-device; subprocess with 2 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_coarse_capacity_reuse_on_mesh():
    """coarse_chunks=1 takes the reuse-outer-dispatch arm (with its
    capacity-equivalence assertion) and must match naive exactly; chunks=2
    still matches within capacity semantics."""
    import subprocess
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_config
from repro.core.moe_layer import moe_ffn, pack_expert_weights
from repro.parallel.compat import make_mesh, use_mesh
from repro.parallel.mesh import AxisCtx

cfg = get_config("granite-moe-3b-a800m-smoke")
cfg = dataclasses.replace(cfg, d_model=32)
E, d, f = cfg.moe.num_experts, 32, 16
mcfg = dataclasses.replace(cfg.moe, d_expert=f, capacity_factor=float(E))
ks = jax.random.split(jax.random.PRNGKey(0), 5)
full = {"w_gate": jax.random.normal(ks[0], (E, d, f)) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f)) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d)) * 0.05}
params = {"router": jax.random.normal(ks[3], (d, E)) * 0.1,
          "experts": pack_expert_weights(full, 2, 1)}
x = jax.random.normal(ks[4], (2, 16, d))
mesh = make_mesh((1, 2), ("data", "model"))
ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model", ep=2, etp=1)
outs = {}
with use_mesh(mesh):
    for impl, chunks in (("naive", 2), ("coarse", 1), ("coarse", 2)):
        m = dataclasses.replace(mcfg, impl=impl, coarse_chunks=chunks)
        y, _ = moe_ffn(cfg, m, params, x, ctx)
        outs[(impl, chunks)] = np.asarray(y)
np.testing.assert_allclose(outs[("coarse", 1)], outs[("naive", 2)],
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(outs[("coarse", 2)], outs[("naive", 2)],
                           rtol=1e-5, atol=1e-6)
print("OK coarse")
"""
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "OK coarse" in r.stdout
