"""Paged block-table KV cache: allocator invariants, device-level paged
gather/scatter oracles, end-to-end paged-vs-contiguous bit-exactness
(mixed-length Poisson trace with slot reuse), batched chunk admission, and
the free-page admission gate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import attention as A
from repro.serving import (AllocatorError, BlockAllocator, RejectedRequest,
                           RejectReason, ServeEngine, pages_for)


# ---------------------------------------------------------------------------
# BlockAllocator (host-side) unit tests
# ---------------------------------------------------------------------------


def test_alloc_free_reuse_roundtrip():
    al = BlockAllocator(n_pages=9, page_size=4, max_blocks=8)
    assert al.free_pages == 8                       # page 0 reserved
    a = al.allocate(0, 13)                          # ceil(13/4) = 4 pages
    assert len(a) == 4 and al.free_pages == 4 and al.used_pages == 4
    assert 0 not in a                               # null page never leaves
    b = al.allocate(1, 16)
    assert len(b) == 4 and al.free_pages == 0
    assert not set(a) & set(b)                      # disjoint ownership
    al.free_slot(0)
    assert al.free_pages == 4 and al.used_pages == 4
    c = al.allocate(2, 9)                           # 3 pages, reuses a's
    assert set(c) <= set(a)
    al.free_slot(1)
    al.free_slot(2)
    assert al.free_pages == 8 and al.used_pages == 0


def test_fragmentation_after_interleaved_eos():
    """Pages freed by interleaved retirements are fungible: any free page
    serves any block-table entry, so a 'fragmented' free list still admits
    a request needing the combined budget."""
    al = BlockAllocator(n_pages=13, page_size=2, max_blocks=12)
    slots = {s: al.allocate(s, 4) for s in range(6)}   # 2 pages each = 12
    assert al.free_pages == 0
    for s in (1, 3, 5):                                # interleaved eos
        al.free_slot(s)
    assert al.free_pages == 6
    big = al.allocate(9, 12)                           # needs all 6 frees
    freed = set(slots[1]) | set(slots[3]) | set(slots[5])
    assert set(big) == freed                           # exactly the holes
    assert al.free_pages == 0


def test_over_budget_rejection():
    al = BlockAllocator(n_pages=5, page_size=4, max_blocks=2)
    assert al.can_admit(8) and not al.can_admit(9)     # max_blocks cap
    al.allocate(0, 8)                                  # 2 of 4 pages
    assert al.can_admit(8) and not al.can_admit(0)
    al.allocate(1, 8)
    assert not al.can_admit(1)                         # pool exhausted
    with pytest.raises(ValueError):
        al.allocate(2, 4)
    with pytest.raises(AllocatorError):
        al.allocate(0, 4)                              # slot already owns
    al.free_slot(1)
    assert al.can_admit(8)
    with pytest.raises(AllocatorError):
        al.free_slot(7)                                # unknown slot raises
    with pytest.raises(AllocatorError):
        al.free_slot(1)                                # double free raises
    al.check()                                         # nothing corrupted
    assert pages_for(0, 4) == 0 and pages_for(1, 4) == 1


def test_allocator_randomized_invariant():
    """Randomized alloc/free churn: after every mutation (including the
    rejected ones) ``used + free == total`` holds, no page is owned twice,
    and the null page never leaves the reserve."""
    rng = np.random.default_rng(42)
    al = BlockAllocator(n_pages=17, page_size=4, max_blocks=6)
    total = al.cfg.n_pages - 1
    live = set()
    for _ in range(500):
        if live and rng.random() < 0.45:
            s = int(rng.choice(sorted(live)))
            al.free_slot(s)
            live.discard(s)
        else:
            s = int(rng.integers(0, 8))
            toks = int(rng.integers(1, 30))
            if s in live:
                with pytest.raises(AllocatorError):
                    al.allocate(s, toks)
            elif al.can_admit(toks):
                pages = al.allocate(s, toks)
                assert 0 not in pages
                live.add(s)
            else:
                with pytest.raises(ValueError):
                    al.allocate(s, toks)
        al.check()
        assert al.used_pages + al.free_pages == total
    # snapshot/restore round-trips the exact ownership state
    state = al.snapshot_state()
    al2 = BlockAllocator(17, 4, 6)
    al2.restore_state(state)
    assert al2.free_pages == al.free_pages
    for s in live:
        assert al2.owned(s) == al.owned(s)


# ---------------------------------------------------------------------------
# Device-level paged gather / scatter oracles
# ---------------------------------------------------------------------------


def test_paged_decode_attention_matches_contiguous():
    """decode_attention through a shuffled block table == the contiguous
    oracle on the logically identical cache, per-row positions included."""
    B, S, Hkv, H, hd, page = 2, 32, 2, 4, 8, 8
    nb = S // page
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    pos = jnp.array([13, 29], jnp.int32)
    want = A.decode_attention(q, kc, vc, pos)

    # scatter rows into a pool under a shuffled table (page 0 = null)
    table = np.array([[3, 1, 6, 4], [2, 8, 5, 7]], np.int32)
    pool_k = np.zeros((9, page, Hkv, hd), np.float32)
    pool_v = np.zeros((9, page, Hkv, hd), np.float32)
    for b in range(B):
        for blk in range(nb):
            pool_k[table[b, blk]] = np.asarray(kc)[b, blk * page:(blk + 1) * page]
            pool_v[table[b, blk]] = np.asarray(vc)[b, blk * page:(blk + 1) * page]
    got = A.decode_attention(q, jnp.asarray(pool_k), jnp.asarray(pool_v),
                             pos, block_table=jnp.asarray(table))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_paged_update_cache_writes_through_table_and_null_page():
    B, Hkv, hd, page, P = 3, 2, 4, 4, 5
    table = jnp.asarray(np.array([[1, 2], [3, 4], [0, 0]], np.int32))
    k_new = jnp.arange(B * Hkv * hd, dtype=jnp.float32).reshape(B, 1, Hkv, hd)
    pool = jnp.zeros((P, page, Hkv, hd), jnp.float32)
    pos = jnp.array([5, 2, 3], jnp.int32)       # row2 is dead (null table)
    kp, vp = A.paged_update_cache(pool, pool, k_new, k_new, pos, table)
    kp = np.asarray(kp)
    np.testing.assert_array_equal(kp[2, 1], np.asarray(k_new)[0, 0])  # 5→pg2
    np.testing.assert_array_equal(kp[3, 2], np.asarray(k_new)[1, 0])  # 2→pg3
    # the dead row landed in the null page, nowhere else
    assert np.all(kp[1] == 0) and np.all(kp[4] == 0)
    np.testing.assert_array_equal(kp[0, 3], np.asarray(k_new)[2, 0])


def test_paged_chunk_update_masks_tokens_to_null_page():
    Hkv, hd, page, P, C = 1, 2, 4, 4, 4
    table = jnp.asarray(np.array([[1, 2], [3, 0]], np.int32))
    k = jnp.ones((2, C, Hkv, hd), jnp.float32)
    mask = jnp.asarray(np.array([[True] * 4, [True, True, False, False]]))
    pool = jnp.zeros((P, page, Hkv, hd), jnp.float32)
    kp, _ = A.paged_chunk_update(pool, pool, k, k, jnp.array([4, 0]),
                                 table, mask)
    kp = np.asarray(kp)
    assert np.all(kp[2] == 1)                   # row0 chunk at block 1
    assert np.all(kp[3, :2] == 1) and np.all(kp[3, 2:] == 0)  # row1 tail mask
    assert np.all(kp[1] == 0)                   # row0 block 0 untouched


# ---------------------------------------------------------------------------
# End-to-end: paged engine vs contiguous engine, bit-exact
# ---------------------------------------------------------------------------


def _trace_prompts(n, rng, lo=2, hi=14):
    return [rng.integers(1, 500, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def test_paged_parity_small():
    """Paged decode is bit-exact vs the contiguous per-slot cache on mixed
    lengths (same params, same schedule)."""
    cfg = get_config("qwen2-0.5b-smoke")
    ref = ServeEngine(cfg, max_seq=64, batch_size=2, seed=0, chunk=4)
    paged = ServeEngine(cfg, params=ref.params, max_seq=64, batch_size=2,
                        chunk=4, page_size=8)
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1]]
    r0 = ref.generate(prompts, max_new=4)
    r1 = paged.generate(prompts, max_new=4)
    np.testing.assert_array_equal(r0.tokens, r1.tokens)
    np.testing.assert_array_equal(r0.lengths, r1.lengths)
    assert paged.free_pages == paged.n_pages - 1   # all pages reclaimed


@pytest.mark.slow
def test_paged_parity_poisson_trace_with_slot_reuse():
    """The acceptance contract: a mixed-length Poisson-arrival trace pushed
    through MORE requests than slots (forced slot + page reuse), paged pool
    SMALLER than slots*max_seq, must be bit-exact vs the contiguous engine
    request by request."""
    cfg = get_config("qwen2-0.5b-smoke")
    rng = np.random.default_rng(7)
    prompts = _trace_prompts(6, rng)
    arrivals = np.cumsum(rng.exponential(2.0, size=len(prompts))).astype(int)

    def run(paged: bool, params=None):
        kw = dict(page_size=8, n_pages=7) if paged else {}   # 6 usable pages
        eng = ServeEngine(cfg, params=params, max_seq=64, batch_size=2,
                          seed=0, chunk=4, **kw)
        nxt = 0
        while nxt < len(prompts) or eng.pending:
            while nxt < len(prompts) and arrivals[nxt] <= eng.decode_steps:
                eng.submit(prompts[nxt], max_new=5)
                nxt += 1
            if not eng.pending:
                eng.submit(prompts[nxt], max_new=5)
                nxt += 1
            eng.step()
        return eng

    ref = run(False)
    got = run(True, params=ref.params)
    assert set(ref.finished) == set(got.finished)
    for rid in ref.finished:
        assert ref.finished[rid].tokens == got.finished[rid].tokens, rid
        assert ref.finished[rid].length == got.finished[rid].length, rid
    assert got.free_pages == got.n_pages - 1
    # the tight pool really was the constraint at some point: 6 usable
    # pages < 2 slots * 8 blocks of parity capacity
    assert got.n_pages - 1 < got.B * got.max_blocks


def test_page_budget_gates_admission():
    """A queued request whose page budget does not fit waits (FIFO) and is
    admitted once pages free up — never dropped, never reordered."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, chunk=4, seed=0,
                      page_size=4, n_pages=5)       # 4 usable pages = 16 toks
    ra = eng.submit([1, 2, 3, 4, 5, 6], max_new=6)  # 12 toks -> 3 pages
    rb = eng.submit([7, 8, 9], max_new=5)           # 8 toks -> 2 pages
    eng.step()
    assert eng.slot_req[0] is not None and eng.slot_req[0].rid == ra
    assert eng.queue and eng.queue[0].rid == rb     # waits on pages, not slots
    assert not eng.live[1]
    eng.run()
    assert eng.finished[ra].length >= 0 and eng.finished[rb].length >= 0
    assert eng.free_pages == 4


def test_submit_rejects_budget_beyond_pool_capacity():
    """A request that could NEVER fit the pool (pages needed > usable
    pages) must be rejected at submit() — otherwise the FIFO admission
    gate would stall on it, and everything behind it, forever."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, chunk=4, seed=0,
                      page_size=4, n_pages=5)       # 16-token pool capacity
    with pytest.raises(RejectedRequest) as ei:
        eng.submit(list(range(1, 21)), max_new=6)   # 26 toks <= max_seq,
    assert ei.value.reason == RejectReason.OVER_CAPACITY  # needs 7 > 4 pages
    assert ei.value.request.status.value == "rejected"
    assert not eng.queue
    eng.submit([1, 2, 3], max_new=5)                # 2 pages: fine
    eng.run()


def test_admission_padding_to_pow2_is_exact():
    """_admit_batch pads the stacked row count to the next power of two
    with identity parking rows on leftover free slots (bounding distinct
    compiles); a 3-of-4-slot admission (padded to 4) must match the
    sequential reference bit-exactly and leave the parking slot free."""
    cfg = get_config("qwen2-0.5b-smoke")
    seq = ServeEngine(cfg, max_seq=64, batch_size=4, seed=0, chunk=4,
                      admit_k=1)
    bat = ServeEngine(cfg, params=seq.params, max_seq=64, batch_size=4,
                      chunk=4)
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1], [9, 10, 11, 12]]   # 3 requests
    r0 = seq.generate(prompts, max_new=4)
    r1 = bat.generate(prompts, max_new=4)
    np.testing.assert_array_equal(r0.tokens, r1.tokens)
    assert bat.admit_rounds == 1 and bat.admissions == 3
    assert not bat.live.any()                       # parking slot untouched


def test_batched_admission_single_stacked_call_and_parity():
    """admit_k > 1 admits several queued requests in one stacked chunk
    call; results match sequential admission (admit_k=1) exactly and the
    admission count still reflects every request."""
    cfg = get_config("qwen2-0.5b-smoke")
    seq = ServeEngine(cfg, max_seq=64, batch_size=3, seed=0, chunk=4,
                      admit_k=1)
    bat = ServeEngine(cfg, params=seq.params, max_seq=64, batch_size=3,
                      chunk=4, admit_k=3)
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1], [9, 10, 11, 12]]
    r0 = seq.generate(prompts, max_new=4)
    r1 = bat.generate(prompts, max_new=4)
    np.testing.assert_array_equal(r0.tokens, r1.tokens)
    assert seq.admissions == bat.admissions == 3
    # sequential engine needed 3 separate admission rounds; batched one 1
    assert bat.prefill["chunk"] == seq.prefill["chunk"]


@pytest.mark.slow
def test_paged_ssm_and_moe_archs_exact():
    """Hybrid state layouts through the paged engine: mamba2 (dense per-slot
    SSM state only) and granite MoE under no-drop capacity are exact vs the
    contiguous engine on mixed lengths with slot reuse."""
    for arch, nodrop in [("mamba2-780m-smoke", False),
                         ("granite-moe-3b-a800m-smoke", True)]:
        cfg = get_config(arch)
        if nodrop:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        ref = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1, chunk=4)
        got = ServeEngine(cfg, params=ref.params, max_seq=32, batch_size=2,
                          chunk=4, page_size=8, n_pages=7)
        prompts = [[1, 2, 3, 4, 5, 6, 7], [5, 6], [8, 9, 10]]
        r0 = ref.generate(prompts, max_new=3)
        r1 = got.generate(prompts, max_new=3)
        np.testing.assert_array_equal(r0.tokens, r1.tokens, err_msg=arch)


@pytest.mark.slow
def test_paged_decode_on_mesh_matches_single_device():
    """Sharded paged decode: the kv-head-sharded page pools on an 8-device
    mesh must match the single-device paged reference (subprocess — the
    main process must keep one CPU device)."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from repro.configs.base import get_config
from repro.parallel.compat import make_mesh, use_mesh
from repro.parallel.mesh import AxisCtx
from repro.parallel.sharding import make_ctx
from repro.models import lm

cfg = get_config("qwen2-0.5b-smoke")      # Hkv=2 divides the 2-way model axis
mesh = make_mesh((4, 2), ("data", "model"))
ctx = make_ctx(cfg, mesh)
params = lm.init_params(cfg, jax.random.PRNGKey(0), ctx)
B, page, n_pages = 4, 8, 9
cache = lm.init_paged_cache(cfg, B, n_pages, page)
table = jnp.asarray(np.array([[1, 2], [3, 4], [5, 6], [7, 8]], np.int32))
tok = jnp.array([[3], [5], [7], [9]], jnp.int32)
pos = jnp.array([0, 1, 2, 3], jnp.int32)
ref, _ = lm.decode_step(cfg, params, cache, tok, pos, AxisCtx(),
                        block_tables=table)
with use_mesh(mesh):
    got, _ = jax.jit(lambda p, c, t: lm.decode_step(
        cfg, p, c, t, pos, ctx, block_tables=table))(params, cache, tok)
err = float(jnp.max(jnp.abs(got - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
assert err < 5e-5, err
print("OK", err)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
