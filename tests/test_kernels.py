"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)

# bf16 interpret runs are pure dtype variants of the fp32 coverage; the
# kernels-interpret CI job runs them (no -m filter) — keep tier-1 fast
BF16_SLOW = pytest.param(jnp.bfloat16, marks=pytest.mark.slow)


def _tol(dtype):
    # fp32 bound accommodates XLA-CPU reduction-order drift across host
    # device partitionings: with --xla_force_host_platform_device_count=8
    # (the CI setting) kernel-vs-oracle differences reach 6.1e-5 abs at
    # K=512, deterministically; kernel bugs produce O(1) errors.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


def assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# grouped_gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,M,K,N", [
    (1, 128, 128, 128),        # single expert, exact tiles
    (4, 128, 256, 128),        # multi-expert
    (3, 64, 96, 200),          # padding path on every dim
    (2, 256, 512, 384),        # multi-tile M/N/K
    (8, 16, 32, 48),           # tiny (all dims below block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
@pytest.mark.parametrize("order", ["expert_major", "n_major"])
def test_grouped_gemm(E, M, K, N, dtype, order):
    k1, k2 = jax.random.split(KEY)
    lhs = jax.random.normal(k1, (E, M, K), jnp.float32).astype(dtype)
    rhs = jax.random.normal(k2, (E, K, N), jnp.float32).astype(dtype)
    got = ops.grouped_gemm(lhs, rhs, order=order, interpret=True)
    want = ref.grouped_gemm_ref(lhs, rhs)
    assert got.shape == (E, M, N)
    assert got.dtype == dtype
    assert_close(got, want, dtype)


def test_grouped_gemm_orders_identical():
    """The comet n_major traversal changes tile COMPLETION ORDER, not values."""
    lhs = jax.random.normal(KEY, (3, 128, 128), jnp.float32)
    rhs = jax.random.normal(KEY, (3, 128, 256), jnp.float32)
    a = ops.grouped_gemm(lhs, rhs, order="expert_major", interpret=True)
    b = ops.grouped_gemm(lhs, rhs, order="n_major", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,hd", [
    (1, 4, 4, 128, 64),        # MHA
    (2, 8, 2, 256, 64),        # GQA 4:1
    (1, 4, 1, 128, 128),       # MQA
    (2, 2, 2, 384, 32),        # non-pow2 seq
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_flash_attention(B, Hq, Hkv, S, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert got.shape == q.shape
    assert_close(got, want, dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d", [(256, 128), (100, 896), (8, 64), (1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_rmsnorm(T, d, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (T, d), jnp.float32).astype(dtype)
    s = 1.0 + 0.1 * jax.random.normal(k2, (d,), jnp.float32)
    got = ops.rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    assert_close(got, want, dtype)


# ---------------------------------------------------------------------------
# topk_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,k,d", [(128, 2, 128), (64, 8, 256), (100, 4, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_topk_combine(T, k, d, dtype):
    k1, k2 = jax.random.split(KEY)
    rows = jax.random.normal(k1, (T, k, d), jnp.float32).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(k2, (T, k), jnp.float32), axis=-1)
    got = ops.topk_combine(rows, w, interpret=True)
    want = ref.topk_combine_ref(rows, w)
    assert_close(got, want, dtype)


# ---------------------------------------------------------------------------
# the kernels compose: grouped_gemm(n_major) + topk_combine == MoE layer-1
# ---------------------------------------------------------------------------

def test_layer1_composition():
    E, R, K, N, topk = 4, 64, 32, 128, 2
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (E, R, K), jnp.float32)
    w2 = jax.random.normal(ks[1], (E, K, N), jnp.float32)
    out = ops.grouped_gemm(h, w2, order="n_major", interpret=True)  # (E,R,N)
    rows = out.reshape(E * R, N)
    sel = jax.random.randint(ks[2], (R, topk), 0, E * R)
    w = jnp.full((R, topk), 0.5, jnp.float32)
    got = ops.topk_combine(rows[sel.reshape(-1)].reshape(R, topk, N), w,
                           interpret=True)
    want = (rows[sel.reshape(-1)].reshape(R, topk, N) * 0.5).sum(axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# ssd_forward (Mamba-2 state-space duality)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,nh,hd,ds,chunk", [
    (1, 64, 2, 16, 8, 16),       # multi-chunk
    (2, 128, 4, 32, 16, 64),     # bigger heads/state
    (1, 32, 1, 8, 4, 32),        # single chunk == whole sequence
    (2, 96, 2, 16, 8, 32),       # non-pow2 chunk count
])
@pytest.mark.parametrize("dtype", [jnp.float32, BF16_SLOW])
def test_ssd_forward(B, S, nh, hd, ds, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, ds), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, ds), jnp.float32)
    D = jnp.full((nh,), 0.5, jnp.float32)
    got = ops.ssd_forward(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 5e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 5e-4)


def test_ssd_forward_chunk_invariance():
    ks = jax.random.split(KEY, 5)
    B, S, nh, hd, ds = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, ds), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, ds), jnp.float32)
    D = jnp.zeros((nh,), jnp.float32)
    y32 = ops.ssd_forward(x, dt, A, Bm, Cm, D, chunk=32, interpret=True)
    y64 = ops.ssd_forward(x, dt, A, Bm, Cm, D, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64),
                               rtol=1e-4, atol=1e-4)
