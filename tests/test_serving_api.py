"""The typed serving API surface: RequestSpec validation (one value
object, validated in __post_init__), kwargs<->spec parity (both
submission doors reject identically, reason-for-reason), per-row
rejection in generate() (a malformed prompt no longer aborts the batch),
and EngineConfig (the one builder behind launch/serve.py and the serving
benchmarks — flag round-trip and built-engine equivalence)."""
import argparse

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serving import (EngineConfig, RejectedRequest, RejectReason,
                           RequestSpec, RequestStatus, ServeEngine)


@pytest.fixture(scope="module")
def params():
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=2, seed=0, chunk=4)
    return eng.params


def make_engine(params, **kw):
    cfg = get_config("qwen2-0.5b-smoke")
    kw.setdefault("max_seq", 64)
    kw.setdefault("batch_size", 2)
    kw.setdefault("chunk", 4)
    return ServeEngine(cfg, params=params, **kw)


# ---------------------------------------------------------------------------
# RequestSpec validation (malformed-in-isolation cases)
# ---------------------------------------------------------------------------


def test_spec_normalizes_and_freezes():
    s = RequestSpec(np.asarray([3, 1, 4], np.int32), max_new=5)
    assert s.prompt == (3, 1, 4)                  # tuple-ified, int-ified
    assert all(isinstance(t, int) for t in s.prompt)
    assert s.budget_tokens == 8
    with pytest.raises(AttributeError):           # frozen value object
        s.max_new = 9


MALFORMED = [
    (([],), {}, RejectReason.EMPTY_PROMPT),
    (("text",), {}, RejectReason.INVALID),        # str is NOT token ids
    ((b"bytes",), {}, RejectReason.INVALID),
    (([1, "x", 3],), {}, RejectReason.INVALID),
    (([1, 2],), {"max_new": 0}, RejectReason.INVALID),
    (([1, 2],), {"max_new": -3}, RejectReason.INVALID),
    (([1, 2],), {"eos_id": 1.5}, RejectReason.INVALID),
    (([1, 2],), {"deadline_s": 0}, RejectReason.INVALID),
    (([1, 2],), {"deadline_s": True}, RejectReason.INVALID),
    (([1, 2],), {"ttft_deadline_s": -1.0}, RejectReason.INVALID),
    (([1, 2],), {"route_hint": -1}, RejectReason.INVALID),
]


@pytest.mark.parametrize("args,kw,reason", MALFORMED)
def test_spec_rejects_malformed(args, kw, reason):
    with pytest.raises(RejectedRequest) as ei:
        RequestSpec(*args, **kw)
    assert ei.value.reason == reason


def test_spec_accepts_numpy_scalars():
    s = RequestSpec((np.int32(7), np.int64(9)), max_new=np.int32(3),
                    eos_id=np.int64(2))
    assert s.prompt == (7, 9) and s.budget_tokens == 5


# ---------------------------------------------------------------------------
# kwargs <-> spec parity: both doors, same verdicts
# ---------------------------------------------------------------------------


def test_submit_parity_malformed(params):
    """Every malformed case rejects with the SAME reason through the
    legacy kwargs door and the spec door, and both leave a terminal
    REJECTED record on the exception."""
    eng = make_engine(params)
    for args, kw, reason in MALFORMED:
        if "route_hint" in kw:                     # spec-only field: no
            continue                               # kwargs door to compare
        with pytest.raises(RejectedRequest) as via_kwargs:
            eng.submit(args[0], **kw)
        with pytest.raises(RejectedRequest) as via_spec:
            try:
                spec = RequestSpec(args[0], **kw)
            except RejectedRequest:
                raise                              # spec door = ctor raise
            eng.submit(spec)
        assert via_kwargs.value.reason == via_spec.value.reason == reason
        assert via_kwargs.value.request.status == RequestStatus.REJECTED
    assert not eng.queue and not eng.pending       # engine untouched


def test_submit_spec_fields_win(params):
    eng = make_engine(params)
    ref = eng.generate([[5, 6, 7]], max_new=3)
    spec = RequestSpec((5, 6, 7), max_new=3)
    got = eng.generate([spec], max_new=31)         # spec's max_new wins
    assert np.array_equal(ref.tokens, got.tokens[:, :3])
    assert int(got.lengths[0]) == 3


def test_submit_spec_eos_and_deadline(params):
    eng = make_engine(params, deadline_s=None)
    full = eng.generate([[5, 6, 7]], max_new=6)
    eos = int(full.tokens[0, 1])
    rid = eng.submit(RequestSpec((5, 6, 7), max_new=6, eos_id=eos,
                                 deadline_s=123.0))
    req = eng.queue[-1]
    assert req.rid == rid
    assert req.eos_id == eos and req.deadline_s == 123.0
    eng.run()
    assert len(eng.finished[rid].tokens) <= 2      # eos truncates


def test_rejected_rid_not_reused(params):
    eng = make_engine(params)
    with pytest.raises(RejectedRequest) as ei:
        eng.submit([], max_new=2)
    bad_rid = ei.value.request.rid
    good_rid = eng.submit([1, 2], max_new=2)
    assert good_rid != bad_rid                     # rids stay unique
    eng.run()


# ---------------------------------------------------------------------------
# generate(): per-row rejection instead of batch abort
# ---------------------------------------------------------------------------


def test_generate_survives_malformed_rows(params):
    eng = make_engine(params)
    ref = eng.generate([[5, 6, 7], [9, 10]], max_new=3)
    res = eng.generate([[5, 6, 7], [], [9, 10], "oops"], max_new=3)
    assert res.statuses == ["ok", "rejected", "ok", "rejected"]
    assert set(res.rejected) == {1, 3}
    assert res.rejected[1].reason == RejectReason.EMPTY_PROMPT
    assert res.rejected[3].reason == RejectReason.INVALID
    # rejected rows zeroed, accepted rows identical to the clean batch
    assert not res.tokens[1].any() and not res.tokens[3].any()
    assert int(res.lengths[1]) == 0 and int(res.lengths[3]) == 0
    assert np.array_equal(res.tokens[[0, 2]], ref.tokens)
    # prefill accounting counts only accepted prompts
    assert res.prefill_tokens == 5


def test_generate_all_rejected_is_not_an_error(params):
    eng = make_engine(params)
    res = eng.generate([[], ""], max_new=2)
    assert res.statuses == ["rejected", "rejected"]
    assert res.tokens.shape == (2, 2) and not res.tokens.any()
    assert eng.generate([[4, 2]], max_new=2).statuses == ["ok"]


# ---------------------------------------------------------------------------
# EngineConfig: validation, builder equivalence, CLI round-trip
# ---------------------------------------------------------------------------


def test_engineconfig_validates():
    with pytest.raises(ValueError):
        EngineConfig(max_seq=0)
    with pytest.raises(ValueError):
        EngineConfig(batch_size=0)
    with pytest.raises(ValueError):
        EngineConfig(shed_policy="yolo")
    with pytest.raises(ValueError):
        EngineConfig(disagg=True, page_size=0)    # handoff needs pages
    with pytest.raises(ValueError):
        EngineConfig(disagg=True, page_size=8, prefill_workers=0)


def test_engineconfig_build_equivalent_to_direct(params):
    cfg = get_config("qwen2-0.5b-smoke")
    direct = ServeEngine(cfg, params=params, max_seq=64, batch_size=2,
                         chunk=4, page_size=8, max_queue=3,
                         deadline_s=9.0)
    built = EngineConfig(max_seq=64, batch_size=2, chunk=4, page_size=8,
                         max_queue=3, deadline_s=9.0).build(cfg,
                                                            params=params)
    assert (built.max_seq, built.B, built.page_size, built.max_queue,
            built.deadline_s) == (direct.max_seq, direct.B,
                                  direct.page_size, direct.max_queue,
                                  direct.deadline_s)
    a = direct.generate([[3, 1, 4], [1, 5]], max_new=4)
    b = built.generate([[3, 1, 4], [1, 5]], max_new=4)
    assert np.array_equal(a.tokens, b.tokens)


def test_engineconfig_cli_round_trip():
    ap = argparse.ArgumentParser()
    EngineConfig.add_cli_args(ap)
    args = ap.parse_args([
        "--max-seq", "128", "--batch", "3", "--chunk", "16", "--seed", "5",
        "--page-size", "8", "--pages", "33", "--admit-k", "2",
        "--max-queue", "7", "--shed", "deadline", "--deadline", "4.5",
        "--snapshot-every", "3", "--chaos", "0.25", "--chaos-seed", "9",
        "--disagg", "--prefill-workers", "2", "--decode-workers", "3",
        "--prefill-slots", "1", "--decode-slots", "2"])
    ec = EngineConfig.from_cli_args(args, chaos_horizon=77)
    assert (ec.max_seq, ec.batch_size, ec.chunk, ec.seed) == (128, 3, 16, 5)
    assert (ec.page_size, ec.n_pages, ec.admit_k) == (8, 33, 2)
    assert (ec.max_queue, ec.shed_policy, ec.deadline_s) == (7, "deadline",
                                                             4.5)
    assert (ec.chaos_rate, ec.chaos_seed, ec.chaos_horizon) == (0.25, 9, 77)
    assert ec.disagg and (ec.prefill_workers, ec.decode_workers) == (2, 3)
    assert (ec.prefill_slots, ec.decode_slots) == (1, 2)
    assert ec.worker_targets() == (("prefill", 0), ("prefill", 1),
                                   ("decode", 0), ("decode", 1),
                                   ("decode", 2))


def test_engineconfig_defaults_round_trip():
    """An empty CLI line reproduces the dataclass defaults (modulo the
    two launcher-historic overrides) — flags and config can't drift."""
    ap = argparse.ArgumentParser()
    EngineConfig.add_cli_args(ap)
    ec = EngineConfig.from_cli_args(ap.parse_args([]))
    assert ec == EngineConfig(max_seq=128, chunk=16)


def test_engineconfig_make_faults():
    assert EngineConfig().make_faults() is None   # chaos off
    ec = EngineConfig(chaos_rate=0.5, chaos_seed=3, chaos_horizon=64)
    inj = ec.make_faults()
    assert inj is not None and inj.plan.seed == 3
    dis = EngineConfig(chaos_rate=0.5, chaos_horizon=64, page_size=8,
                       disagg=True, prefill_workers=1, decode_workers=1)
    plan = dis.make_faults(role=("decode", 0)).plan
    assert plan.crash_workers and not plan.crash_steps  # crashes target
    assert all(t in dis.worker_targets()                # single workers
               for t in plan.crash_workers.values())
