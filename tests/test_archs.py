"""Per-architecture smoke tests: every assigned arch (reduced config) runs a
forward + one train step on CPU with finite outputs and correct shapes, and
the decode path is consistent with prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ASSIGNED_ARCHS, PAPER_ARCHS, ShapeConfig,
                                get_config)
from repro.data.synthetic import SyntheticLM
from repro.launch.specs import train_batch_specs
from repro.models import lm
from repro.parallel.mesh import AxisCtx

# jamba's per-family coverage is dominated by the SSD Pallas kernel running
# in interpret mode (Python-loop execution on CPU) — slow-marked to keep
# the tier-1 fast lane short; the kernels-interpret CI job runs it.
_SSD_HEAVY = ("jamba-v0.1-52b-smoke",)


def _arch_param(name):
    return pytest.param(name, marks=pytest.mark.slow) \
        if name in _SSD_HEAVY else name


ALL_SMOKE = [_arch_param(a + "-smoke")
             for a in ASSIGNED_ARCHS + PAPER_ARCHS]
CTX = AxisCtx()
SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


def _params_and_batch(name):
    cfg = get_config(name)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, CTX)
    structs, _ = train_batch_specs(cfg, SHAPE, accum=1)
    data = SyntheticLM(cfg, structs, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    return cfg, params, batch


@pytest.mark.parametrize("name", ALL_SMOKE)
def test_forward_shapes_and_finite(name):
    cfg, params, batch = _params_and_batch(name)
    h, aux, _ = jax.jit(lambda p, b: lm.forward(cfg, p, b, CTX))(params, batch)
    S = batch["tokens"].shape[-1] if "tokens" in batch else \
        batch["labels"].shape[-1]
    assert h.shape == (2, S, cfg.d_model), (name, h.shape)
    assert np.isfinite(np.asarray(h, np.float32)).all(), name
    assert np.isfinite(float(aux)), name
    if cfg.moe is not None:
        assert float(aux) > 0, f"{name}: MoE aux loss should be positive"
    else:
        assert float(aux) == 0.0, name


@pytest.mark.parametrize("name", ALL_SMOKE)
def test_train_step_improves(name):
    """Two SGD-ish steps on the same batch must reduce the loss."""
    cfg, params, batch = _params_and_batch(name)

    def loss(p):
        l, _ = lm.loss_fn(cfg, p, batch, CTX)
        return l

    vg = jax.jit(jax.value_and_grad(loss))
    l0, g = vg(params)
    # jamba's exp() ssm dynamics NaN at lr=0.5; 0.15 converges for all
    lr = 0.15
    params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg.astype(p.dtype),
                                    params, g)
    l1, g = vg(params)
    params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg.astype(p.dtype),
                                    params, g)
    l2, _ = vg(params)
    assert np.isfinite([float(l0), float(l1), float(l2)]).all(), name
    assert float(l2) < float(l0), (name, float(l0), float(l2))


@pytest.mark.parametrize("name", [_arch_param(n) for n in (
    "qwen2-0.5b-smoke", "granite-moe-3b-a800m-smoke", "mamba2-780m-smoke",
    "jamba-v0.1-52b-smoke", "whisper-small-smoke")])
def test_prefill_decode_consistency(name):
    """prefill(S tokens) then decode token S must match the full forward's
    logits at position S — the serving-correctness contract per family.
    (No-drop MoE capacity: with drops, prefill may drop a token that the
    single-token decode necessarily keeps — not a bug, a capacity semantic.)"""
    cfg = get_config(name)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key, CTX)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    enc_len = 0
    if cfg.n_enc_layers:
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, 64, cfg.d_model), jnp.float32) * 0.02
        batch["frames"] = frames
        enc_len = 64

    # full forward over S+1 tokens -> logits at position S
    batch_full = dict(batch, tokens=toks)
    h, _, _ = lm.forward(cfg, params, batch_full, CTX)
    from repro.models.common import logits_for
    want = logits_for(h, lm.output_head(cfg, params))[:, S]

    # prefill S, then one decode step
    logits_p, cache_p = lm.prefill(cfg, params, batch, CTX)
    cache = lm.init_cache(cfg, B, S + 8, CTX, enc_len=enc_len)
    cache = _copy_prefill_into(cfg, cache, cache_p, S)
    got, _ = lm.decode_step(cfg, params, cache, toks[:, S:S + 1],
                            jnp.int32(S), CTX)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


def _copy_prefill_into(cfg, cache, cache_p, S):
    """Insert prefill cache entries (stacked (n_periods, B, S, ...) from the
    scan) into the fixed-size decode cache."""
    out = []
    for entry, pre in zip(cache, cache_p):
        e = {}
        for k in entry:
            if k in ("k", "v", "xk", "xv"):
                buf = entry[k]
                src = pre[k]
                if k in ("k", "v"):
                    e[k] = buf.at[:, :, :S].set(src.astype(buf.dtype))
                else:
                    e[k] = buf.at[:, :, :src.shape[2]].set(
                        src.astype(buf.dtype))
            elif k == "conv":
                e[k] = pre[k].astype(entry[k].dtype)
            else:
                e[k] = pre[k]
        out.append(e)
    return tuple(out)


def test_long_context_decode_subquadratic_archs():
    """ssm/hybrid archs decode against a large cache without materializing
    O(S^2); smoke-scale stand-in for the long_500k cell."""
    for name in ["mamba2-780m-smoke", "jamba-v0.1-52b-smoke"]:
        cfg = get_config(name)
        B, S = 1, 512
        params = lm.init_params(cfg, jax.random.PRNGKey(0), CTX)
        cache = lm.init_cache(cfg, B, S, CTX)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, new_cache = jax.jit(
            lambda p, c, t: lm.decode_step(cfg, p, c, t, jnp.int32(S // 2),
                                           CTX))(params, cache, tok)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), name


def test_vlm_uses_stub_embeds():
    cfg = get_config("llava-next-34b-smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0), CTX)
    B, S = 2, 16
    batch = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.01,
             "labels": jnp.zeros((B, S), jnp.int32)}
    h, _, _ = lm.forward(cfg, params, batch, CTX)
    assert h.shape == (B, S, cfg.d_model)
