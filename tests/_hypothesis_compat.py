"""Optional-``hypothesis`` shim: property tests skip cleanly when the
package is absent, while every plain test in the same module stays
collectable and runs.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from tests._hypothesis_compat import given, settings, st

With hypothesis installed this re-exports the real objects. Without it,
``@given(...)`` replaces the test with a zero-argument function that calls
``pytest.skip`` — zero-argument so pytest never tries to resolve the
property's value parameters as fixtures.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on the CPU CI image
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning an inert placeholder (only ever passed to the
        stub ``given`` below)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            skipper.__module__ = getattr(fn, "__module__", __name__)
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
