"""Property-based tests (hypothesis) for the routing / shared-tensor substrate
— the invariants every transport implementation relies on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import routing as R

SET = settings(max_examples=30, deadline=None)


def mcfg(E, k, **kw):
    return MoEConfig(num_experts=E, top_k=k, d_expert=16, **kw)


# ---------------------------------------------------------------------------
# capacity
# ---------------------------------------------------------------------------

@given(T=st.integers(1, 4096), k=st.integers(1, 8), E=st.integers(1, 128),
       f=st.floats(1.0, 4.0))
@SET
def test_capacity_properties(T, k, E, f):
    C = R.capacity(T, k, E, f)
    assert C % 4 == 0 and C >= 4
    assert C >= min(T * k / E, 1)          # at least the balanced load
    # capacity covers the balanced load times the factor
    assert C * E >= T * k * min(f, 1.0) or C >= 4


@given(T=st.integers(1, 512), k=st.integers(1, 4), E=st.integers(1, 32))
@SET
def test_capacity_full_factor_never_drops(T, k, E):
    """factor == E ⇒ C*E ≥ T*k, so no token can ever be dropped."""
    C = R.capacity(T, k, E, float(E))
    assert C * E >= T * k


# ---------------------------------------------------------------------------
# dispatch / combine inverse property
# ---------------------------------------------------------------------------

@given(T=st.integers(2, 64), E=st.integers(2, 16), k=st.integers(1, 4),
       d=st.sampled_from([8, 16]), seed=st.integers(0, 2**31 - 1))
@SET
def test_dispatch_combine_roundtrip(T, E, k, d, seed):
    """With no-drop capacity, combine(dispatch(x)) with uniform weights must
    reproduce sum_k x for every token (expert fn = identity)."""
    k = min(k, E)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (T, d), jnp.float32)
    # distinct experts per token (top-k semantics)
    scores = jax.random.normal(k2, (T, E), jnp.float32)
    _, idx = jax.lax.top_k(scores, k)
    C = R.capacity(T, k, E, float(E))
    buf, info = R.build_dispatch(x, idx, E, C)
    assert buf.shape == (E, C, d)
    w = jnp.ones((T, k), jnp.float32)
    y = R.combine(buf.reshape(E * C, d), info, w, E_loc=E, C=C, rot=None, ep=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * k,
                               rtol=1e-5, atol=1e-5)


@given(T=st.integers(2, 64), E=st.integers(2, 16), seed=st.integers(0, 999))
@SET
def test_dispatch_slots_unique_and_ordered(T, E, seed):
    """Every kept (token, choice) lands in a unique slot; slots within an
    expert are filled in arrival order (the paper's sort-by-source order)."""
    k = 2 if E >= 2 else 1
    key = jax.random.PRNGKey(seed)
    scores = jax.random.normal(key, (T, E), jnp.float32)
    _, idx = jax.lax.top_k(scores, k)
    C = R.capacity(T, k, E, float(E))
    _, info = R.build_dispatch(jnp.zeros((T, 1), jnp.float32), idx, E, C)
    flat_e = np.asarray(info.flat_e)
    pos = np.asarray(info.pos)
    keep = np.asarray(info.keep)
    assert keep.all()                       # no-drop capacity
    slots = flat_e * C + pos
    assert len(np.unique(slots)) == len(slots)
    for e in range(E):
        pe = pos[flat_e == e]
        assert sorted(pe.tolist()) == list(range(len(pe)))


@given(T=st.integers(4, 64), E=st.integers(2, 8), seed=st.integers(0, 999),
       factor=st.floats(0.1, 1.0))
@SET
def test_capacity_drop_is_fifo(T, E, seed, factor):
    """Dropped tokens are exactly those beyond capacity, in arrival order."""
    k = 1
    key = jax.random.PRNGKey(seed)
    scores = jax.random.normal(key, (T, E), jnp.float32)
    _, idx = jax.lax.top_k(scores, k)
    C = R.capacity(T, k, E, factor)
    _, info = R.build_dispatch(jnp.zeros((T, 1), jnp.float32), idx, E, C)
    keep = np.asarray(info.keep)
    pos = np.asarray(info.pos)
    np.testing.assert_array_equal(keep, pos < C)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_topk_normalized():
    m = mcfg(8, 2)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 16), jnp.float32)
    w = jax.random.normal(key, (16, 8), jnp.float32)
    idx, wts, aux = R.router(x, w, m)
    assert idx.shape == (32, 2) and wts.shape == (32, 2)
    np.testing.assert_allclose(np.asarray(wts.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(idx[:, 0]) != np.asarray(idx[:, 1])).all()
    assert np.isfinite(float(aux))


def test_router_aux_loss_balanced_lower():
    """Uniform routing must give a lower aux loss than collapsed routing."""
    m = mcfg(4, 1, aux_loss_coef=1.0)
    T, d = 256, 8
    x = jnp.eye(4, d).repeat(T // 4, axis=0)            # 4 distinct inputs
    w_bal = jnp.eye(d, 4) * 10                          # each input -> own expert
    w_col = jnp.zeros((d, 4)).at[:, 0].set(10)          # all -> expert 0
    _, _, aux_bal = R.router(x, w_bal, m)
    _, _, aux_col = R.router(x, w_col, m)
    assert float(aux_bal) < float(aux_col)
    assert abs(float(aux_bal) - 1.0) < 0.05             # E * (1/E*1/E) * E = 1


def test_moe_flops_formula():
    assert R.moe_flops(128, 2, 64, 256, glu=True) == 2 * 128 * 2 * 3 * 64 * 256
    assert R.moe_flops(128, 2, 64, 256, glu=False) == 2 * 128 * 2 * 2 * 64 * 256
