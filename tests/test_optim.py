"""AdamW vs a hand-rolled reference; schedule; int8 compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.optim import compression as C


def test_adamw_matches_manual_reference():
    opt = AdamW(lr=lambda s: 1e-2, b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st_ = opt.init(p)
    new_p, new_st, stats = opt.update(g, st_, p)

    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.array([1.0, -2.0, 3.0]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_st["m"]["w"]), m, rtol=1e-6)
    assert int(new_st["count"]) == 1


def test_adamw_weight_decay_decoupled():
    opt = AdamW(lr=lambda s: 1e-1, weight_decay=0.5, clip_norm=1e9)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    new_p, _, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(new_p["w"]), [2.0 - 0.1 * 0.5 * 2.0])


def test_clipping_caps_update():
    opt = AdamW(lr=lambda s: 1.0, clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.array([30.0, 40.0, 0.0])}        # norm 50
    _, st_, stats = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(float(stats["grad_norm"]), 50.0, rtol=1e-5)
    # effective grad after scale has norm 1
    np.testing.assert_allclose(float(global_norm(st_["m"])) / 0.1, 1.0,
                               rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(5)), 0.5)
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(lr(110)), 0.1, rtol=1e-5)
    assert float(lr(60)) < float(lr(20))


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100), scale=st.floats(1e-4, 1e3))
@settings(max_examples=25, deadline=None)
def test_quantize_bounded_error(seed, scale):
    x = np.random.default_rng(seed).standard_normal(64).astype(np.float32) * scale
    q, s = C.quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(C.dequantize_int8(q, s)) - x)
    assert err.max() <= float(s) * 0.5 + 1e-6        # within half a quantum


def test_error_feedback_accumulates_to_truth():
    """Summing dequantized grads + final residual == summing true grads —
    the error-feedback telescoping identity that preserves convergence."""
    rng = np.random.default_rng(0)
    resid = jnp.zeros((32,), jnp.float32)
    total_sent = np.zeros((32,), np.float32)
    total_true = np.zeros((32,), np.float32)
    for step in range(20):
        g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        q, s, resid = C.compress_with_feedback(g, resid)
        total_sent += np.asarray(C.dequantize_int8(q, s))
        total_true += np.asarray(g)
    np.testing.assert_allclose(total_sent + np.asarray(resid), total_true,
                               rtol=1e-4, atol=1e-4)


def test_compress_pytree_roundtrip_structure():
    g = {"a": jnp.ones((4,)), "b": {"c": jnp.full((2, 2), -3.0)}}
    r = C.init_residuals(g)
    packed, new_r = C.compress_pytree(g, r)
    out = C.decompress_pytree(packed)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones(4), rtol=1e-2)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]),
                               np.full((2, 2), -3.0), rtol=1e-2)
    assert jax.tree_util.tree_structure(new_r) == \
        jax.tree_util.tree_structure(g)
