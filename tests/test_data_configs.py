"""Data pipeline determinism + config registry / param-count sanity."""
import numpy as np
import pytest

from repro.configs.base import (ASSIGNED_ARCHS, LM_SHAPES, PAPER_ARCHS,
                                ShapeConfig, get_config, list_archs,
                                shape_applicable)
from repro.data.synthetic import Prefetcher, SyntheticLM
from repro.launch.specs import train_batch_specs

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def pipeline(arch="qwen2-0.5b-smoke", seed=0, pidx=0):
    cfg = get_config(arch)
    structs, _ = train_batch_specs(cfg, SHAPE, accum=1)
    return SyntheticLM(cfg, structs, seed=seed, process_index=pidx)


def test_batches_deterministic_per_step():
    a, b = pipeline(), pipeline()
    for step in (0, 3, 17):
        ba, bb = a.batch_at(step), b.batch_at(step)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])


def test_batches_differ_across_steps_seeds_processes():
    p = pipeline()
    assert not np.array_equal(p.batch_at(0)["tokens"], p.batch_at(1)["tokens"])
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              pipeline(seed=1).batch_at(0)["tokens"])
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              pipeline(pidx=1).batch_at(0)["tokens"])


def test_labels_are_shifted_tokens():
    b = pipeline().batch_at(0)
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])


def test_tokens_in_vocab_range():
    cfg = get_config("qwen2-0.5b-smoke")
    b = pipeline().batch_at(0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size


def test_prefetcher_yields_in_order():
    p = Prefetcher(pipeline(), start_step=5, depth=2)
    try:
        s0, b0 = p.next()
        s1, b1 = p.next()
        assert (s0, s1) == (5, 6)
        np.testing.assert_array_equal(b0["tokens"],
                                      pipeline().batch_at(5)["tokens"])
    finally:
        p.close()


def test_whisper_batch_has_frames():
    cfg = get_config("whisper-small-smoke")
    structs, _ = train_batch_specs(cfg, SHAPE, accum=1)
    b = SyntheticLM(cfg, structs).batch_at(0)
    assert "frames" in b and "tokens" in b and "labels" in b
    assert b["frames"].shape[-1] == cfg.d_model


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

def test_registry_contains_assigned_and_paper_archs():
    names = list_archs()
    for a in ASSIGNED_ARCHS + PAPER_ARCHS:
        assert a in names, a
    smoke = list_archs(include_smoke=True)
    for a in ASSIGNED_ARCHS + PAPER_ARCHS:
        assert a + "-smoke" in smoke, a


@pytest.mark.parametrize("name,low,high", [
    ("qwen2-0.5b", 0.4e9, 0.65e9),
    ("qwen1.5-4b", 3.0e9, 4.5e9),
    ("phi3-medium-14b", 12e9, 15e9),
    ("mixtral-8x7b", 44e9, 49e9),
    ("nemotron-4-340b", 300e9, 380e9),
    ("mamba2-780m", 0.6e9, 0.9e9),
    ("jamba-v0.1-52b", 45e9, 58e9),
    ("llava-next-34b", 30e9, 38e9),
    ("granite-moe-3b-a800m", 2.5e9, 3.9e9),
    ("qwen3-moe-235b-a22b", 200e9, 260e9),
])
def test_param_counts_match_public_sizes(name, low, high):
    n = get_config(name).param_count()
    assert low <= n <= high, (name, n / 1e9)


@pytest.mark.parametrize("name,low,high", [
    ("mixtral-8x7b", 11e9, 15e9),          # 12.9B active per token
    ("qwen3-moe-235b-a22b", 18e9, 26e9),   # ~22B active
    ("granite-moe-3b-a800m", 0.6e9, 1.2e9),
])
def test_active_param_counts(name, low, high):
    n = get_config(name).active_param_count()
    assert low <= n <= high, (name, n / 1e9)


def test_shape_applicability_long500k():
    ok, _ = shape_applicable(get_config("mamba2-780m"), LM_SHAPES["long_500k"])
    assert ok
    ok, _ = shape_applicable(get_config("jamba-v0.1-52b"),
                             LM_SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(get_config("phi3-medium-14b"),
                               LM_SHAPES["long_500k"])
    assert not ok and "full-attention" in why


def test_smoke_configs_are_reduced_same_family():
    for a in ASSIGNED_ARCHS:
        full, smoke = get_config(a), get_config(a + "-smoke")
        assert smoke.family == full.family
        assert smoke.d_model <= 128
        assert smoke.n_layers <= max(2 * 8, 2)
        assert (smoke.moe is None) == (full.moe is None)
        assert (smoke.ssm is None) == (full.ssm is None)
