"""PR 3: fine-grained backward-pass overlap.

Gradient equivalence of the custom-VJP comet ring (±fused_combine, every
GroupGEMM backend, GLU/non-GLU, capacity drops) against the naive/XLA-
autodiff reference; the explicit dgrad/wgrad kernel entry points vs the jnp
oracle; the shared knob-legalization helpers; the plan-key token-count fix;
the backward cost model + plan cache v3 (v2 loads compatibly); and the
multi-device ring backward (subprocess, slow)."""
import dataclasses
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import adaptive as A
from repro.core import routing as R
from repro.core import transport as T
from repro.core.moe_layer import local_token_count, moe_ffn
from repro.kernels import ops, ref
from repro.parallel.mesh import AxisCtx

KEY = jax.random.PRNGKey(0)


def _problem(activation="swiglu", E=8, d=32, f=16, B=2, S=16, k=2,
             capacity_factor=None, seed=0):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, d_model=d, activation=activation)
    mcfg = dataclasses.replace(
        cfg.moe, num_experts=E, d_expert=f, top_k=k,
        capacity_factor=capacity_factor if capacity_factor else float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    full = {"w_up": jax.random.normal(ks[1], (E, d, f)) * 0.1,
            "w_down": jax.random.normal(ks[2], (E, f, d)) * 0.1}
    if activation in ("swiglu", "geglu"):
        full["w_gate"] = jax.random.normal(ks[0], (E, d, f)) * 0.1
    params = {"router": jax.random.normal(ks[3], (d, E)) * 0.1,
              "experts": {kk: v[None] for kk, v in full.items()}}
    x = jax.random.normal(ks[4], (B, S, d), jnp.float32)
    return cfg, mcfg, params, x


def _grads(cfg, mcfg, params, x):
    def loss(p):
        y, aux = moe_ffn(cfg, mcfg, p, x, AxisCtx())
        return jnp.sum(y ** 2) + aux
    return jax.grad(loss)(params)


def _assert_tree_close(got, want, rtol=1e-4, atol=1e-5, msg=""):
    for k in want["experts"]:
        np.testing.assert_allclose(
            np.asarray(got["experts"][k]), np.asarray(want["experts"][k]),
            rtol=rtol, atol=atol, err_msg=f"experts[{k}] {msg}")
    np.testing.assert_allclose(np.asarray(got["router"]),
                               np.asarray(want["router"]),
                               rtol=rtol, atol=atol, err_msg=f"router {msg}")


# ---------------------------------------------------------------------------
# gradient-equivalence grid: comet custom VJP vs naive/XLA-autodiff
# ---------------------------------------------------------------------------

# the full {backend x activation x combine} grid; the redundant diagonal is
# slow-marked (it runs in the backward-kernels CI job) to keep tier-1 short
_GRID = [
    ("xla", "swiglu", False),
    ("xla", "swiglu", True),
    ("xla", "gelu", False),
    ("pallas_fused", "swiglu", True),
    ("pallas_fused", "gelu", False),
    pytest.param("xla", "gelu", True, marks=pytest.mark.slow),
    pytest.param("pallas_fused", "swiglu", False, marks=pytest.mark.slow),
    pytest.param("pallas_fused", "gelu", True, marks=pytest.mark.slow),
    pytest.param("pallas", "swiglu", True, marks=pytest.mark.slow),
    pytest.param("pallas", "gelu", False, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("gemm,activation,fused_combine", _GRID)
def test_comet_grads_match_autodiff_reference(gemm, activation,
                                              fused_combine):
    """The acceptance grid: grads of the comet custom VJP across
    {gemm backend × ±fused_combine × GLU/non-GLU} match the naive
    XLA-autodiff reference within fp32 tolerance."""
    cfg, mcfg, params, x = _problem(activation)
    g_ref = _grads(cfg, dataclasses.replace(mcfg, impl="naive"), params, x)
    m = dataclasses.replace(mcfg, impl="comet", n_col_blocks=2,
                            fused_combine=fused_combine, gemm_impl=gemm)
    g = _grads(cfg, m, params, x)
    _assert_tree_close(g, g_ref, rtol=1e-4, atol=1e-4,
                       msg=f"{gemm} fc={fused_combine} {activation}")


def test_comet_grads_under_capacity_drops():
    """Dropped (token, choice) pairs must contribute zero gradient through
    the custom VJP exactly as through autodiff."""
    cfg, mcfg, params, x = _problem(capacity_factor=0.5)
    g_ref = _grads(cfg, dataclasses.replace(mcfg, impl="naive"), params, x)
    for gemm in ("xla", "pallas_fused"):
        m = dataclasses.replace(mcfg, impl="comet", n_col_blocks=2,
                                fused_combine=True, gemm_impl=gemm)
        g = _grads(cfg, m, params, x)
        _assert_tree_close(g, g_ref, rtol=1e-4, atol=1e-4, msg=gemm)


def test_transport_custom_vjp_equals_autodiff():
    """Directly at the transport: the decomposed backward (custom_vjp=True)
    and XLA autodiff of the same forward (custom_vjp=False) produce
    identical (send, w) cotangents."""
    E, C, d, f = 4, 8, 24, 16
    ks = jax.random.split(KEY, 5)
    send = jax.random.normal(ks[0], (1, E, C, d), jnp.float32)
    w = {"w_gate": jax.random.normal(ks[1], (E, d, f)) * 0.1,
         "w_up": jax.random.normal(ks[2], (E, d, f)) * 0.1,
         "w_down": jax.random.normal(ks[3], (E, f, d)) * 0.1}
    cot = jax.random.normal(ks[4], (1, E, C, d), jnp.float32)

    def loss(send_, w_, custom):
        blocks, _ = T.transport_comet_blocks(AxisCtx(), send_, w_, "swiglu",
                                             n_col_blocks=3, custom_vjp=custom)
        out = jnp.concatenate(blocks, axis=-1)
        return jnp.vdot(out, cot)

    for gemm in ("xla", "pallas_fused"):
        g1 = jax.grad(lambda s_, w_: loss(s_, w_, True), argnums=(0, 1))
        g0 = jax.grad(lambda s_, w_: loss(s_, w_, False), argnums=(0, 1))
        with_ = g1(send, w)
        without = g0(send, w)
        np.testing.assert_allclose(np.asarray(with_[0]),
                                   np.asarray(without[0]),
                                   rtol=1e-4, atol=1e-5)
        for k in w:
            np.testing.assert_allclose(np.asarray(with_[1][k]),
                                       np.asarray(without[1][k]),
                                       rtol=1e-4, atol=1e-5, err_msg=k)


def test_train_step_grads_flow_with_plan(tmp_path):
    """A tuned plan cache threaded through the trainer config reaches the
    jitted train step: the loss is finite and expert grads are non-zero
    under the plan's comet schedule."""
    path = str(tmp_path / "plans.json")
    cfg, mcfg, params, x = _problem(d=32, f=16)
    s = A.plan_shape(mcfg, cfg.d_model, x.shape[0] * x.shape[1], 1, 1)
    cache = A.PlanCache(path)
    cache.put(s, A.TPU_V5E,
              A.Plan("comet", 1, 2, "xla", True, measured_s=1e-6,
                     source="measured"))
    m2 = dataclasses.replace(mcfg, impl="naive", plan_cache=path)

    def loss(p):
        y, aux = moe_ffn(cfg, m2, p, x, AxisCtx())
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    g_ref = _grads(cfg, dataclasses.replace(mcfg, impl="comet"), params, x)
    _assert_tree_close(g, g_ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# dgrad / wgrad kernel entry points vs the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("activation", ["swiglu", "gelu",
                                        pytest.param(
                                            "geglu",
                                            marks=pytest.mark.slow),
                                        pytest.param(
                                            "relu2",
                                            marks=pytest.mark.slow)])
def test_dgrad_wgrad_kernels_match_oracle(activation):
    E, Rr, d, f = 3, 21, 17, 19
    ks = jax.random.split(KEY, 4)
    rows = jax.random.normal(ks[0], (E, Rr, d), jnp.float32)
    w = {"w_up": jax.random.normal(ks[1], (E, d, f)) * 0.1,
         "w_down": jax.random.normal(ks[2], (E, f, d)) * 0.1}
    if activation in ("swiglu", "geglu"):
        w["w_gate"] = jax.random.normal(ks[3], (E, d, f)) * 0.1
    dy = jax.random.normal(ks[3], (E, Rr, d), jnp.float32)

    def loss_ref(rr, ww):
        return jnp.vdot(ref.fused_mlp_ref(rr, ww.get("w_gate"), ww["w_up"],
                                          ww["w_down"], activation), dy)

    gr, gw = jax.grad(loss_ref, argnums=(0, 1))(rows, w)
    dx = ops.fused_mlp_dgrad(rows, w, dy, activation, interpret=True)
    dwg, dwu, dwd = ops.fused_mlp_wgrad(rows, w, dy, activation,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dwu), np.asarray(gw["w_up"]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dwd), np.asarray(gw["w_down"]),
                               rtol=1e-4, atol=1e-5)
    if "w_gate" in w:
        np.testing.assert_allclose(np.asarray(dwg), np.asarray(gw["w_gate"]),
                                   rtol=1e-4, atol=1e-5)


def test_dgrad_wgrad_col_blocks_sum_to_full():
    """Per-column-block calls (the backward's dcombine N-decomposition)
    sum to the full-width gradients — the linearity the ring relies on."""
    E, Rr, d, f = 2, 12, 16, 24
    ks = jax.random.split(KEY, 4)
    rows = jax.random.normal(ks[0], (E, Rr, d), jnp.float32)
    w = {"w_gate": jax.random.normal(ks[1], (E, d, f)) * 0.1,
         "w_up": jax.random.normal(ks[2], (E, d, f)) * 0.1,
         "w_down": jax.random.normal(ks[3], (E, f, d)) * 0.1}
    dy = jax.random.normal(ks[3], (E, Rr, d), jnp.float32)
    full_dx = ops.fused_mlp_dgrad(rows, w, dy, "swiglu", interpret=True)
    _, full_dwu, full_dwd = ops.fused_mlp_wgrad(rows, w, dy, "swiglu",
                                                interpret=True)
    dx_sum, dwu_sum = 0, 0
    for st, wd_ in ((0, 8), (8, 8)):
        dyb = dy[..., st:st + wd_]
        dx_sum = dx_sum + ops.fused_mlp_dgrad(rows, w, dyb, "swiglu",
                                              col_slice=(st, wd_),
                                              interpret=True)
        _, du, dd = ops.fused_mlp_wgrad(rows, w, dyb, "swiglu",
                                        col_slice=(st, wd_), interpret=True)
        dwu_sum = dwu_sum + du
        np.testing.assert_allclose(np.asarray(dd),
                                   np.asarray(full_dwd[..., st:st + wd_]),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dx_sum), np.asarray(full_dx),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dwu_sum), np.asarray(full_dwu),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# knob legalization: one shared helper for tuner + transport
# ---------------------------------------------------------------------------

def test_legalize_helpers():
    assert A.legalize_n_col(100, 8) == 5          # 8,7,6 don't divide 100
    assert A.legalize_n_col(128, 4) == 4
    assert A.legalize_n_col(7, 8) == 7
    assert A.legalize_ring_group(6, 4) == 3
    assert A.legalize_ring_group(8, 8) == 8
    assert A.legalize_ring_group(1, 4) == 1
    p = A.legalize_plan(A.Plan("comet", ring_group=4, n_col_blocks=8),
                        100, 6)
    assert (p.ring_group, p.n_col_blocks) == (3, 5)


def test_resolve_plan_returns_legalized_knobs(tmp_path):
    """A cache entry with illegal knobs (e.g. hand-written or pre-v3) must
    resolve to the executable schedule — what transport_comet_blocks runs
    and what the cost model is evaluated on."""
    cfg, mcfg, params, x = _problem(d=100)
    path = str(tmp_path / "plans.json")
    toks = x.shape[0] * x.shape[1]
    s = A.plan_shape(mcfg, 100, toks, 1, 1)
    cache = A.PlanCache(path)
    # bypass tune_plan's legalization the way an external writer would
    cache.plans[cache.key(s, A.TPU_V5E)] = A.Plan(
        "comet", ring_group=5, n_col_blocks=8, measured_s=1e-6,
        source="measured")
    cache.save()
    m2 = dataclasses.replace(mcfg, plan_cache=path)
    plan = A.resolve_plan(m2, 100, toks, 1, 1)
    assert plan.n_col_blocks == A.legalize_n_col(100, 8) == 5
    assert plan.ring_group == 1                   # ep == 1
    y, _ = moe_ffn(cfg, m2, params, x, AxisCtx())
    assert np.isfinite(np.asarray(y)).all()


def test_tune_plan_persists_legal_knobs(tmp_path):
    """The tuner never persists knobs the transport would re-legalize."""
    path = str(tmp_path / "plans.json")
    s = A.MoEShape(M=512, N=100, K=64, E=6, topk=2, ep=6, etp=1)
    cands = [A.Plan("comet", ring_group=4, n_col_blocks=8),
             A.Plan("naive")]
    cache = A.PlanCache(path)
    plan = A.tune_plan(s, A.TPU_V5E, cache, candidates=cands)
    for p in A.PlanCache(path).plans.values():
        assert p.n_col_blocks == A.legalize_n_col(s.N, p.n_col_blocks)
        assert p.ring_group == A.legalize_ring_group(s.ep, p.ring_group)
    assert plan == A.PlanCache(path).get(s, A.TPU_V5E)


# ---------------------------------------------------------------------------
# plan-key token count (the moe_ffn lookup bugfix)
# ---------------------------------------------------------------------------

def test_local_token_count_matches_body_sharding():
    ctx = SimpleNamespace(active=True, seq_shard=True, model_size=4,
                          dp_size=2, dp_axes=("data",))
    # seq-sharded: S divides the model axis -> both dp and model divide
    assert local_token_count(ctx, 4, 32) == 4 * 32 // (2 * 4)
    # indivisible batch: REPLICATED over dp (the old key divided -> under-
    # counted by dp x)
    assert local_token_count(ctx, 3, 32) == 3 * 32 // 4
    # S indivisible by the model axis: no seq shard (the old key ignored
    # this entirely -> overcounted by model_size x when it did shard)
    assert local_token_count(ctx, 4, 31) == 4 * 31 // 2
    # S == 1 never seq-shards
    assert local_token_count(ctx, 4, 1) == 2
    ctx_ns = SimpleNamespace(active=True, seq_shard=False, model_size=4,
                             dp_size=2, dp_axes=("data",))
    assert local_token_count(ctx_ns, 4, 32) == 4 * 32 // 2
    assert local_token_count(SimpleNamespace(active=False), 2, 16) == 32


# ---------------------------------------------------------------------------
# backward cost model + plan cache v3
# ---------------------------------------------------------------------------

def test_layer_times_has_backward_terms():
    s = A.MoEShape(M=8192, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
    lt = A.layer_times(A.TPU_V5E, s)
    assert lt["t_bwd_gemm"] > lt["t_chunk_compute"]       # dgrad+wgrad+remat
    assert lt["bwd_balance"] == pytest.approx(
        2.0 * lt["t_hop"] / lt["t_bwd_gemm"])


def test_bwd_hot_path_strictly_below_autodiff_baseline():
    """Acceptance: modeled comet-backward hot-path HBM bytes AND exposed
    reverse-collective time strictly below the XLA-autodiff transposed
    baseline at every paper shape."""
    from benchmarks.figures import PAPER_MODELS
    hw = A.TPU_V5E
    for name, m in PAPER_MODELS.items():
        s = A.MoEShape(M=8192, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                       ep=8, etp=1)
        plan = min((A.legalize_plan(p, s.N, s.ep)
                    for p in A.candidate_plans(s)
                    if p.impl == "comet" and p.gemm_impl == "pallas_fused"),
                   key=lambda p: A.modeled_plan_time_bwd(hw, s, p))
        assert A.hot_path_hbm_bytes_bwd(s, plan) \
            < A.autodiff_bwd_hbm_bytes(s), name
        assert A.bwd_exposed_comm_time(hw, s, plan) \
            < 2.0 * s.ep * A.layer_times(hw, s)["t_hop"], name


def test_step_ranking_prefers_comet_and_dw_amortization():
    """fwd+bwd ranking: comet still beats naive on the bandwidth-bound
    shape, and ring_group > 1 amortizes the dW accumulator flushes."""
    s = A.MoEShape(M=16384, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    for hw in (A.TPU_V5E, A.H100_NVL):
        plan = A.tune_plan(s, hw)
        assert plan.impl == "comet" and plan.objective == "fwd_bwd"
        assert plan.t_bwd_s > 0
        assert A.modeled_step_time(hw, s, plan) \
            <= A.modeled_step_time(hw, s, A.Plan("naive"))
        rg1 = A.Plan("comet", 1, 1, "pallas_fused")
        rg4 = A.Plan("comet", 4, 1, "pallas_fused")
        assert A._dw_accum_time(hw, s, s.ep // 4) \
            < A._dw_accum_time(hw, s, s.ep)
        assert A.modeled_plan_time_bwd(hw, s, rg4) \
            < A.modeled_plan_time_bwd(hw, s, rg1)


def test_bcast_not_picked_for_training_shape():
    """The decode-path transport must not win a training-shape fwd+bwd
    ranking (its backward requires full-token replication)."""
    s = A.MoEShape(M=16384, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    assert A.tune_plan(s, A.TPU_V5E).impl != "bcast"
    s_dec = A.MoEShape(M=8, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    assert A.tune_plan(s_dec, A.TPU_V5E).impl == "bcast"


def test_plan_cache_v2_roundtrip_compat(tmp_path):
    """A v2 (PR 2) cache file loads into v3 code: missing t_bwd_s/objective
    default ('fwd' — it was ranked forward-only), apply() threads the
    backend, and a re-save upgrades the envelope to v3 losslessly."""
    path = str(tmp_path / "v2.json")
    s = A.MoEShape(M=1024, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    key = A.PlanCache.key(s, A.TPU_V5E)
    entry = {"impl": "comet", "ring_group": 2, "n_col_blocks": 4,
             "gemm_impl": "pallas_fused", "fused_combine": True,
             "measured_s": 2e-3, "source": "measured"}
    with open(path, "w") as f:
        json.dump({"version": 2, "plans": {key: entry}}, f)
    cache = A.PlanCache(path)
    plan = cache.get(s, A.TPU_V5E)
    assert plan.objective == "fwd" and plan.t_bwd_s == 0.0
    assert plan.fused_combine and plan.gemm_impl == "pallas_fused"
    cfg = get_config("granite-moe-3b-a800m-smoke")
    m2 = plan.apply(cfg.moe)
    assert m2.gemm_impl == "pallas_fused" and m2.plan_override
    cache.save()
    re = A.PlanCache(path)
    assert re.get(s, A.TPU_V5E) == plan
    with open(path) as f:
        assert json.load(f)["version"] == A.PLAN_CACHE_VERSION == 6


# ---------------------------------------------------------------------------
# multi-device ring backward (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multidevice_ring_backward_matches_reference():
    """The decomposed backward ring on a real 8-device mesh: grads of comet
    (custom VJP) match the single-device naive/autodiff reference across
    {ep,etp} x ring_group x n_col x fused_combine."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " \
    + os.environ.get("XLA_FLAGS", "")
import dataclasses
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.core.moe_layer import moe_ffn, pack_expert_weights
from repro.parallel.compat import make_mesh, use_mesh
from repro.parallel.mesh import AxisCtx

cfg = get_config("granite-moe-3b-a800m-smoke")
d = cfg.d_model
E, f = 8, 64
ks = jax.random.split(jax.random.PRNGKey(7), 8)
full = {"w_gate": jax.random.normal(ks[0], (E, d, f)) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f)) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d)) * 0.05}
router_w = jax.random.normal(ks[3], (d, E)) * 0.1
x = jax.random.normal(ks[4], (4, 32, d), jnp.float32)
mcfg0 = dataclasses.replace(cfg.moe, num_experts=E, d_expert=f,
                            capacity_factor=float(E), top_k=2)
params_local = {"router": router_w,
                "experts": {k: v[None] for k, v in full.items()}}

def loss_local(p):
    y, aux = moe_ffn(cfg, dataclasses.replace(mcfg0, impl="naive"), p, x,
                     AxisCtx())
    return jnp.sum(y ** 2) + aux
g_local = jax.jit(jax.grad(loss_local))(params_local)

mesh = make_mesh((2, 4), ("data", "model"))
for ep, etp in ((4, 1), (2, 2)):
    ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
                  ep=ep, etp=etp)
    packed = pack_expert_weights(full, ep, etp)
    params = {"router": router_w, "experts": packed}
    gl_packed = pack_expert_weights(
        {k: v[0] for k, v in g_local["experts"].items()}, ep, etp)
    for rg, n_col, fc in ((1, 2, False), (1, 2, True), (2, 1, False),
                          (2, 2, True)):
        m = dataclasses.replace(mcfg0, impl="comet", ring_group=rg,
                                n_col_blocks=n_col, fused_combine=fc)
        def loss(p):
            y, aux = moe_ffn(cfg, m, p, x, ctx)
            return jnp.sum(y ** 2) + aux
        with use_mesh(mesh):
            g = jax.jit(jax.grad(loss))(params)
        for k in packed:
            e = float(jnp.max(jnp.abs(g["experts"][k] - gl_packed[k])))
            s = float(jnp.max(jnp.abs(gl_packed[k]))) + 1e-9
            assert e / s < 5e-5, ("grad", k, ep, etp, rg, n_col, fc, e / s)
        er = float(jnp.max(jnp.abs(g["router"] - g_local["router"])))
        sr = float(jnp.max(jnp.abs(g_local["router"]))) + 1e-9
        assert er / sr < 5e-5, ("router", ep, etp, rg, n_col, fc, er / sr)
        print(f"OK ep{ep} etp{etp} rg{rg} nc{n_col} fc{int(fc)}")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert r.stdout.count("OK") == 8
