"""The discrete-event simulator's reproduction of the paper's claims —
same bands benchmarks/run.py validates, asserted under pytest."""
import pytest

from repro.analysis.simulator import (H100_NVL, L20_PCIE, TPU_V5E, MoEShape,
                                      sim_comet, sim_fastermoe, sim_megatron,
                                      sim_tutel)

MIXTRAL = dict(N=4096, K=14336, E=8, topk=2)


def shape(M, ep=8, etp=1, **over):
    d = dict(MIXTRAL, **over)
    return MoEShape(M=M, N=d["N"], K=d["K"], E=d["E"], topk=d["topk"],
                    ep=ep, etp=etp)


def test_comet_beats_all_baselines_across_M():
    for M in (1024, 4096, 16384, 65536):
        s = shape(M)
        t_comet = sim_comet(H100_NVL, s)["total"]
        for base in (sim_megatron, sim_fastermoe, sim_tutel):
            t_base = base(H100_NVL, s)["total"]
            assert t_comet < t_base, (M, base.__name__)


def test_layer_speedup_in_paper_band():
    """Paper Fig. 10: 1.28-2.37x (avg 1.96). Allow a conservative floor."""
    sp = []
    for M in (1024, 2048, 4096, 8192, 16384, 32768, 65536):
        s = shape(M)
        t_comet = sim_comet(H100_NVL, s)["total"]
        for base in (sim_megatron, sim_fastermoe, sim_tutel):
            sp.append(base(H100_NVL, s)["total"] / t_comet)
    avg = sum(sp) / len(sp)
    assert 1.4 <= avg <= 2.6, avg
    assert min(sp) >= 1.0


def test_latency_hiding_ordering():
    """Paper Fig. 11: comet 86.5% > tutel 68.6% > fastermoe 29.2%."""
    s = shape(16384)
    hide = {}
    for name, fn in (("comet", sim_comet), ("tutel", sim_tutel),
                     ("fastermoe", sim_fastermoe)):
        r = fn(H100_NVL, s)
        hide[name] = r["overlapped"] / max(r["comm"], 1e-12)
    assert hide["comet"] >= 0.75
    assert hide["comet"] > hide["tutel"] > hide["fastermoe"]


def test_speedup_larger_at_small_M():
    """Paper: 'the advantage of Comet is prominent especially when M is
    small' (host scheduling dominates there)."""
    def sp(M):
        s = shape(M)
        return sim_tutel(H100_NVL, s)["total"] / sim_comet(H100_NVL, s)["total"]
    assert sp(1024) > sp(65536)


def test_comet_stable_across_parallelism():
    """Paper Fig. 12: baselines degrade as TP grows; comet maintains."""
    ts_comet, ts_tutel = [], []
    for ep, etp in [(8, 1), (4, 2), (2, 4)]:
        s = shape(8192, ep, etp)
        ts_comet.append(sim_comet(H100_NVL, s)["total"])
        ts_tutel.append(sim_tutel(H100_NVL, s)["total"])
    assert max(ts_comet) / min(ts_comet) < max(ts_tutel) / min(ts_tutel)


def test_l20_cluster_speedup_band():
    """Paper Fig. 14 right: 1.19-1.46x on the bandwidth-limited cluster."""
    sp = []
    for ep, etp in [(8, 1), (4, 2)]:
        s = MoEShape(M=8192, N=4096, K=14336, E=8, topk=4, ep=ep, etp=etp)
        t_comet = sim_comet(L20_PCIE, s)["total"]
        for base in (sim_megatron, sim_tutel):
            sp.append(base(L20_PCIE, s)["total"] / t_comet)
    avg = sum(sp) / len(sp)
    assert 1.0 <= avg <= 1.9, avg


def test_tpu_mode_no_compute_derate():
    """Hardware adaptation: on TPU the DMA engines are disjoint from the MXU,
    so comet-TPU must never be slower than comet-GPU-model at equal specs."""
    s = shape(16384)
    t_tpu = sim_comet(H100_NVL, s, tpu=True)["total"]
    t_gpu = sim_comet(H100_NVL, s, tpu=False)["total"]
    assert t_tpu <= t_gpu


def test_imbalance_prolongs_and_comet_stays_best():
    for std in (0.0, 0.032, 0.05):
        s = shape(8192)
        tc = sim_comet(H100_NVL, s, imb=std)["total"]
        tm = sim_megatron(H100_NVL, s, imb=std)["total"]
        tt = sim_tutel(H100_NVL, s, imb=std)["total"]
        assert tc <= min(tm, tt)
    assert sim_comet(H100_NVL, shape(8192), imb=0.05)["total"] > \
        sim_comet(H100_NVL, shape(8192), imb=0.0)["total"]


def test_fastermoe_rejects_tensor_parallel():
    with pytest.raises(ValueError):
        sim_fastermoe(H100_NVL, shape(8192, ep=4, etp=2))
