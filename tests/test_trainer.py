"""Fault-tolerance: checkpoint/restart replay determinism, fault injection,
straggler monitor, elastic re-meshing (CPU, single device)."""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.training.trainer import StragglerMonitor, Trainer, TrainerConfig

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def make_trainer(tmp, **kw):
    cfg = get_config("qwen2-0.5b-smoke")
    tcfg = TrainerConfig(ckpt_dir=tmp, ckpt_every=2, log_every=1000,
                         keep=2, **kw)
    return Trainer(cfg, SHAPE, mesh=None, tcfg=tcfg)


def losses(out):
    return [m["loss"] for m in out["metrics"]]


def test_checkpoint_restart_is_bit_identical():
    """Run 6 steps straight vs. run-4 + new-trainer-resume-to-6: the loss
    trajectory (and final params) must be identical — data is a pure function
    of (seed, step) and restore is exact."""
    with tempfile.TemporaryDirectory() as t1, \
            tempfile.TemporaryDirectory() as t2:
        tr_a = make_trainer(t1)
        out_a = tr_a.run(6)
        tr_b = make_trainer(t2)
        tr_b.run(4)
        tr_b2 = make_trainer(t2)             # fresh object: restore path
        out_b = tr_b2.run(6)
        la, lb = losses(out_a), losses(out_b)
        np.testing.assert_allclose(la[4:], lb[-2:], rtol=1e-6)

        sa, _ = tr_a.ckpt.restore(
            jax.tree_util.tree_map(lambda x: x, tr_a.init_state()))
        sb, _ = tr_b2.ckpt.restore(
            jax.tree_util.tree_map(lambda x: x, tr_b2.init_state()))
        fa = jax.tree_util.tree_leaves(sa["params"])
        fb = jax.tree_util.tree_leaves(sb["params"])
        for a, b in zip(fa, fb):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-6)


def test_fault_injection_recovers_and_replays():
    """A step that raises (simulated node failure) triggers restore + replay;
    the final trajectory equals the fault-free run."""
    with tempfile.TemporaryDirectory() as t1, \
            tempfile.TemporaryDirectory() as t2:
        clean = make_trainer(t1).run(6)

        crashed = {"done": False}

        def bomb(step):
            if step == 5 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        tr = make_trainer(t2)
        tr.fault_hook = bomb
        out = tr.run(6)
        assert out["restarts"] == 1
        np.testing.assert_allclose(losses(clean)[-1], losses(out)[-1],
                                   rtol=1e-6)


def test_too_many_restarts_raises():
    with tempfile.TemporaryDirectory() as t:
        tr = make_trainer(t, max_restarts=1)
        tr.fault_hook = lambda s: (_ for _ in ()).throw(
            RuntimeError("always down"))
        with pytest.raises(RuntimeError):
            tr.run(3)


def test_straggler_monitor():
    hits = []
    m = StragglerMonitor(factor=2.0, on_straggler=lambda s, dt, e: hits.append(s))
    for s in range(10):
        m.observe(s, 0.1)
    assert m.observe(10, 0.5)            # 5x the EWMA -> flagged
    assert hits == [10]
    ewma_before = m.ewma
    m.observe(11, 0.5)                   # outliers must not poison the EWMA
    assert m.ewma == ewma_before
    assert not m.observe(12, 0.11)


def test_elastic_rescale_cpu_roundtrip():
    """mesh=None -> mesh=None rescale keeps state exact (host round-trip)."""
    with tempfile.TemporaryDirectory() as t:
        tr = make_trainer(t)
        tr.run(2)
        state, step = tr.restore_or_init()
        state2 = tr.rescale(state, None)
        a = jax.tree_util.tree_leaves(state["params"])
        b = jax.tree_util.tree_leaves(state2["params"])
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_nan_guard_skips_update_in_graph():
    """A poisoned state makes loss/grad_norm non-finite; the jitted step
    must refuse the update IN-GRAPH (donated state — host-side refusal is
    impossible): metrics say skipped and the step counter holds still."""
    with tempfile.TemporaryDirectory() as t:
        tr = make_trainer(t)
        state = tr.init_state()
        batch = tr._device_batch(tr.data.batch_at(0))
        state, metrics = tr.built["jit"](state, batch)
        assert int(metrics["skipped"]) == 0 and int(state["step"]) == 1
        # poison one param leaf -> NaN loss everywhere downstream
        leaves, treedef = jax.tree_util.tree_flatten(state["params"])
        leaves[0] = leaves[0] * jax.numpy.nan
        state["params"] = jax.tree_util.tree_unflatten(treedef, leaves)
        batch = tr._device_batch(tr.data.batch_at(1))
        state2, metrics = tr.built["jit"](state, batch)
        assert int(metrics["skipped"]) == 1
        assert int(state2["step"]) == 1          # update refused


def test_nan_limit_escalates_to_checkpoint_replay():
    """Persistent NaNs (poisoned params — skipping can't heal those) must
    escalate after nan_limit consecutive skips to the normal restore/replay
    path, and the run then completes with a finite trajectory."""
    with tempfile.TemporaryDirectory() as t1, \
            tempfile.TemporaryDirectory() as t2:
        clean = make_trainer(t1).run(6)

        fired = {"done": False}

        def poison(step, state):
            if step == 3 and not fired["done"]:
                fired["done"] = True
                bad = jax.tree_util.tree_map(
                    lambda x: x * jax.numpy.nan, state["params"])
                return {"params": bad, "opt": state["opt"],
                        "step": state["step"]}
            return state

        tr = make_trainer(t2, nan_limit=2)
        tr.fault_hook = poison
        out = tr.run(6)
        assert out["restarts"] == 1
        assert out["nan_skips"] == 3             # nan_limit + 1 before raise
        assert np.isfinite(losses(out)[-1])
        # post-recovery trajectory equals the fault-free run
        np.testing.assert_allclose(losses(clean)[-1], losses(out)[-1],
                                   rtol=1e-6)


def test_loss_decreases_over_training():
    from repro.optim.adamw import AdamW
    with tempfile.TemporaryDirectory() as t:
        cfg = get_config("qwen2-0.5b-smoke")
        tcfg = TrainerConfig(ckpt_dir=t, ckpt_every=1000, log_every=1000)
        tr = Trainer(cfg, SHAPE, mesh=None, tcfg=tcfg,
                     optim=AdamW(lr=lambda s: 5e-3))
        out = tr.run(20)
        ls = losses(out)
        assert ls[-1] < ls[0], ls
