"""End-to-end system behaviour: the launch-layer step builders produce
runnable jitted steps on CPU (mesh=None), and the dry-run machinery works
against a tiny forced-device subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.train_step import (build_decode_step, build_prefill_step,
                                     build_train_step)

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def test_train_step_runs_and_updates():
    cfg = get_config("granite-moe-3b-a800m-smoke")
    built = build_train_step(cfg, SHAPE, mesh=None)
    from repro.models import lm
    from repro.optim.adamw import AdamW
    params = lm.init_params(cfg, jax.random.PRNGKey(0), built["ctx"])
    state = {"params": params, "opt": AdamW().init(params),
             "step": jnp.zeros((), jnp.int32)}
    data = SyntheticLM(cfg, built["batch_structs"])
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    # snapshot before the call: the step donates its input state
    a = np.asarray(jax.tree_util.tree_leaves(params)[0]).copy()
    new_state, metrics = built["jit"](state, batch)
    assert int(new_state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    b = jax.tree_util.tree_leaves(new_state["params"])[0]
    assert not np.array_equal(a, np.asarray(b))


def test_grad_accum_equals_large_batch():
    """accum=2 over half batches == accum=1 over the full batch (same data)."""
    cfg = get_config("qwen2-0.5b-smoke")
    from repro.models import lm
    from repro.optim.adamw import AdamW
    shape4 = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW()
    # deep-copy per state: the jitted step donates (deletes) its input
    state = lambda: jax.tree_util.tree_map(
        jnp.copy, {"params": params, "opt": opt.init(params),
                   "step": jnp.zeros((), jnp.int32)})

    b1 = build_train_step(cfg, shape4, mesh=None, accum=1)
    data = SyntheticLM(cfg, b1["batch_structs"])
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1, m1 = b1["jit"](state(), batch)

    b2 = build_train_step(cfg, shape4, mesh=None, accum=2)
    batch2 = {k: jnp.asarray(v).reshape((2, 2) + v.shape[1:])
              for k, v in data.batch_at(0).items()}
    s2, m2 = b2["jit"](state(), batch2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_prefill_and_decode_steps_build_and_run():
    cfg = get_config("qwen2-0.5b-smoke")
    from repro.models import lm
    pshape = ShapeConfig("p", seq_len=32, global_batch=2, kind="prefill")
    built = build_prefill_step(cfg, pshape, mesh=None)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), built["ctx"])
    data = SyntheticLM(cfg, built["batch_structs"])
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    logits, cache = built["jit"](params, batch)
    assert logits.shape == (2, cfg.vocab_size)

    dshape = ShapeConfig("d", seq_len=64, global_batch=2, kind="decode")
    dbuilt = build_decode_step(cfg, dshape, mesh=None)
    cache0 = lm.init_cache(cfg, 2, 64, dbuilt["ctx"])
    tok = jnp.zeros((2, 1), jnp.int32)
    # per-row positions + live-slot mask (the continuous-batching signature)
    nxt, logits, cache1 = dbuilt["jit"](params, cache0, tok,
                                        jnp.array([0, 3], jnp.int32),
                                        jnp.array([True, False]))
    assert nxt.shape == (2, 1)
    assert int(nxt[1, 0]) == 0          # dead slot emits token 0
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.slow
def test_dryrun_one_cell_small_fleet():
    """Compile one real (arch × shape) cell on a 16-device forced fleet via
    the dry-run entry; asserts the roofline report is well-formed."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["REPRO_DRYRUN_DEVICES"] = "16"
    r = subprocess.run(
        [sys.executable, "-c", (
            "import repro.launch.dryrun as D;"
            "import jax;"
            "from repro.configs.base import get_config, LM_SHAPES;"
            "from repro.parallel.compat import make_mesh;"
            "mesh = make_mesh((4, 4), ('data', 'model'));"
            "r = D.run_cell(get_config('qwen2-0.5b'), LM_SHAPES['decode_32k'],"
            "               mesh, 16, 'comet');"
            "assert r['status'] == 'ok', r;"
            "assert r['hlo_flops_per_device'] > 0;"
            "print('OK', r['dominant'])")],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
