"""Mamba-2 SSD: chunked dual form vs the sequential recurrence oracle, and
decode-step consistency with the prefill state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import ssm as S

KEY = jax.random.PRNGKey(7)


def make_inputs(B=2, Sq=64, nh=4, hd=16, ds=8):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, Sq, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Sq, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, Sq, ds), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, Sq, ds), jnp.float32)
    D = jnp.ones((nh,), jnp.float32) * 0.5
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_reference(chunk):
    x, dt, A, Bm, Cm, D = make_inputs()
    got, _ = S.ssd_chunked(x, dt, A, Bm, Cm, D, chunk)
    want = S.ssd_reference(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    """The dual form must be exactly chunk-size independent."""
    x, dt, A, Bm, Cm, D = make_inputs(Sq=64)
    y8, h8 = S.ssd_chunked(x, dt, A, Bm, Cm, D, 8)
    y32, h32 = S.ssd_chunked(x, dt, A, Bm, Cm, D, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32),
                               rtol=1e-4, atol=1e-4)


def test_ssd_final_state_matches_recurrence():
    """h_final from the chunked form == state after running the recurrence."""
    x, dt, A, Bm, Cm, D = make_inputs(B=1, Sq=32)
    _, h_final = S.ssd_chunked(x, dt, A, Bm, Cm, D, 8)

    # sequential state
    h = jnp.zeros_like(h_final)
    for t in range(32):
        a = jnp.exp(dt[:, t] * A)
        xd = x[:, t] * dt[:, t, :, None]
        h = h * a[..., None, None] + jnp.einsum("bs,bhp->bhsp", Bm[:, t], xd)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_ssm_block_prefill_then_decode_matches_full():
    """Running S tokens chunked, then decoding token S+1 with the cache, must
    equal running S+1 tokens in one pass (the serving-correctness contract)."""
    cfg = get_config("mamba2-780m-smoke")
    s = cfg.ssm
    key = jax.random.PRNGKey(0)
    import repro.models.common as C
    p = C.init_from_schema(S.ssm_schema(cfg, s), key, "float32")
    B, Sq = 2, 16
    x_full = jax.random.normal(jax.random.PRNGKey(1),
                               (B, Sq + 1, cfg.d_model), jnp.float32) * 0.3

    y_full, _ = S.ssm_forward(cfg, s, p, x_full)
    y_pre, cache = S.ssm_forward(cfg, s, p, x_full[:, :Sq], return_cache=True)
    np.testing.assert_allclose(np.asarray(y_full[:, :Sq]), np.asarray(y_pre),
                               rtol=2e-4, atol=2e-4)
    y_dec, _ = S.ssm_forward(cfg, s, p, x_full[:, Sq:Sq + 1], cache=cache)
    np.testing.assert_allclose(np.asarray(y_full[:, Sq]),
                               np.asarray(y_dec[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_causal_conv_streaming():
    """Streaming conv with state must equal the full conv."""
    B, Sq, C_, W = 1, 12, 6, 4
    x = jax.random.normal(KEY, (B, Sq, C_), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (W, C_), jnp.float32)
    b = jnp.zeros((C_,))
    y_full, _ = S._causal_conv(x, w, b)
    state = jnp.zeros((B, W - 1, C_))
    ys = []
    for t in range(Sq):
        yt, state = S._causal_conv(x[:, t:t + 1], w, b, state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
