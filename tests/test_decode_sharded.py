"""Split-KV flash decode: the sharded decode attention (kv-group sharding /
split-KV partial merge) must match the plain oracle, unit-level on CPU and
end-to-end on an 8-device mesh (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def test_partial_merge_equals_full_softmax():
    """Merging per-shard (m, l, acc) partials must equal attention over the
    whole cache — checked WITHOUT a mesh by manual sharding + merge math."""
    B, S, H, Hkv, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    pos = jnp.int32(37)
    want = A.decode_attention(q, kc, vc, pos)

    shards = 4
    Sl = S // shards
    parts = [A.decode_attention_partial(q, kc[:, r * Sl:(r + 1) * Sl],
                                        vc[:, r * Sl:(r + 1) * Sl], pos,
                                        r * Sl)
             for r in range(shards)]
    # replicate merge_decode_partials' math without a mesh axis
    ms = jnp.stack([p[0] for p in parts])
    ls = jnp.stack([p[1] for p in parts])
    accs = jnp.stack([p[2] for p in parts])
    m_g = jnp.max(ms, axis=0)
    corr = jnp.exp(ms - m_g)
    l_g = jnp.sum(ls * corr, axis=0)
    acc_g = jnp.sum(accs * corr[..., None], axis=0)
    got = (acc_g / jnp.maximum(l_g[..., None], 1e-30)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_fully_masked_shard_contributes_zero():
    """A shard entirely beyond pos must not produce NaNs or contributions."""
    B, S, Hkv, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, 2, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    m, l, acc = A.decode_attention_partial(q, kc, vc, jnp.int32(3),
                                           kv_offset=16)   # all masked
    assert np.isfinite(np.asarray(m)).all()
    np.testing.assert_array_equal(np.asarray(l), 0.0)
    np.testing.assert_array_equal(np.asarray(acc), 0.0)


@pytest.mark.slow
def test_decode_on_mesh_matches_unpacked_reference():
    """Full decode_step on an 8-device mesh == single-device reference with
    properly unpacked (ETP) expert weights, for a MoE (split-KV), a dense
    (split-KV) and a GQA-divisible (kv-group) arch."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.configs.base import get_config
from repro.parallel.compat import make_mesh, use_mesh
from repro.parallel.mesh import AxisCtx
from repro.parallel.sharding import make_ctx
from repro.models import lm

def unpack_layer(moe_p, ep, etp):
    out = dict(moe_p)
    ex = {}
    for k, w in moe_p["experts"].items():
        def un(wp):
            slices = [wp[g * etp + t] for g in range(ep) for t in range(etp)]
            axis = -2 if k == "w_down" else -1
            groups = [jnp.concatenate(slices[g*etp:(g+1)*etp], axis=axis)
                      for g in range(ep)]
            return jnp.concatenate(groups, axis=0)[None]
        ex[k] = jax.vmap(un)(w) if w.ndim == 5 else un(w)
    out["experts"] = ex
    return out

for arch, shape in [("granite-moe-3b-a800m-smoke", (2, 4)),
                    ("qwen2-0.5b-smoke", (1, 8)),
                    ("jamba-v0.1-52b-smoke", (2, 4))]:
    cfg = get_config(arch)
    mesh = make_mesh(shape, ("data", "model"))
    ctx = make_ctx(cfg, mesh)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), ctx)
    local = jax.tree_util.tree_map(lambda v: v, params)
    for li, lp in enumerate(params["layers"]):
        if "moe" in lp:
            local["layers"][li] = dict(lp)
            local["layers"][li]["moe"] = unpack_layer(lp["moe"], ctx.ep, ctx.etp)
    B, S = 2, 32
    cache0 = lm.init_cache(cfg, B, S)
    tok = jnp.array([[3], [5]], jnp.int32)
    ref, _ = lm.decode_step(cfg, local, cache0, tok, jnp.int32(4), AxisCtx())
    with use_mesh(mesh):
        got, _ = jax.jit(lambda p, c, t: lm.decode_step(
            cfg, p, c, t, jnp.int32(4), ctx))(params, cache0, tok)
    err = float(jnp.max(jnp.abs(got - ref))) / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert err < 5e-5, (arch, err)
    print("OK", arch, err)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert r.stdout.count("OK") == 3
