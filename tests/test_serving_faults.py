"""Serving robustness: typed rejections, bounded queue + shedding,
cancellation, deadlines (fake clock), NaN-row quarantine, deterministic
fault injection, and exactly-once crash recovery (snapshot/restore/replay
with zero lost and zero duplicated tokens vs the fault-free run)."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serving import (FaultInjector, FaultPlan, InjectedFault,
                           RejectedRequest, RejectReason, RequestStatus,
                           ServeEngine)


@pytest.fixture(scope="module")
def params():
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=2, seed=0, chunk=4)
    return eng.params


def make_engine(params, **kw):
    cfg = get_config("qwen2-0.5b-smoke")
    kw.setdefault("max_seq", 64)
    kw.setdefault("batch_size", 2)
    kw.setdefault("chunk", 4)
    return ServeEngine(cfg, params=params, **kw)


PROMPTS = [[3, 1, 4, 1, 5], [2, 7, 1], [9, 10, 11, 12], [6, 5]]


def _tokens_by_rid(eng, rids):
    return {rid: list(eng.finished[rid].tokens) for rid in rids}


# ---------------------------------------------------------------------------
# Typed rejections (the paths that used to assert-crash the engine)
# ---------------------------------------------------------------------------


def test_submit_rejections_typed_and_engine_survives(params):
    eng = make_engine(params)
    with pytest.raises(RejectedRequest) as ei:
        eng.submit([], max_new=4)
    assert ei.value.reason == RejectReason.EMPTY_PROMPT
    assert ei.value.request.status == RequestStatus.REJECTED
    with pytest.raises(RejectedRequest) as ei:
        eng.submit([1, 2, 3], max_new=62)            # 3 + 62 > 64
    assert ei.value.reason == RejectReason.TOO_LONG
    assert not eng.queue and not eng.pending
    # the engine is fully serviceable afterwards
    res = eng.generate([[5, 6, 7]], max_new=3)
    assert res.tokens.shape == (1, 3)


def test_submit_over_capacity_paged(params):
    eng = make_engine(params, max_seq=32, page_size=4, n_pages=5)
    with pytest.raises(RejectedRequest) as ei:
        eng.submit(list(range(1, 21)), max_new=6)    # 7 pages > 4 usable
    assert ei.value.reason == RejectReason.OVER_CAPACITY
    eng.generate([[1, 2, 3]], max_new=3)             # still serviceable


def test_rejection_inside_step_does_not_trip_recovery(params):
    """RejectedRequest must propagate to the caller untouched — it is a
    client error, not an engine failure, so no recovery cycle runs."""
    eng = make_engine(params, recover=True)
    with pytest.raises(RejectedRequest):
        eng.submit([], max_new=2)
    assert eng.failures == 0 and eng.recoveries == 0


# ---------------------------------------------------------------------------
# Bounded queue + shedding
# ---------------------------------------------------------------------------


def test_bounded_queue_reject_policy(params):
    eng = make_engine(params, max_queue=2)
    eng.submit([1, 2], max_new=2)
    eng.submit([3, 4], max_new=2)
    with pytest.raises(RejectedRequest) as ei:
        eng.submit([5, 6], max_new=2)
    assert ei.value.reason == RejectReason.QUEUE_FULL
    assert len(eng.queue) == 2 and eng.shed == 0
    eng.run()
    assert all(r.status == RequestStatus.OK for r in eng.finished.values())


def test_bounded_queue_deadline_shed(params):
    clock = FakeClock()
    eng = make_engine(params, max_queue=2, shed_policy="deadline",
                      clock=clock)
    ra = eng.submit([1, 2], max_new=2, deadline_s=0.5)    # least slack
    rb = eng.submit([3, 4], max_new=2, deadline_s=50.0)
    rc = eng.submit([5, 6], max_new=2, deadline_s=50.0)   # sheds ra
    assert eng.shed == 1
    assert eng.finished[ra].status == RequestStatus.EXPIRED
    assert [r.rid for r in eng.queue] == [rb, rc]
    # a no-deadline queue never sheds: ties reject the newcomer instead
    eng2 = make_engine(params, max_queue=1, shed_policy="deadline")
    rd = eng2.submit([1, 2], max_new=2)
    with pytest.raises(RejectedRequest):
        eng2.submit([3, 4], max_new=2)
    assert eng2.queue[0].rid == rd and eng2.shed == 0


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------


def test_cancel_queued_and_live(params):
    eng = make_engine(params, batch_size=1, page_size=8)
    ra = eng.submit(PROMPTS[0], max_new=8)
    rb = eng.submit(PROMPTS[1], max_new=8)
    eng.step()                                   # admits ra; rb queued
    assert eng.live[0] and eng.slot_req[0].rid == ra
    used_before = eng.alloc.used_pages
    assert used_before > 0
    assert eng.cancel(ra)                        # live cancel: slot + pages
    assert not eng.live[0] and eng.slot_req[0] is None
    assert eng.alloc.used_pages == 0
    got = eng.finished[ra]
    assert got.status == RequestStatus.CANCELLED
    assert len(got.tokens) >= 1                  # partial tokens kept
    assert eng.cancel(rb)                        # queued cancel
    assert eng.finished[rb].status == RequestStatus.CANCELLED
    assert not eng.cancel(ra)                    # already terminal
    assert not eng.cancel(12345)                 # unknown rid
    # freed capacity is immediately reusable
    res = eng.generate([[7, 8, 9]], max_new=3)
    assert res.tokens.shape == (1, 3)


# ---------------------------------------------------------------------------
# Deadlines (deterministic via injected clock)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_ttft_deadline_expires_queued(params):
    clock = FakeClock()
    eng = make_engine(params, batch_size=1, clock=clock)
    ra = eng.submit(PROMPTS[0], max_new=4)               # takes the slot
    rb = eng.submit(PROMPTS[1], max_new=4, ttft_deadline_s=1.0)
    eng.step()
    assert eng.live[0]
    clock.t = 2.0                                        # rb is now late
    eng.step()
    assert eng.finished[rb].status == RequestStatus.EXPIRED
    assert "ttft" in eng.finished[rb].error
    assert eng.expired == 1
    eng.run()
    assert eng.finished[ra].status == RequestStatus.OK


def test_total_deadline_expires_live(params):
    clock = FakeClock()
    eng = make_engine(params, clock=clock)
    ra = eng.submit(PROMPTS[0], max_new=32, deadline_s=5.0)
    eng.step()                                           # admit + token 0
    assert eng.live.any()
    clock.t = 6.0
    eng.step()                                           # decode then expire
    got = eng.finished[ra]
    assert got.status == RequestStatus.EXPIRED
    assert len(got.tokens) >= 1                          # partial kept
    assert not eng.pending


# ---------------------------------------------------------------------------
# NaN quarantine
# ---------------------------------------------------------------------------


def test_nan_row_quarantined_neighbors_exact(params):
    clean = make_engine(params)
    ref = clean.generate(PROMPTS[:2], max_new=6)
    plan = FaultPlan(nan_rows={3: 1})                 # poison 1 row @ step 3
    eng = make_engine(params, faults=FaultInjector(plan))
    rids = [eng.submit(p, max_new=6) for p in PROMPTS[:2]]
    eng.run()
    statuses = [eng.finished[r].status for r in rids]
    assert statuses.count(RequestStatus.QUARANTINED) == 1
    assert eng.quarantined == 1
    ok_i = statuses.index(RequestStatus.OK)
    bad_i = 1 - ok_i
    # the surviving neighbour's stream is bit-identical to fault-free
    assert eng.finished[rids[ok_i]].tokens == ref.tokens[ok_i].tolist()
    # the quarantined one kept its pre-fault prefix of the clean stream
    bad = eng.finished[rids[bad_i]].tokens
    assert bad == ref.tokens[bad_i].tolist()[:len(bad)]
    assert not eng.pending                            # engine drained clean


# ---------------------------------------------------------------------------
# Crash recovery: exactly-once
# ---------------------------------------------------------------------------


def _run_faulted(params, plan, tmp=None, n=4, max_new=6, paged=True,
                 **kw):
    emissions = []
    eng = make_engine(
        params, page_size=8 if paged else 0,
        snapshot_dir=str(tmp) if tmp is not None else None,
        snapshot_every=2, faults=FaultInjector(plan),
        on_token=lambda rid, idx, tok: emissions.append((rid, idx, tok)),
        **kw)
    rids = [eng.submit(p, max_new=max_new) for p in PROMPTS[:n]]
    eng.run()
    return eng, rids, emissions


def _assert_exactly_once(eng, rids, emissions):
    """Zero lost, zero duplicated: every (rid, idx) emitted exactly once
    and the emitted stream reassembles each request's token list."""
    seen = {}
    for rid, idx, tok in emissions:
        assert (rid, idx) not in seen, f"duplicate emission {(rid, idx)}"
        seen[(rid, idx)] = tok
    for rid in rids:
        toks = eng.finished[rid].tokens
        got = [seen[(rid, i)] for i in range(len(toks))]  # KeyError = lost
        assert got == toks


def test_crash_recovery_exactly_once_with_snapshots(params, tmp_path):
    clean = make_engine(params, page_size=8)
    ref = clean.generate(PROMPTS, max_new=6)
    plan = FaultPlan(crash_steps=(5,))
    eng, rids, emissions = _run_faulted(params, plan, tmp=tmp_path)
    assert eng.failures == 1 and eng.recoveries == 1
    for i, rid in enumerate(rids):
        got = eng.finished[rid]
        assert got.status == RequestStatus.OK
        assert got.tokens == ref.tokens[i].tolist(), i   # bit-identical
    _assert_exactly_once(eng, rids, emissions)
    assert eng.free_pages == eng.n_pages - 1             # pages all home


def test_crash_recovery_without_snapshot_replays_from_scratch(params):
    """recover=True with no snapshot_dir: reset to the initial state and
    replay the full event log — slower, still exactly-once."""
    clean = make_engine(params)
    ref = clean.generate(PROMPTS[:2], max_new=5)
    plan = FaultPlan(crash_steps=(4,))
    eng, rids, emissions = _run_faulted(params, plan, paged=False,
                                        n=2, max_new=5, recover=True)
    assert eng.recoveries == 1
    for i, rid in enumerate(rids):
        assert eng.finished[rid].tokens == ref.tokens[i].tolist(), i
    _assert_exactly_once(eng, rids, emissions)


def test_unrecoverable_crash_fails_all_terminally(params):
    """No recovery configured: the fault propagates, but every request
    still reaches a terminal status (failed) — nobody is left hanging."""
    eng = make_engine(params, faults=FaultInjector(
        FaultPlan(crash_steps=(2,))))
    rids = [eng.submit(p, max_new=4) for p in PROMPTS[:2]]
    with pytest.raises(InjectedFault):
        eng.run()
    for rid in rids:
        assert eng.finished[rid].status == RequestStatus.FAILED
    assert not eng.pending


def test_max_restarts_caps_consecutive_failures(params, tmp_path):
    """A crash on every step exhausts max_restarts and re-raises; requests
    end terminally failed."""
    plan = FaultPlan(crash_steps=tuple(range(1, 50)))
    eng = make_engine(params, snapshot_dir=str(tmp_path), max_restarts=2,
                      faults=FaultInjector(plan))
    rid = eng.submit(PROMPTS[0], max_new=4)
    with pytest.raises(InjectedFault):
        eng.run()
    assert eng.failures == 3                       # 2 recovered + 1 fatal
    assert eng.recoveries == 2
    assert eng.finished[rid].status == RequestStatus.FAILED


def test_manual_snapshot_restore_roundtrip(params, tmp_path):
    eng = make_engine(params, page_size=8, snapshot_dir=str(tmp_path),
                      snapshot_every=0)            # manual snapshots only
    rid = eng.submit(PROMPTS[0], max_new=8)
    eng.step()
    eng.step()
    eng.snapshot()
    toks_at_snap = list(eng.finished.get(rid, eng.slot_req[0]).tokens)
    pos_at_snap = eng.pos.copy()
    eng.step()
    eng.step()
    eng.restore()
    assert eng.slot_req[0].rid == rid
    assert eng.slot_req[0].tokens == toks_at_snap
    np.testing.assert_array_equal(eng.pos, pos_at_snap)
    eng.alloc.check()
    eng.run()
    assert eng.finished[rid].status == RequestStatus.OK


# ---------------------------------------------------------------------------
# Latency spikes + page pressure
# ---------------------------------------------------------------------------


def test_latency_spike_flags_straggler(params):
    slept = []
    inj = FaultInjector(FaultPlan(latency_s={4: 0.5}), sleep=slept.append)
    eng = make_engine(params, faults=inj)
    # warm the EWMA with real steps, then check the spike is recorded
    eng.generate(PROMPTS[:2], max_new=6)
    assert inj.counts["latency"] == 1 and slept == [0.5]


def test_page_squeeze_stalls_then_admits(params):
    clean = make_engine(params, max_seq=32, page_size=4, n_pages=9)
    ref = clean.generate(PROMPTS[:2], max_new=4)
    # from step 1, hold 6 of the 8 usable pages for 3 steps: admission of
    # the 2nd request (3 pages) must stall, then proceed — and the final
    # streams are still bit-identical to fault-free
    inj = FaultInjector(FaultPlan(page_squeeze={1: (6, 3)}))
    eng = make_engine(params, max_seq=32, page_size=4, n_pages=9,
                      faults=inj)
    rids = [eng.submit(p, max_new=4) for p in PROMPTS[:2]]
    eng.step()
    assert inj.counts["page_squeeze"] == 1
    assert len(eng.queue) >= 1                      # someone had to wait
    eng.run()
    for i, rid in enumerate(rids):
        assert eng.finished[rid].status == RequestStatus.OK
        assert eng.finished[rid].tokens == ref.tokens[i].tolist(), i
    assert eng.free_pages == eng.n_pages - 1        # squeezes released


# ---------------------------------------------------------------------------
# Chaos traces (the acceptance contract)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_trace_exactly_once(params, tmp_path, seed):
    """Poisson fault schedule (crashes + NaN rows + latency spikes + page
    squeezes) over a multi-request trace: every request reaches a terminal
    status, non-quarantined streams are bit-identical to the fault-free
    run, and emission is exactly-once."""
    clean = make_engine(params, page_size=8)
    ref = clean.generate(PROMPTS, max_new=8)
    plan = FaultPlan.poisson(seed, horizon=64, crash_rate=0.08,
                             nan_rate=0.05, spike_rate=0.1, spike_s=0.0,
                             squeeze_rate=0.1, squeeze_hold=2)
    eng, rids, emissions = _run_faulted(params, plan, tmp=tmp_path / "s",
                                        max_new=8, max_restarts=10)
    for i, rid in enumerate(rids):
        got = eng.finished[rid]
        assert got.status in (RequestStatus.OK, RequestStatus.QUARANTINED)
        if got.status == RequestStatus.OK:
            assert got.tokens == ref.tokens[i].tolist(), (seed, i)
        else:                                   # pre-fault prefix is clean
            assert got.tokens == ref.tokens[i].tolist()[:len(got.tokens)]
    _assert_exactly_once(eng, rids, emissions)
    eng.faults.release_all(eng)       # squeezes may outlive the drain
    assert eng.free_pages == eng.n_pages - 1
    # every crash that actually fired was recovered from
    assert eng.failures == eng.recoveries == eng.faults.counts["crash"]
