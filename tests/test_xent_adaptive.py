"""Chunked cross-entropy vs full softmax (values + grads), and the adaptive
workload-assignment model's paper-qualitative behaviours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive import (H100_NVL, L20_PCIE, TPU_V5E, MoEShape,
                                 AdaptiveCache, choose_n_col, gemm_time,
                                 layer_times)
from repro.models.common import chunked_xent


def full_xent(h, w, labels):
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                              axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - tgt) * mask) / jnp.maximum(mask.sum(), 1.0)


@pytest.mark.parametrize("S,chunk", [(64, 16), (100, 32), (16, 64)])
def test_chunked_xent_matches_full(S, chunk):
    B, d, V = 2, 32, 97
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
    labels = jax.random.randint(ks[2], (B, S), -1, V)  # includes ignored
    got, cnt = chunked_xent(h, w, labels, chunk=chunk)
    want = full_xent(h, w, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    assert int(cnt) == int((np.asarray(labels) >= 0).sum())


def test_chunked_xent_grads_match_full():
    B, S, d, V = 2, 48, 16, 61
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    g1 = jax.grad(lambda hh: chunked_xent(hh, w, labels, chunk=16)[0])(h)
    g2 = jax.grad(lambda hh: full_xent(hh, w, labels))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# adaptive workload assignment (paper §3.2.2 behaviours, TPU-native knobs)
# ---------------------------------------------------------------------------

def shape(M, N=4096, K=14336, E=8, topk=2, ep=8, etp=1):
    return MoEShape(M=M, N=N, K=K, E=E, topk=topk, ep=ep, etp=etp)


def test_small_tiles_less_efficient():
    """Paper §2.2.1: partitioned experts lose GEMM efficiency below tile size
    — time-per-flop must be larger for rows < 128."""
    hw = TPU_V5E
    t_small = gemm_time(hw, 64, 4096, 4096) / (2 * 64 * 4096 * 4096)
    t_big = gemm_time(hw, 1024, 4096, 4096) / (2 * 1024 * 4096 * 4096)
    assert t_small > t_big


def test_optimal_split_grows_with_M():
    """Paper Fig. 8: when M grows, the optimal comm allocation (here: finer
    N-decomposition) grows or stays equal, never shrinks."""
    prev = 0
    for M in (1024, 4096, 16384, 65536):
        n = choose_n_col(TPU_V5E, shape(M))
        assert n >= prev, (M, n, prev)
        prev = n


def test_optimal_split_depends_on_bandwidth():
    """Paper Fig. 14: on a bandwidth-poor cluster (L20/PCIe) the same shape
    needs a less aggressive decomposition than on the fast fabric."""
    s = shape(16384)
    n_fast = choose_n_col(H100_NVL, s)
    n_slow = choose_n_col(L20_PCIE, s)
    assert n_fast >= n_slow


def test_dispatch_balance_scales_with_ep():
    """More EP groups → smaller chunks and more hops; the per-chunk balance
    ratio (hop/compute) is shape-invariant but total exposure shifts."""
    t8 = layer_times(TPU_V5E, shape(8192, ep=8))
    t4 = layer_times(TPU_V5E, shape(8192, ep=4))
    assert t4["t_chunk_compute"] > t8["t_chunk_compute"]  # bigger chunks
    assert t4["t_hop"] > t8["t_hop"]


def test_adaptive_cache_tunes_and_caches(tmp_path):
    calls = []

    def measure(cfg):
        calls.append(cfg["n_col_blocks"])
        return abs(cfg["n_col_blocks"] - 3) + 1.0      # best at 3

    cache = AdaptiveCache(str(tmp_path / "cache.json"))
    s = shape(4096)
    best = cache.tune(s, TPU_V5E,
                      [{"n_col_blocks": n} for n in (1, 2, 3, 4)], measure)
    assert best["n_col_blocks"] == 3
    n_calls = len(calls)
    # second call: cache hit, no re-measurement
    best2 = cache.tune(s, TPU_V5E,
                       [{"n_col_blocks": n} for n in (1, 2, 3, 4)], measure)
    assert best2["n_col_blocks"] == 3 and len(calls) == n_calls
    # persisted
    cache2 = AdaptiveCache(str(tmp_path / "cache.json"))
    assert cache2.get(s, TPU_V5E)["n_col_blocks"] == 3
