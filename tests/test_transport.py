"""Transport equivalence: naive / coarse / comet / dense must be numerically
identical (same routing, same outputs) — single-device here, multi-device
(8 simulated hosts, EP×ETP hybrids, gradients) via the selftest subprocess."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.moe_layer import moe_ffn, moe_schema, pack_expert_weights
from repro.parallel.mesh import AxisCtx
from tests.conftest import run_selftest


def _problem(E=8, d=64, f=32, B=2, S=16, k=2, seed=0):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, d_model=d)
    mcfg = dataclasses.replace(cfg.moe, num_experts=E, d_expert=f, top_k=k,
                               capacity_factor=float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    full = {
        "w_gate": jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.05,
    }
    params = {"router": jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1,
              "experts": {kk: v[None] for kk, v in full.items()}}
    x = jax.random.normal(ks[4], (B, S, d), jnp.float32)
    return cfg, mcfg, params, x


@pytest.mark.parametrize("impl", ["naive", "comet", "coarse", "dense"])
def test_single_device_impls_match_dense(impl):
    cfg, mcfg, params, x = _problem()
    ref_m = dataclasses.replace(mcfg, impl="naive")
    y_ref, aux_ref = moe_ffn(cfg, ref_m, params, x, AxisCtx())
    m = dataclasses.replace(mcfg, impl=impl)
    y, aux = moe_ffn(cfg, m, params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_n_col_blocks_invariance():
    """The layer-1 N-decomposition granularity must not change values."""
    cfg, mcfg, params, x = _problem(d=64)
    outs = []
    for n_col in (1, 2, 4):
        m = dataclasses.replace(mcfg, impl="comet", n_col_blocks=n_col)
        y, _ = moe_ffn(cfg, m, params, x, AxisCtx(), n_col=n_col)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_capacity_drops_affect_all_impls_identically():
    cfg, mcfg, params, x = _problem()
    tight = dataclasses.replace(mcfg, capacity_factor=0.5)
    ys = []
    for impl in ("naive", "comet", "coarse"):
        m = dataclasses.replace(tight, impl=impl)
        y, _ = moe_ffn(cfg, m, params, x, AxisCtx())
        ys.append(np.asarray(y))
    np.testing.assert_allclose(ys[0], ys[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ys[0], ys[2], rtol=1e-5, atol=1e-6)


def test_grad_flows_through_router_and_experts():
    cfg, mcfg, params, x = _problem()
    m = dataclasses.replace(mcfg, impl="comet")

    def loss(p):
        y, aux = moe_ffn(cfg, m, p, x, AxisCtx())
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    for k, v in g["experts"].items():
        assert float(jnp.max(jnp.abs(v))) > 0, k
    assert float(jnp.max(jnp.abs(g["router"]))) > 0


def test_pack_expert_weights_layout():
    E, d, f, ep, etp = 4, 8, 6, 2, 2
    w = jnp.arange(E * d * f, dtype=jnp.float32).reshape(E, d, f)
    packed = pack_expert_weights({"w_up": w}, ep, etp)["w_up"]
    assert packed.shape == (4, 2, 8, 3)
    # rank r = g*etp + t owns experts [g*E_loc:(g+1)*E_loc], cols [t*f_loc:...]
    np.testing.assert_array_equal(np.asarray(packed[0]),
                                  np.asarray(w[0:2, :, 0:3]))
    np.testing.assert_array_equal(np.asarray(packed[1]),
                                  np.asarray(w[0:2, :, 3:6]))
    np.testing.assert_array_equal(np.asarray(packed[3]),
                                  np.asarray(w[2:4, :, 3:6]))


# ---------------------------------------------------------------------------
# multi-device (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multidevice_equivalence_and_grads():
    """EP/ETP hybrids × impls (incl. comet ring_group=2) × seq-shard: fwd,
    aux and grads match the single-device oracle; plus full mesh train steps
    on two archs."""
    r = run_selftest(devices=8)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-4000:]}\nERR:\n{r.stderr[-2000:]}"
    assert "FAILURES" not in r.stdout
    assert "comet-rg2" in r.stdout          # the ring_group knob is covered


@pytest.mark.slow
def test_sp_residual_matches_on_mesh():
    """sp_residual (Megatron-SP residual stream) must not change loss or
    grads — checked per family on an 8-device mesh."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, dataclasses
import jax.numpy as jnp
from repro.configs.base import get_config
from repro.parallel.compat import make_mesh, use_mesh
from repro.models import lm
from repro.parallel.sharding import make_ctx
for arch in ("mamba2-780m-smoke", "phi3-medium-14b-smoke",
             "granite-moe-3b-a800m-smoke", "jamba-v0.1-52b-smoke"):
    cfg0 = get_config(arch)
    mesh = make_mesh((2, 4), ("data", "model"))
    ctx = make_ctx(cfg0, mesh)
    params = lm.init_params(cfg0, jax.random.PRNGKey(0), ctx)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg0.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                          cfg0.vocab_size)}
    outs = {}
    for sp in (False, True):
        cfg = dataclasses.replace(cfg0, sp_residual=sp)
        with use_mesh(mesh):
            loss, _ = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b, ctx))(params, batch)
            g = jax.jit(jax.grad(
                lambda p: lm.loss_fn(cfg, p, batch, ctx)[0]))(params)
        outs[sp] = (float(loss), g)
    assert abs(outs[True][0] - outs[False][0]) < 1e-5, arch
    for a, b in zip(jax.tree_util.tree_leaves(outs[True][1]),
                    jax.tree_util.tree_leaves(outs[False][1])):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-4, (arch, err)
    print("OK", arch)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert r.stdout.count("OK") == 4


@pytest.mark.slow
def test_pad_heads_matches_on_mesh():
    """attn.pad_heads (head-count padding for TP divisibility) must be exact:
    dummy heads see zero K/V and are dropped pre-o-projection."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, dataclasses
import jax.numpy as jnp
from repro.configs.base import get_config
from repro.parallel.compat import make_mesh, use_mesh
from repro.models import lm
from repro.parallel.sharding import make_ctx
for arch in ("phi3-medium-14b-smoke", "qwen2-0.5b-smoke"):
    cfg0 = get_config(arch)
    mesh = make_mesh((1, 8), ("data", "model"))  # 4 heads % 8 != 0 -> pads
    ctx = make_ctx(cfg0, mesh)
    params = lm.init_params(cfg0, jax.random.PRNGKey(0), ctx)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg0.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg0.vocab_size)}
    outs = {}
    for pad in (False, True):
        cfg = dataclasses.replace(
            cfg0, attn=dataclasses.replace(cfg0.attn, pad_heads=pad))
        with use_mesh(mesh):
            loss, _ = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b, ctx))(params, batch)
            g = jax.jit(jax.grad(
                lambda p: lm.loss_fn(cfg, p, batch, ctx)[0]))(params)
        outs[pad] = (float(loss), g)
    assert abs(outs[True][0] - outs[False][0]) < 1e-5, arch
    for a, b in zip(jax.tree_util.tree_leaves(outs[True][1]),
                    jax.tree_util.tree_leaves(outs[False][1])):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4, arch
    print("OK", arch)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert r.stdout.count("OK") == 2
