"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the real single CPU device; multi-device checks run in
subprocesses (launch/selftest.py) with their own flags."""
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_selftest(devices: int, case: str = "all", timeout: int = 900):
    """Run the multi-device selftest in a subprocess; returns CompletedProcess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest",
         "--devices", str(devices), "--case", case],
        capture_output=True, text=True, timeout=timeout, env=env)
