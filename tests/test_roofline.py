"""Roofline layer: analytic kernel-boundary formulas, model FLOPs, and an
end-to-end analyze() on a real compiled function; hypothesis properties for
the simulator's physical sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.analysis import roofline as RL
from repro.analysis.simulator import (H100_NVL, MoEShape, sim_comet,
                                      sim_megatron, sim_tutel)
from repro.configs.base import LM_SHAPES, ShapeConfig, get_config

SET = settings(max_examples=20, deadline=None)


def test_model_flops_train_matches_6nd():
    cfg = get_config("qwen2-0.5b")
    shape = LM_SHAPES["train_4k"]
    tokens = shape.global_batch * shape.seq_len
    assert RL.model_flops(cfg, shape) == pytest.approx(
        6.0 * cfg.param_count() * tokens)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("mixtral-8x7b")
    shape = LM_SHAPES["train_4k"]
    dense_equiv = 6.0 * cfg.param_count() * shape.global_batch * shape.seq_len
    got = RL.model_flops(cfg, shape)
    assert got < 0.5 * dense_equiv            # top-2 of 8 experts
    assert got == pytest.approx(
        6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len)


def test_flash_kernel_bytes_scales():
    cfg = get_config("qwen2-0.5b")
    t = RL.flash_kernel_bytes(cfg, LM_SHAPES["train_4k"])
    p = RL.flash_kernel_bytes(cfg, ShapeConfig("p", 4096, 256, "prefill"))
    assert t == pytest.approx(4 * p)          # train = fwd+remat+bwd(2)
    d = RL.flash_kernel_bytes(cfg, LM_SHAPES["decode_32k"])
    # decode reads the whole KV cache once per token per layer
    a = cfg.attn
    want = cfg.n_layers * 2 * 2 * 128 * 32768 * a.n_kv_heads * a.head_dim
    assert d == pytest.approx(want)


def test_ssd_kernel_bytes_only_for_ssm():
    assert RL.ssd_kernel_bytes(get_config("qwen2-0.5b"),
                               LM_SHAPES["train_4k"]) == 0.0
    assert RL.ssd_kernel_bytes(get_config("mamba2-780m"),
                               LM_SHAPES["train_4k"]) > 0.0
    # jamba: 28 of 32 layers are mamba
    j = RL.ssd_kernel_bytes(get_config("jamba-v0.1-52b"),
                            LM_SHAPES["train_4k"])
    m = RL.ssd_kernel_bytes(get_config("mamba2-780m"), LM_SHAPES["train_4k"])
    assert j > 0 and m > 0


def test_analyze_end_to_end_on_compiled_fn():
    def f(x, w):
        return jnp.tanh(x @ w) @ w
    x = jnp.zeros((256, 256))
    c = jax.jit(f).lower(x, x).compile()
    r = RL.analyze(c, n_chips=1)
    assert r["hlo_flops_per_device"] >= 2 * 2 * 256 ** 3 * 0.99
    assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["collective_bytes_per_device"] == 0.0


# ---------------------------------------------------------------------------
# simulator physical-sanity properties
# ---------------------------------------------------------------------------

def _shape(M, E=8, topk=2, ep=8):
    return MoEShape(M=M, N=4096, K=14336, E=E, topk=topk, ep=ep, etp=1)


@given(M=st.sampled_from([1024, 4096, 16384, 65536]))
@SET
def test_sim_hiding_fraction_bounded(M):
    for fn in (sim_comet, sim_tutel, sim_megatron):
        r = fn(H100_NVL, _shape(M))
        assert 0.0 <= r["overlapped"] <= r["comm"] + 1e-12
        assert r["total"] > 0


@given(M=st.sampled_from([1024, 2048, 8192, 32768]))
@SET
def test_sim_total_monotone_in_M(M):
    for fn in (sim_comet, sim_tutel, sim_megatron):
        a = fn(H100_NVL, _shape(M))["total"]
        b = fn(H100_NVL, _shape(2 * M))["total"]
        assert b > a


@given(topk=st.integers(1, 8))
@SET
def test_sim_total_monotone_in_topk(topk):
    a = sim_comet(H100_NVL, _shape(16384, topk=topk))["total"]
    b = sim_comet(H100_NVL, _shape(16384, topk=topk + 1))["total"]
    assert b > a


@given(M=st.sampled_from([2048, 8192, 32768]))
@SET
def test_sim_comet_never_slower_than_serial_parts(M):
    """comet total ≥ max(compute-only, comm-only) — no free lunch."""
    s = _shape(M)
    r = sim_comet(H100_NVL, s)
    assert r["total"] >= r["comm"] - r["overlapped"] - 1e-12
