"""PR 9: topology-aware hierarchical comet ring + low-precision wire format.

Covers the new ``comet_hier`` transport end to end: candidate→legalize→
execute round trip (no re-legalization drift, generalizing the PR 3
fixed-point test to EVERY transport), wire-format rotation determinism,
plan-cache v5→v6 load compatibility, topology cost-model properties, and
single-device + 8-simulated-device numerical equivalence against naive.
"""
import dataclasses
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import adaptive as A
from repro.core import transport as T
from repro.core.moe_layer import moe_ffn
from repro.parallel.mesh import AxisCtx
from tests._hypothesis_compat import given, settings, st


def _problem(E=8, d=64, f=32, B=2, S=16, k=2, seed=0):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, d_model=d)
    mcfg = dataclasses.replace(cfg.moe, num_experts=E, d_expert=f, top_k=k,
                               capacity_factor=float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    full = {
        "w_gate": jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.05,
    }
    params = {"router": jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1,
              "experts": {kk: v[None] for kk, v in full.items()}}
    x = jax.random.normal(ks[4], (B, S, d), jnp.float32)
    return cfg, mcfg, params, x


# ---------------------------------------------------------------------------
# the two-level ring's step bookkeeping
# ---------------------------------------------------------------------------


def test_hier_step_order_covers_every_group_shift():
    """The (node_shift, local_shift) enumeration visits every EP group
    exactly once, local first, inter-node block before the intra tail."""
    for ep, ig in ((8, 4), (8, 2), (16, 4), (6, 3), (8, 1), (4, 4)):
        ig_l = A.legalize_intra_group(ep, ig)
        nn = ep // ig_l
        order = A.hier_step_order(ep, ig)
        assert len(order) == ep
        assert order[0] == (0, 0)
        # bijective onto group shifts
        shifts = {(sn * ig_l + sl) for sn, sl in
                  ((sn % nn, sl % ig_l) for sn, sl in order)}
        assert shifts == set(range(ep))
        classes = A.hier_step_classes(ep, ig)
        assert classes[0] == "local"
        n_intra = sum(c == "intra" for c in classes)
        n_inter = sum(c == "inter" for c in classes)
        assert n_intra == ig_l - 1 and n_inter == ep - ig_l
        # inter block strictly precedes the intra tail
        if n_intra and n_inter:
            assert classes[1:1 + n_inter] == ["inter"] * n_inter
            assert classes[1 + n_inter:] == ["intra"] * n_intra


@given(ep=st.integers(min_value=1, max_value=64),
       ig=st.integers(min_value=-4, max_value=128))
@settings(max_examples=200, deadline=None)
def test_legalize_intra_group_properties(ep, ig):
    out = A.legalize_intra_group(ep, ig)
    assert 1 <= out <= ep and ep % out == 0
    # idempotent, and a fixed point when already legal
    assert A.legalize_intra_group(ep, out) == out


def test_hier_segments_match_flat_counts():
    """The hierarchy re-routes hops, it never adds or removes any."""
    flat = T.comet_ring_segments(8, 2, 4)
    hier = T.comet_hier_segments(8, 2, 4, intra_group=4)
    for k, v in flat.items():
        assert hier[k] == v
    assert hier["intra_hops"] == 3 and hier["inter_hops"] == 4


# ---------------------------------------------------------------------------
# candidate -> legalize -> execute round trip (generalizes the PR 3
# fixed-point test: EVERY emitted (transport, knobs) pair must be a
# legalization fixed point AND run through moe_layer unchanged)
# ---------------------------------------------------------------------------


def test_every_candidate_is_executable_after_legalize():
    cfg, mcfg, params, x = _problem()
    s = A.MoEShape(M=32, N=cfg.d_model, K=mcfg.d_expert, E=8, topk=2,
                   ep=8, etp=1)
    y_ref, _ = moe_ffn(cfg, dataclasses.replace(mcfg, impl="naive"),
                       params, x, AxisCtx())
    cands = list(A.candidate_plans(s, hw=A.H100_CROSSNODE))
    impls = {p.impl for p in cands}
    assert "comet_hier" in impls        # asymmetric preset enumerates hier
    seen = set()
    for p in cands:
        lp = A.legalize_plan(p, s.N, s.ep)
        # no re-legalization drift: what the tuner ranks IS what runs
        assert A.legalize_plan(lp, s.N, s.ep) == lp
        key = (lp.impl, lp.ring_group, lp.n_col_blocks, lp.intra_group,
               lp.wire_dtype, lp.fused_combine)
        if key in seen or lp.gemm_impl != "xla":
            continue                    # pallas variants differ only in
        seen.add(key)                   # backend; interpret mode is slow
        m2 = dataclasses.replace(
            lp.apply(mcfg), gemm_impl="", plan_cache="")
        y, _ = moe_ffn(cfg, m2, params, x, AxisCtx(),
                       n_col=max(1, lp.n_col_blocks))
        assert bool(jnp.all(jnp.isfinite(y))), key
        if lp.wire_dtype == "fp32":
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=str(key))


def test_flat_preset_candidate_stream_has_no_hier():
    s = A.MoEShape(M=4096, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
    impls = {p.impl for p in A.candidate_plans(s, hw=A.TPU_V5E)}
    assert impls == {"naive", "coarse", "comet", "bcast"}


def test_wire_dtype_is_hier_only():
    assert A.Plan("comet", wire_dtype="bf16").validate()
    assert not A.Plan("comet_hier", intra_group=2,
                      wire_dtype="bf16").validate()
    assert A.Plan("comet_hier", wire_dtype="nope").validate()


# ---------------------------------------------------------------------------
# wire format: quantize-once determinism + accumulation dtype
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["bf16", "fp8_e4m3"])
def test_wire_payload_bit_identical_across_rotations(wire):
    """Dispatch chunks are quantized ONCE before any permute, so the bytes
    of chunk c must be identical no matter which ring rotation carries it:
    encode(roll(send)) == roll(encode(send)) bit-for-bit."""
    if wire == "fp8_e4m3" and not T.wire_dtype_supported(wire):
        pytest.skip("no float8_e4m3fn in this jax")
    send = jax.random.normal(jax.random.PRNGKey(3), (8, 2, 4, 16),
                             jnp.float32)
    pay, sc = T._wire_encode(send, wire, per_chunk=True)
    for rot in (1, 3, 5):
        pay_r, sc_r = T._wire_encode(jnp.roll(send, rot, axis=0), wire,
                                     per_chunk=True)
        same = np.array_equal(
            np.asarray(pay_r).view(np.uint8),
            np.asarray(jnp.roll(pay, rot, axis=0)).view(np.uint8))
        assert same, f"rotation {rot} changed {wire} wire bytes"
        if sc is not None:
            np.testing.assert_array_equal(
                np.asarray(sc_r), np.asarray(jnp.roll(sc, rot, axis=0)))


def test_wire_decode_accumulates_in_fp32():
    """fp8 dequant must multiply in fp32 before the output cast — the
    documented fp32-accumulation contract."""
    if not T.wire_dtype_supported("fp8_e4m3"):
        pytest.skip("no float8_e4m3fn in this jax")
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)) * 3.0,
                    jnp.float32)
    pay, sc = T._wire_encode(x, "fp8_e4m3")
    assert pay.dtype == jnp.float8_e4m3fn and sc.dtype == jnp.float32
    out = T._wire_decode(pay, sc, jnp.float32)
    assert out.dtype == jnp.float32
    # e4m3 has a 3-bit mantissa: relative error bounded by 2^-3 per element
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=2 ** -3, atol=1e-6)
    # fp32 and bf16 wires carry no scale
    for wd in ("fp32", "bf16"):
        _, s0 = T._wire_encode(x, wd)
        assert s0 is None


def test_unsupported_wire_dtype_raises():
    cfg, mcfg, params, x = _problem()
    with pytest.raises(ValueError, match="wire_dtype"):
        T.transport_comet_hier(AxisCtx(), jnp.zeros((1, 8, 4, 64)),
                               {k: v[0] for k, v in
                                params["experts"].items()},
                               cfg.activation, wire_dtype="int3")


# ---------------------------------------------------------------------------
# plan cache v5 -> v6
# ---------------------------------------------------------------------------


def test_plan_cache_v5_file_loads_into_v6(tmp_path):
    """A v5 cache FILE (no intra_group/wire_dtype keys, version: 5) loads
    compatibly; a v6 save round-trips the new knobs."""
    key = "tpu_v5e:M1024:N2048:K1408:E8:k2:ep8:etp1"
    v5_entry = {"impl": "comet", "ring_group": 2, "n_col_blocks": 2,
                "gemm_impl": "xla", "fused_combine": True,
                "measured_s": 1e-3, "source": "model"}
    p = tmp_path / "v5.json"
    p.write_text(json.dumps({"version": 5, "plans": {key: v5_entry}}))
    cache = A.PlanCache(str(p))
    assert key in cache.plans
    plan = cache.plans[key]
    assert plan.intra_group == 1 and plan.wire_dtype == "fp32"

    # round-trip a hier plan through a v6 save
    hier = A.Plan("comet_hier", 2, 2, "xla", intra_group=4,
                  wire_dtype="fp8_e4m3", measured_s=2e-3)
    key2 = "h100_crossnode:M1024:N2048:K1408:E8:k2:ep8:etp1"
    cache.plans[key2] = hier
    out = tmp_path / "v6.json"
    cache.path = str(out)
    cache.save()
    raw = json.loads(out.read_text())
    assert raw["version"] == 6
    cache2 = A.PlanCache(str(out))
    assert cache2.plans[key2] == hier
    assert cache2.plans[key] == plan


# ---------------------------------------------------------------------------
# topology cost model
# ---------------------------------------------------------------------------


def test_modeled_exposed_comm_hier_strictly_below_flat():
    """On the asymmetric preset the hierarchical ring's modeled exposed
    comm must be STRICTLY below flat comet — for a comm-bound shape AND a
    compute-bound one (the intra-class tail keeps the last return hop
    cheap even when hops otherwise hide behind GEMMs)."""
    hw = A.H100_CROSSNODE
    shapes = [A.MoEShape(M=2048, N=2048, K=1408, E=64, topk=4, ep=8, etp=1),
              A.MoEShape(M=4096, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)]
    for s in shapes:
        flat = A.fwd_exposed_comm_time(hw, s, A.Plan("comet", 1, 1))
        hier = A.fwd_exposed_comm_time(
            hw, s, A.Plan("comet_hier", 1, 1, intra_group=4))
        assert hier < flat, (s.K, hier, flat)
        # bwd side too
        fb = A.bwd_exposed_comm_time(hw, s, A.Plan("comet", 1, 1))
        hb = A.bwd_exposed_comm_time(
            hw, s, A.Plan("comet_hier", 1, 1, intra_group=4))
        assert hb <= fb, (s.K, hb, fb)


def test_hop_latency_is_a_hardware_field():
    """HOP_LATENCY_S was promoted to Hardware.hop_latency_s; the presets
    keep the historical value and the cost model reads the field."""
    assert A.TPU_V5E.hop_latency_s == A.HOP_LATENCY_S == 5e-6
    hw_slow = dataclasses.replace(A.TPU_V5E, hop_latency_s=50e-6)
    s = A.MoEShape(M=1024, N=2048, K=1408, E=8, topk=2, ep=8, etp=1)
    assert (A.layer_times(hw_slow, s)["t_hop"]
            > A.layer_times(A.TPU_V5E, s)["t_hop"])


def test_flat_presets_price_flat():
    """Default (flat) Hardware descriptors leave the two link classes at
    link_bw, so flat pricing is unchanged by the topology machinery."""
    s = A.MoEShape(M=1024, N=2048, K=1408, E=8, topk=2, ep=8, etp=1)
    hops = A.hop_time_profile(A.TPU_V5E, s, A.Plan("comet", 1, 1))
    t = A.layer_times(A.TPU_V5E, s)["t_hop"]
    assert hops == [0.0] + [t] * 7


def test_tune_cli_unknown_hw_lists_presets():
    import os
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "tune.py"),
         "--hw", "not_a_preset"],
        capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode != 0
    err = r.stderr
    assert "not_a_preset" in err
    for name in ("tpu_v5e", "h100_crossnode"):
        assert name in err
    assert "intra_bw" in err and "intra_group" in err


# ---------------------------------------------------------------------------
# numerics: single-device grid (fast) + the 8-device two-level ring (slow)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire,rtol", [("fp32", 1e-5), ("bf16", 2e-2),
                                       ("fp8_e4m3", 2e-1)])
def test_single_device_hier_matches_naive(wire, rtol):
    if not T.wire_dtype_supported(wire):
        pytest.skip("no float8_e4m3fn in this jax")
    cfg, mcfg, params, x = _problem()
    y_ref, aux_ref = moe_ffn(cfg, dataclasses.replace(mcfg, impl="naive"),
                             params, x, AxisCtx())
    for fc in (False, True):
        m = dataclasses.replace(mcfg, impl="comet_hier", intra_group=4,
                                wire_dtype=wire, n_col_blocks=2,
                                fused_combine=fc)
        y, aux = moe_ffn(cfg, m, params, x, AxisCtx(), n_col=2)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=rtol, atol=rtol * 0.1)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_hier_grad_flows_and_matches_flat_fp32():
    """fp32 wire: the hier custom-VJP backward must agree with the flat
    comet backward (single-device degenerate path shares it) and with XLA
    autodiff over the hier forward."""
    cfg, mcfg, params, x = _problem()

    def loss(p, m):
        y, aux = moe_ffn(cfg, m, p, x, AxisCtx())
        return jnp.sum(y ** 2) + aux

    m_h = dataclasses.replace(mcfg, impl="comet_hier", intra_group=4)
    m_c = dataclasses.replace(mcfg, impl="comet")
    g_h = jax.grad(lambda p: loss(p, m_h))(params)
    g_c = jax.grad(lambda p: loss(p, m_c))(params)
    for k in g_c["experts"]:
        np.testing.assert_allclose(np.asarray(g_h["experts"][k]),
                                   np.asarray(g_c["experts"][k]),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.slow
def test_hier_ring_on_8_devices():
    """The real two-level ring: 8 simulated hosts, intra_group in {2, 4},
    wire formats, ring_group/fused_combine grid, custom-VJP gradients vs
    the local reference — all in a subprocess with its own XLA_FLAGS."""
    import os
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.core.moe_layer import moe_ffn, pack_expert_weights
from repro.parallel.compat import use_mesh
from repro.parallel.mesh import AxisCtx, make_mesh

cfg = get_config("granite-moe-3b-a800m-smoke")
d = cfg.d_model
E, f = 8, 64
ks = jax.random.split(jax.random.PRNGKey(7), 6)
full = {"w_gate": jax.random.normal(ks[0], (E, d, f), jnp.float32)*0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32)*0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32)*0.05}
router_w = jax.random.normal(ks[3], (d, E), jnp.float32)*0.1
x = jax.random.normal(ks[4], (4, 32, d), jnp.float32)
mcfg0 = dataclasses.replace(cfg.moe, num_experts=E, d_expert=f,
                            capacity_factor=float(E), top_k=2)
params_local = {"router": router_w,
                "experts": {k: v[None] for k, v in full.items()}}
mref = dataclasses.replace(mcfg0, impl="naive")
y_ref, _ = jax.jit(lambda xx: moe_ffn(cfg, mref, params_local, xx,
                                      AxisCtx()))(x)
mesh = make_mesh((1, 8), ("data", "model"))
ep, etp = 8, 1
ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
              ep=ep, etp=etp)
packed = pack_expert_weights(full, ep, etp)
params = {"router": router_w, "experts": packed}
fails = []
for ig in (2, 4):
    for rg in (1, 2):
        for fc in (False, True):
            for wd in ("fp32", "bf16"):
                m2 = dataclasses.replace(
                    mcfg0, impl="comet_hier", ring_group=rg,
                    n_col_blocks=2, intra_group=ig, wire_dtype=wd,
                    fused_combine=fc)
                with use_mesh(mesh):
                    y, _ = jax.jit(
                        lambda xx: moe_ffn(cfg, m2, params, xx, ctx))(x)
                err = float(jnp.max(jnp.abs(y - y_ref)))
                err /= float(jnp.max(jnp.abs(y_ref))) + 1e-9
                tol = 2e-5 if wd == "fp32" else 2e-2
                if not err < tol:
                    fails.append(f"ig{ig} rg{rg} fc{int(fc)} {wd}: {err}")

def loss(p, m2, c):
    y, aux = moe_ffn(cfg, m2, p, x, c)
    return jnp.sum(y**2) + aux

m_h = dataclasses.replace(mcfg0, impl="comet_hier", intra_group=4,
                          ring_group=2, n_col_blocks=2, fused_combine=True)
with use_mesh(mesh):
    g_h = jax.jit(jax.grad(lambda p: loss(p, m_h, ctx)))(params)
g_local = jax.jit(jax.grad(lambda p: loss(p, mref, AxisCtx())))(params_local)
gl_packed = pack_expert_weights(
    {k: v[0] for k, v in g_local["experts"].items()}, ep, etp)
for k in packed:
    e = float(jnp.max(jnp.abs(g_h["experts"][k] - gl_packed[k])))
    s = float(jnp.max(jnp.abs(gl_packed[k]))) + 1e-9
    if not e / s < 5e-5:
        fails.append(f"grad[{k}]: {e/s}")
# ETP hybrid: ep=4, etp=2, two nodes of two groups
ep2, etp2 = 4, 2
ctx2 = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
               ep=ep2, etp=etp2)
packed2 = pack_expert_weights(full, ep2, etp2)
params2 = {"router": router_w, "experts": packed2}
m2 = dataclasses.replace(mcfg0, impl="comet_hier", intra_group=2,
                         n_col_blocks=2)
with use_mesh(mesh):
    y, _ = jax.jit(lambda xx: moe_ffn(cfg, m2, params2, xx, ctx2))(x)
err = float(jnp.max(jnp.abs(y - y_ref)))
err /= float(jnp.max(jnp.abs(y_ref))) + 1e-9
if not err < 2e-5:
    fails.append(f"etp2: {err}")
assert not fails, fails
print("HIER_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0 and "HIER_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-2000:]
