"""Adaptive transport autotuner: plan-cache round-trip, application inside
moe_layer, the explicit-override escape hatch, analytical fallback, the
JAX version-compat shim, and the tuner CLI."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import adaptive as A
from repro.core import transport as T
from repro.core.moe_layer import moe_ffn
from repro.parallel.mesh import AxisCtx


def _problem(E=8, d=128, f=64, B=2, S=16, k=2, seed=0):
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, d_model=d)
    mcfg = dataclasses.replace(cfg.moe, num_experts=E, d_expert=f, top_k=k,
                               capacity_factor=float(E))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    full = {
        "w_gate": jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.05,
    }
    params = {"router": jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1,
              "experts": {kk: v[None] for kk, v in full.items()}}
    x = jax.random.normal(ks[4], (B, S, d), jnp.float32)
    return cfg, mcfg, params, x


# ---------------------------------------------------------------------------
# plan cache round-trip + application in moe_layer
# ---------------------------------------------------------------------------


def test_plan_cache_roundtrip_and_moe_layer_pickup(tmp_path, monkeypatch):
    """tune → save → load → moe_ffn resolves and RUNS the cached plan."""
    cfg, mcfg, params, x = _problem()
    path = str(tmp_path / "plans.json")
    toks = x.shape[0] * x.shape[1]
    s = A.plan_shape(mcfg, cfg.d_model, toks, 1, 1)

    # deterministic fake measure: comet rg1 nc4 wins
    def measure(plan):
        if plan.impl == "comet" and plan.n_col_blocks == 4 \
                and plan.ring_group == 1:
            return 1.0
        return 2.0 + plan.n_col_blocks

    cache = A.PlanCache(path)
    cands = list(A.candidate_plans(s, max_col_blocks=4))
    # the smoke d_model=128 only admits n_col=1 under the 128-column floor;
    # widen the space explicitly so the round-trip exercises n_col > 1
    cands += [A.Plan("comet", 1, 4), A.Plan("comet", 1, 2)]
    won = A.tune_plan(s, A.TPU_V5E, cache, measure=measure, candidates=cands)
    assert won.impl == "comet" and won.n_col_blocks == 4
    assert won.source == "measured" and won.measured_s == 1.0
    assert os.path.exists(path)

    # reload from disk: identical plan
    re = A.PlanCache(path)
    assert re.get(s, A.TPU_V5E) == won

    # moe_ffn picks it up: transport_comet must receive the cached n_col
    seen = {}
    real = T.transport_comet

    def spy(ctx, send, w, act, n_col_blocks=1, ring_group=1, **kw):
        seen["n_col"] = n_col_blocks
        seen["ring_group"] = ring_group
        return real(ctx, send, w, act, n_col_blocks=n_col_blocks,
                    ring_group=ring_group, **kw)

    monkeypatch.setattr(T, "transport_comet", spy)
    import repro.core.moe_layer as ML
    monkeypatch.setattr(ML.T, "transport_comet", spy)
    m2 = dataclasses.replace(mcfg, impl="naive", plan_cache=path)
    y, aux = moe_ffn(cfg, m2, params, x, AxisCtx())
    assert seen == {"n_col": 4, "ring_group": 1}   # plan overrode impl=naive
    y_ref, aux_ref = moe_ffn(cfg, dataclasses.replace(mcfg, impl="comet"),
                             params, x, AxisCtx())
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_corrupt_cache_files_start_empty(tmp_path):
    """A truncated, garbage, or future-versioned plan cache must warn and
    start empty (retune) instead of killing the run; a good cache written
    afterwards round-trips normally."""
    good = A.PlanCache(str(tmp_path / "good.json"))
    s = A.MoEShape(M=64, N=128, K=64, E=4, topk=2, ep=1, etp=1)
    good.put(s, A.TPU_V5E, A.Plan("comet", 1, 2), save=True)
    blob = open(str(tmp_path / "good.json")).read()

    # truncated mid-file (torn write without the atomic rename)
    trunc = tmp_path / "trunc.json"
    trunc.write_text(blob[:len(blob) // 2])
    with pytest.warns(UserWarning, match="unreadable"):
        cache = A.PlanCache(str(trunc))
    assert cache.plans == {}

    # outright garbage
    garbage = tmp_path / "garbage.json"
    garbage.write_text("\x00\xffnot json at all{{{")
    with pytest.warns(UserWarning, match="unreadable"):
        assert A.PlanCache(str(garbage)).plans == {}

    # a future format version must not be silently misread
    future = tmp_path / "future.json"
    future.write_text('{"version": %d, "plans": {"k": {"impl": "comet"}}}'
                      % (A.PLAN_CACHE_VERSION + 1))
    with pytest.warns(UserWarning, match="version"):
        assert A.PlanCache(str(future)).plans == {}

    # one mangled entry is skipped; the healthy ones survive
    import json
    raw = json.loads(blob)
    key = next(iter(raw["plans"]))
    raw["plans"]["bad1"] = {"impl": "comet", "n_col_blocks": "not-an-int",
                            "unknown_field": 1}
    raw["plans"]["bad2"] = ["not", "a", "dict"]
    mixed = tmp_path / "mixed.json"
    mixed.write_text(json.dumps(raw))
    with pytest.warns(UserWarning, match="malformed"):
        cache = A.PlanCache(str(mixed))
    assert key in cache.plans and len(cache.plans) == 1

    # the empty caches stay usable: put + save + reload round-trips
    cache = A.PlanCache(str(trunc))  # warns again; we only need the object
    cache.put(s, A.TPU_V5E, A.Plan("comet", 1, 2), save=True)
    assert A.PlanCache(str(trunc)).get(s, A.TPU_V5E).impl == "comet"


def test_plan_override_escape_hatch(tmp_path):
    """plan_override pins the explicit knobs even with a cache configured."""
    cfg, mcfg, params, x = _problem()
    path = str(tmp_path / "plans.json")
    toks = x.shape[0] * x.shape[1]
    s = A.plan_shape(mcfg, cfg.d_model, toks, 1, 1)
    cache = A.PlanCache(path)
    cache.put(s, A.TPU_V5E, A.Plan("coarse", 1, 1, measured_s=1e-6,
                                   source="measured"))
    m2 = dataclasses.replace(mcfg, plan_cache=path, plan_override=True)
    assert not A.plan_lookup_enabled(m2)
    assert A.resolve_plan(m2, cfg.d_model, toks, 1, 1) is None
    m3 = dataclasses.replace(m2, plan_override=False)
    got = A.resolve_plan(m3, cfg.d_model, toks, 1, 1)
    assert got is not None and got.impl == "coarse"


def test_missing_cache_falls_back_to_model(tmp_path):
    """A configured-but-absent cache file must resolve analytically and the
    layer must still run."""
    cfg, mcfg, params, x = _problem()
    path = str(tmp_path / "never_written.json")
    m2 = dataclasses.replace(mcfg, plan_cache=path)
    toks = x.shape[0] * x.shape[1]
    plan = A.resolve_plan(m2, cfg.d_model, toks, 1, 1)
    assert plan is not None and plan.source == "model"
    y, _ = moe_ffn(cfg, m2, params, x, AxisCtx())
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# measured tuning loop (real executions, single device)
# ---------------------------------------------------------------------------


def test_measured_tuning_roundtrip(tmp_path):
    cfg, mcfg, params, x = _problem()
    path = str(tmp_path / "measured.json")
    ctx = AxisCtx()
    calls = []
    inner = A.make_timing_measure(cfg, mcfg, params, x, ctx, iters=1,
                                  warmup=1)

    def measure(plan):
        calls.append(plan)
        return inner(plan)

    toks = x.shape[0] * x.shape[1]
    s = A.plan_shape(mcfg, cfg.d_model, toks, 1, 1)
    cache = A.PlanCache(path)
    plan = A.tune_plan(s, A.TPU_V5E, cache, measure=measure)
    assert plan.source == "measured" and plan.measured_s > 0
    assert len(calls) >= 3                       # several candidates timed
    n = len(calls)
    again = A.tune_plan(s, A.TPU_V5E, cache, measure=measure)
    assert again == plan and len(calls) == n     # cache hit, no re-measure


# ---------------------------------------------------------------------------
# simulator-backed tuning: comet wins a bandwidth-bound shape
# ---------------------------------------------------------------------------


def test_tuned_comet_beats_naive_bandwidth_bound():
    """qwen2-moe-2.7b-like shape (small d_expert, many experts, topk=4):
    communication-heavy per flop — the tuned plan must be comet and its
    modeled latency no worse than the non-overlapped naive baseline."""
    s = A.MoEShape(M=16384, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    for hw in (A.TPU_V5E, A.H100_NVL):
        plan = A.tune_plan(s, hw)
        t_plan = A.modeled_plan_time(hw, s, plan)
        t_naive = A.modeled_plan_time(hw, s, A.Plan("naive"))
        assert t_plan <= t_naive, (hw.name, t_plan, t_naive)
        assert plan.impl == "comet", (hw.name, plan)


def test_candidate_space_legal():
    s = A.MoEShape(M=4096, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
    cands = list(A.candidate_plans(s))
    impls = {p.impl for p in cands}
    assert impls == {"naive", "coarse", "comet", "bcast"}
    for p in cands:
        if p.impl == "comet":
            assert s.N % p.n_col_blocks == 0
            assert s.N // p.n_col_blocks >= 128
            assert s.ep % p.ring_group == 0


# ---------------------------------------------------------------------------
# latency phases (PR 4): phase-qualified keys, fwd-only serving objectives
# ---------------------------------------------------------------------------


def test_phase_qualified_keys_and_objectives(tmp_path):
    """decode/prefill plans live under phase-qualified keys with fwd-only
    objectives; the train key stays unqualified (v3 layout)."""
    path = str(tmp_path / "plans.json")
    s = A.MoEShape(M=8, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    cache = A.PlanCache(path)
    pd = A.tune_plan(s, A.TPU_V5E, cache, phase="decode")
    pt = A.tune_plan(s, A.TPU_V5E, cache, phase="train")
    pp = A.tune_plan(s, A.TPU_V5E, cache, phase="prefill")
    assert pd.objective == "decode_latency" and pd.phase == "decode"
    assert pd.t_bwd_s == 0.0                     # no bwd terms at inference
    assert pp.objective == "prefill_tput"
    assert pt.objective == "fwd_bwd" and pt.phase == "train"
    base = A.PlanCache.key(s, A.TPU_V5E)
    assert A.PlanCache.key(s, A.TPU_V5E, "train") == base
    assert A.PlanCache.key(s, A.TPU_V5E, "decode") == base + ":phdecode"
    assert set(cache.plans) == {base, base + ":phdecode", base + ":phprefill"}
    # round-trip preserves the phase entries distinctly
    re = A.PlanCache(path)
    assert re.get(s, A.TPU_V5E, "decode") == pd
    assert re.get(s, A.TPU_V5E) == pt


def test_decode_phase_prefers_latency_transport():
    """Tiny-M decode under the fwd-only latency objective picks bcast (the
    train objective's training-semantics bwd terms no longer penalize it),
    and the tuned decode plan is never slower than naive on the model."""
    s = A.MoEShape(M=8, N=4096, K=1792, E=16, topk=2, ep=8, etp=1)
    plan = A.tune_plan(s, A.TPU_V5E, phase="decode")
    assert plan.impl == "bcast", plan
    t_plan = A.modeled_plan_time(A.TPU_V5E, s, plan)
    t_naive = A.modeled_plan_time(A.TPU_V5E, s, A.Plan("naive"))
    assert t_plan <= t_naive


def test_v3_cache_without_phase_still_loads(tmp_path):
    """A v3 cache file (unqualified keys, no phase field) loads into v4
    code: train-phase lookups resolve it, serving phases fall back to the
    analytical model instead of mis-resolving a train plan."""
    import json
    path = str(tmp_path / "v3.json")
    s = A.MoEShape(M=1024, N=2048, K=1408, E=64, topk=4, ep=8, etp=1)
    key = A.PlanCache.key(s, A.TPU_V5E)
    entry = {"impl": "comet", "ring_group": 2, "n_col_blocks": 4,
             "gemm_impl": "xla", "fused_combine": False,
             "measured_s": 2e-3, "t_bwd_s": 1e-3, "source": "measured",
             "objective": "fwd_bwd"}
    with open(path, "w") as f:
        json.dump({"version": 3, "plans": {key: entry}}, f)
    cache = A.PlanCache(path)
    hit = cache.get(s, A.TPU_V5E, "train")
    assert hit is not None and hit.ring_group == 2
    assert hit.phase == "train"                  # defaulted on load
    assert cache.get(s, A.TPU_V5E, "decode") is None
    # resolve_plan with a decode-phase mcfg falls back analytically
    cfg = get_config("granite-moe-3b-a800m-smoke")
    m2 = dataclasses.replace(cfg.moe, plan_cache=path, plan_phase="decode")
    plan = A.resolve_plan(m2, s.N, s.M, s.ep, s.etp)
    assert plan is not None and plan.source == "model"


def test_serve_engine_threads_decode_phase(tmp_path):
    """ServeEngine's decode step resolves the :phdecode entry, its chunk
    step the :phprefill entry — checked through the step-builder configs."""
    from repro.configs.base import ShapeConfig
    from repro.launch.train_step import (build_decode_step,
                                         build_prefill_chunk_step,
                                         build_prefill_step)
    cfg = get_config("granite-moe-3b-a800m-smoke")
    path = str(tmp_path / "plans.json")
    A.PlanCache(path).save()
    shape = ShapeConfig("s", seq_len=16, global_batch=2, kind="decode")
    # the builders stash the phase on the threaded MoE config; fn closures
    # capture cfg, so inspect via a rebuilt config
    from repro.launch.train_step import _with_plan_cache
    assert _with_plan_cache(cfg, path, phase="decode").moe.plan_phase \
        == "decode"
    assert _with_plan_cache(cfg, path, phase="prefill").moe.plan_phase \
        == "prefill"
    assert _with_plan_cache(cfg, path).moe.plan_phase == "train"
    # and the builders run end to end with a cache configured
    d = build_decode_step(cfg, shape, mesh=None, plan_cache=path)
    c = build_prefill_chunk_step(cfg, shape, mesh=None, plan_cache=path)
    p = build_prefill_step(cfg, shape, mesh=None, plan_cache=path)
    assert d["ctx"] is not None and c["chunk"] == 16 and p["ctx"] is not None


def test_transport_default_gemm_impl_is_static():
    """The mutable GEMM_IMPL ambient global is gone: _impl(None)/""
    resolve to the static "xla" default."""
    assert not hasattr(T, "set_gemm_impl")
    assert not hasattr(T, "GEMM_IMPL")
    assert T._impl(None) == "xla" and T._impl("") == "xla"
    assert T._impl("pallas_fused") == "pallas_fused"
    with pytest.raises(AssertionError):
        T._impl("nope")


# ---------------------------------------------------------------------------
# JAX version-compat shim
# ---------------------------------------------------------------------------


def test_compat_shim_on_installed_jax():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.compat import make_mesh, shard_map, use_mesh

    mesh = make_mesh((1,), ("x",))
    assert tuple(mesh.axis_names) == ("x",)

    f = shard_map(lambda a: jax.lax.psum(jnp.sum(a), "x"), mesh=mesh,
                  in_specs=(P("x"),), out_specs=P(), check_vma=False)
    x = jnp.arange(8.0)
    with use_mesh(mesh):
        y = jax.jit(f)(x)
    assert float(y) == float(x.sum())
    # context manager is re-enterable (fresh object each time)
    with use_mesh(mesh):
        pass


# ---------------------------------------------------------------------------
# tuner CLI
# ---------------------------------------------------------------------------


def test_tune_cli_writes_plan_cache(tmp_path):
    out = str(tmp_path / "plans" / "tpu_v5e.json")
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "tune.py"),
         "--hw", "tpu_v5e", "--out", out, "--M", "1024"],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert os.path.exists(out)
    cache = A.PlanCache(out)
    assert len(cache.plans) >= 4                 # 3 paper models + smoke
    assert all(p.impl in A.TRANSPORTS for p in cache.plans.values())
