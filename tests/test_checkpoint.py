"""Checkpoint manager: atomic commit, gc, restore-with-cast, async errors."""
import os
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8), dtype),
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": [jnp.ones((2,), dtype), jnp.zeros((3,), dtype)]}}


def test_roundtrip_exact():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        t = tree()
        m.save(3, t, wait=True)
        got, step = m.restore(jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_partial_visible():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        m.save(1, tree(), wait=True)
        # a stale tmp dir (simulated crash mid-write) must be invisible
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert m.all_steps() == [1]
        assert m.latest_step() == 1


def test_gc_keeps_last_n():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            m.save(s, tree(), wait=True)
        assert m.all_steps() == [3, 4]


def test_async_save_overlaps_and_completes():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=5)
        for s in range(3):
            m.save(s, tree(s))           # async
        m.wait()
        assert m.all_steps() == [0, 1, 2]


def test_restore_bf16_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        t = tree(dtype=jnp.bfloat16)
        m.save(0, t, wait=True)
        got, _ = m.restore(jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(got)):
            assert b.dtype == a.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_restore_missing_raises():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        with pytest.raises(FileNotFoundError):
            m.restore({"a": jax.ShapeDtypeStruct((1,), jnp.float32)})


def test_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        m.save(0, {"a": jnp.zeros((2, 2))}, wait=True)
        with pytest.raises(ValueError):
            m.restore({"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})
