"""Serving engine: continuous batching (slot scheduler, chunked prefill,
mixed-length exactness), masked batched prefill parity, eos accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.models.common import logits_for
from repro.serving import ServeEngine, stitch_prefill_cache


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen2-0.5b-smoke")
    return ServeEngine(cfg, max_seq=64, batch_size=2, seed=0, chunk=4)


def _greedy_reference(cfg, params, prompt, n):
    """Per-prompt unpadded full-forward greedy continuation (oracle)."""
    seq = list(prompt)
    out = []
    for _ in range(n):
        h, _, _ = lm.forward(cfg, params,
                             {"tokens": jnp.asarray([seq], jnp.int32)})
        tok = int(jnp.argmax(logits_for(h, lm.output_head(cfg, params))[0, -1]))
        out.append(tok)
        seq.append(tok)
    return out


def test_generate_shapes_and_determinism(engine):
    prompts = [[5, 6, 7, 8], [9, 10]]
    r1 = engine.generate(prompts, max_new=8)
    r2 = engine.generate(prompts, max_new=8)
    assert r1.tokens.shape == (2, 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert (r1.tokens >= 0).all()
    assert (r1.tokens < engine.cfg.vocab_size).all()


def test_generate_matches_full_forward_greedy(engine):
    """Engine output token t must equal argmax of the full forward over
    prompt+generated — the incremental-decoding correctness contract.
    MIXED-length prompts: chunked prefill + per-slot decode is exact (the
    old left-padding approximation is gone)."""
    cfg = engine.cfg
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1]]
    res = engine.generate(prompts, max_new=4)
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, engine.params, p, 4)
        assert res.tokens[i].tolist() == want, (i, res.tokens[i], want)


@pytest.mark.slow
def test_more_requests_than_slots_exact(engine):
    """Continuous batching: 4 mixed-length requests through 2 slots — late
    requests are admitted into slots freed mid-decode, and every row still
    matches its unpadded per-prompt reference exactly."""
    cfg = engine.cfg
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1, 9, 6, 8, 3], [5, 6],
               [9, 10, 11, 12, 13, 14, 15, 16, 17]]
    res = engine.generate(prompts, max_new=4)
    assert res.tokens.shape == (4, 4)
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, engine.params, p, 4)
        assert res.tokens[i].tolist() == want, (i, res.tokens[i], want)


def test_late_arrival_reuses_freed_slot():
    """A request submitted MID-DECODE of another lands in the freed slot
    (single-slot engine forces reuse) and still decodes exactly."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=1, seed=0, chunk=8)
    pa, pb = [3, 1, 4, 1, 5], [2, 7, 1, 9]
    ra = eng.submit(pa, max_new=5)
    eng.step()
    eng.step()                                  # A mid-decode, slot 0 busy
    rb = eng.submit(pb, max_new=3)              # late arrival: queued
    assert eng.slot_req[0] is not None and eng.slot_req[0].rid == ra
    eng.run()
    assert eng.finished[rb].slot == -1 and eng.admissions == 2
    for rid, p, n in [(ra, pa, 5), (rb, pb, 3)]:
        want = _greedy_reference(cfg, eng.params, p, n)
        assert eng.finished[rid].tokens == want, (rid, want)


def test_eos_stops_row(engine):
    prompts = [[5, 6, 7], [8, 9, 10]]
    probe = engine.generate(prompts, max_new=3)
    eos = int(probe.tokens[0, 1])          # force an eos we know will occur
    res = engine.generate(prompts, max_new=6, eos_id=eos)
    assert res.lengths[0] <= 1 or (res.tokens[0, :res.lengths[0]] != eos).all()


def test_eos_on_first_decoded_token_frees_slot():
    """A row whose FIRST decoded token (from prefill logits) is eos reports
    length 0, never enters the decode batch, and its slot is immediately
    reusable by the next queued request."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=1, seed=0, chunk=8)
    p = [3, 1, 4, 1, 5]
    first = _greedy_reference(cfg, eng.params, p, 1)[0]
    ra = eng.submit(p, max_new=4, eos_id=first)      # eos == token 0
    rb = eng.submit([2, 7, 1], max_new=2)
    eng.run()
    a, b = eng.finished[ra], eng.finished[rb]
    assert a.length == 0 and a.tokens == [first]
    assert b.tokens == _greedy_reference(cfg, eng.params, [2, 7, 1], 2)
    res_like = a.ttft_s
    assert res_like >= 0.0


def test_generate_lengths_eos_on_first_token(engine):
    """generate() batch accounting when a row finishes on token 0:
    lengths == 0, tokens[0] == eos, remaining columns zero-padded."""
    cfg = engine.cfg
    p = [5, 6, 7, 8]
    first = _greedy_reference(cfg, engine.params, p, 1)[0]
    res = engine.generate([p, [9, 10]], max_new=4, eos_id=first)
    assert res.lengths[0] == 0
    assert res.tokens[0, 0] == first
    assert (res.tokens[0, 1:] == 0).all()


def test_chunk_legalized_to_max_seq_divisor():
    """A chunk that does not divide max_seq would let the tail chunk's
    cache write clamp past max_seq and silently corrupt earlier chunks'
    K/V — the engine legalizes the chunk to a divisor, and a prompt whose
    chunk grid would have overrun still decodes exactly."""
    cfg = get_config("qwen2-0.5b-smoke")
    eng = ServeEngine(cfg, max_seq=40, batch_size=1, seed=0, chunk=16)
    assert 40 % eng.chunk == 0 and eng.chunk <= 16
    p = list(range(1, 37))                       # 36 tokens + 4 new = 40
    res = eng.generate([p], max_new=4)
    want = _greedy_reference(cfg, eng.params, p, 4)
    assert res.tokens[0].tolist() == want, (res.tokens[0], want)


def test_chunk_size_invariance(engine):
    """The chunk geometry must not change results: chunk=4 vs a chunk
    covering the whole prompt produce identical tokens."""
    cfg = engine.cfg
    prompts = [[3, 1, 4, 1, 5, 9, 2], [2, 7]]
    res_small = engine.generate(prompts, max_new=4)
    eng_big = ServeEngine(cfg, params=engine.params, max_seq=64,
                          batch_size=2, chunk=16)
    res_big = eng_big.generate(prompts, max_new=4)
    np.testing.assert_array_equal(res_small.tokens, res_big.tokens)


def test_masked_batched_prefill_plus_slot_decode_parity():
    """The lm-level contract behind the engine: masked LEFT-padded batched
    prefill + stitched cache + per-row-position decode (rope_pos = real
    position, kv_start = pad offset) matches the unpadded per-prompt
    reference exactly for mixed lengths."""
    cfg = get_config("qwen2-0.5b-smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[3, 1, 4, 1, 5, 9, 2, 6], [2, 7, 1]]
    plen = max(len(p) for p in prompts)
    toks = np.zeros((2, plen), np.int32)
    mask = np.zeros((2, plen), bool)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p
        mask[i, plen - len(p):] = True
    logits, pre = lm.prefill(cfg, params, {"tokens": jnp.asarray(toks),
                                           "mask": jnp.asarray(mask)})
    cache = lm.init_cache(cfg, 2, 32)
    cache = stitch_prefill_cache(cfg, cache, pre, plen)
    pads = np.array([plen - len(p) for p in prompts], np.int32)
    seqs = [list(p) for p in prompts]
    nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    for t in range(4):
        for i in range(2):
            want = _greedy_reference(cfg, params, seqs[i], 1)[0]
            assert int(nxt[i]) == want, (i, t, int(nxt[i]), want)
            seqs[i].append(want)
        lg, cache = lm.decode_step(
            cfg, params, cache, jnp.asarray(nxt[:, None]),
            jnp.int32(plen + t),                        # cache write index
            rope_pos=jnp.asarray(plen + t - pads),      # real positions
            kv_start=jnp.asarray(pads))                 # pad exclusion
        nxt = np.asarray(jnp.argmax(lg, axis=-1), np.int32)


@pytest.mark.slow
def test_ssm_mixed_length_serving_exact():
    """Mamba-2: chunked prefill continuation (conv window + SSD state) and
    masked tail must reproduce the per-prompt reference for mixed lengths."""
    cfg = get_config("mamba2-780m-smoke")
    eng = ServeEngine(cfg, max_seq=64, batch_size=2, seed=1, chunk=4)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [5, 6]]
    res = eng.generate(prompts, max_new=4)
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, eng.params, p, 4)
        assert res.tokens[i].tolist() == want, (i, res.tokens[i], want)


@pytest.mark.slow
def test_moe_arch_serves_mixed_lengths_nodrop_exact():
    """MoE arch through the continuous engine. Under no-drop capacity the
    mixed-length run is exact vs the per-prompt reference; with finite
    capacity_factor routing drops may differ between batch compositions —
    the standard capacity-batched MoE caveat, now the ONLY remaining
    serving approximation."""
    cfg = get_config("granite-moe-3b-a800m-smoke")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1, chunk=4)
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8]]
    res = eng.generate(prompts, max_new=3)
    assert res.tokens.shape == (2, 3)
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, eng.params, p, 3)
        assert res.tokens[i].tolist() == want, (i, res.tokens[i], want)


@pytest.mark.slow
def test_hybrid_arch_serves():
    """Jamba (hybrid attn+ssm+moe) runs through chunked prefill + slot
    decode; shape/finiteness only (capacity routing differs per chunk)."""
    cfg = get_config("jamba-v0.1-52b-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1, chunk=8)
    res = eng.generate([[1, 2, 3], [4]], max_new=4)
    assert res.tokens.shape == (2, 4)
    assert (res.tokens < cfg.vocab_size).all()


def test_ssm_arch_serves():
    cfg = get_config("mamba2-780m-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1)
    res = eng.generate([[1, 2, 3, 4], [5, 6]], max_new=4)
    assert res.tokens.shape == (2, 4)
    assert (res.tokens < cfg.vocab_size).all()
