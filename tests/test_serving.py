"""Serving engine: batched generate correctness, eos handling, cache stitch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.serving import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen2-0.5b-smoke")
    return ServeEngine(cfg, max_seq=64, batch_size=2, seed=0)


def test_generate_shapes_and_determinism(engine):
    prompts = [[5, 6, 7, 8], [9, 10]]
    r1 = engine.generate(prompts, max_new=8)
    r2 = engine.generate(prompts, max_new=8)
    assert r1.tokens.shape == (2, 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert (r1.tokens >= 0).all()
    assert (r1.tokens < engine.cfg.vocab_size).all()


def test_generate_matches_full_forward_greedy(engine):
    """Engine output token t must equal argmax of the full forward over
    prompt+generated — the incremental-decoding correctness contract.
    Equal-length prompts: left-padding has no mask (documented engine
    limitation), so parity is exact only without padding."""
    cfg = engine.cfg
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1, 9, 6]]
    res = engine.generate(prompts, max_new=4)
    for i, p in enumerate(prompts):
        seq = list(p)
        for t in range(4):
            batch = {"tokens": jnp.asarray([seq], jnp.int32)}
            h, _, _ = lm.forward(cfg, engine.params, batch)
            from repro.models.common import logits_for
            logits = logits_for(h, lm.output_head(cfg, engine.params))
            want = int(jnp.argmax(logits[0, -1]))
            assert res.tokens[i, t] == want, (i, t, res.tokens[i], want)
            seq.append(want)


def test_eos_stops_row(engine):
    prompts = [[5, 6, 7], [8, 9, 10]]
    probe = engine.generate(prompts, max_new=3)
    eos = int(probe.tokens[0, 1])          # force an eos we know will occur
    res = engine.generate(prompts, max_new=6, eos_id=eos)
    assert res.lengths[0] <= 1 or (res.tokens[0, :res.lengths[0]] != eos).all()


def test_moe_arch_serves():
    cfg = get_config("granite-moe-3b-a800m-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1)
    res = eng.generate([[1, 2, 3], [4]], max_new=4)
    assert res.tokens.shape == (2, 4)


def test_ssm_arch_serves():
    cfg = get_config("mamba2-780m-smoke")
    eng = ServeEngine(cfg, max_seq=32, batch_size=2, seed=1)
    res = eng.generate([[1, 2, 3, 4], [5, 6]], max_new=4)
    assert res.tokens.shape == (2, 4)
    assert (res.tokens < cfg.vocab_size).all()
