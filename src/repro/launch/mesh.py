"""Production mesh factory (assignment-mandated shape)."""
from __future__ import annotations

import jax

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:      # pre-AxisType jax
        axis_types = None
    return compat.make_mesh(shape, axes, axis_types=axis_types)
