"""Serving launcher CLI: continuous-batching engine (chunked prefill +
slot-based decode), with tuned per-phase plans.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m-smoke \
      --batch 4 --max-new 16 --plan-cache plans/tpu_v5e.json --plan-hw tpu_v5e
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (requests in flight)")
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests to serve (default: --batch)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk size (0 = min(32, max_seq))")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache page size in tokens (0 = contiguous "
                         "per-slot regions); legalized to a divisor of "
                         "--max-seq")
    ap.add_argument("--pages", type=int, default=0,
                    help="total KV pages incl. the null page (0 = parity "
                         "capacity: slots * max_seq/page + 1)")
    ap.add_argument("--admit-k", type=int, default=0,
                    help="max requests admitted per step in one stacked "
                         "chunk call (0 = up to every free slot)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-cache", default=None,
                    help="tuned plan cache JSON; phase-qualified entries "
                         "(:phprefill/:phdecode) schedule the serving steps")
    ap.add_argument("--plan-hw", default="",
                    help="hardware key for plan lookup (default tpu_v5e)")
    args = ap.parse_args()

    from repro.configs.base import get_config
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    eng = ServeEngine(cfg, max_seq=args.max_seq, batch_size=args.batch,
                      seed=args.seed, plan_cache=args.plan_cache,
                      plan_hw=args.plan_hw, chunk=args.chunk,
                      page_size=args.page_size, n_pages=args.pages,
                      admit_k=args.admit_k)
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or args.batch
    prompts = [rng.integers(1, cfg.vocab_size, size=args.prompt_len).tolist()
               for _ in range(n_req)]
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    for i, row in enumerate(res.tokens):
        print(f"req{i}: {row.tolist()}")
    tput = (res.prefill_tokens + eng.decode_tokens) / dt
    print(f"{res.prefill_tokens} prefill toks + {res.decode_steps} decode "
          f"steps ({eng.decode_tokens} toks) across {args.batch} slots / "
          f"{n_req} requests in {dt:.2f}s  ({tput:.0f} tok/s)")
    print(f"phase timings: prefill {eng.prefill_s:.2f}s "
          f"({eng.prefill_tokens / max(eng.prefill_s, 1e-9):.0f} tok/s), "
          f"decode {eng.decode_s:.2f}s "
          f"({eng.decode_s / max(eng.decode_steps, 1) * 1e3:.1f} ms/step)")
    if eng.paged:
        print(f"paged cache: page {eng.page_size} toks, "
              f"{eng.n_pages - 1} usable pages "
              f"({eng.free_pages} free after drain), "
              f"{eng.admissions} admissions")


if __name__ == "__main__":
    main()
