"""Serving launcher CLI: continuous-batching engine (chunked prefill +
slot-based decode) with tuned per-phase plans, or — with ``--disagg`` —
the router/worker topology (prefill workers + decode workers with
paged-page KV migration, serving/disagg.py).

Engine flags are grouped (engine / paging / robustness / chaos / disagg)
and map 1:1 onto :class:`repro.serving.EngineConfig`; the benchmarks
build engines through the same config, so the CLI and the gates can
never construct different engines from the same knobs.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m-smoke \
      --batch 4 --max-new 16 --plan-cache plans/tpu_v5e.json --plan-hw tpu_v5e

  # disaggregated: 1 prefill worker + 2 decode workers, paged handoff,
  # mixed-length Poisson trace + robustness summary
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
      --disagg --page-size 8 --prefill-workers 1 --decode-workers 2 \
      --requests 12 --chaos 0.02
"""
import argparse
import time
from collections import Counter

import numpy as np


def _make_trace(cfg, args, mixed: bool):
    """The request workload: fixed-length prompts for the classic mode,
    mixed lengths (0.5x–2x --prompt-len) for the disagg trace — the
    prefill-heavy mix is what the topology exists for."""
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or args.batch
    lens = (rng.integers(max(1, args.prompt_len // 2),
                         2 * args.prompt_len + 1, size=n_req)
            if mixed else np.full(n_req, args.prompt_len))
    return [rng.integers(1, cfg.vocab_size, size=int(n)).tolist()
            for n in lens]


def _print_engine_summary(eng, prompts, args, dt):
    n_prefill = sum(len(p) for p in prompts)
    tput = (n_prefill + eng.decode_tokens) / dt
    print(f"{n_prefill} prefill toks + {eng.decode_steps} decode "
          f"steps ({eng.decode_tokens} toks) across {args.batch} slots / "
          f"{len(prompts)} requests in {dt:.2f}s  ({tput:.0f} tok/s)")
    print(f"phase timings: prefill {eng.prefill_s:.2f}s "
          f"({eng.prefill_tokens / max(eng.prefill_s, 1e-9):.0f} tok/s), "
          f"decode {eng.decode_s:.2f}s "
          f"({eng.decode_s / max(eng.decode_steps, 1) * 1e3:.1f} ms/step)")
    if eng.paged:
        print(f"paged cache: page {eng.page_size} toks, "
              f"{eng.n_pages - 1} usable pages "
              f"({eng.free_pages} free after drain), "
              f"{eng.admissions} admissions")
    if eng.faults is not None or eng.failures or eng.expired or \
            eng.quarantined or eng.shed:
        statuses = Counter(r.status.value for r in eng.finished.values())
        print(f"robustness: statuses {dict(statuses)}, "
              f"{eng.failures} step failures / {eng.recoveries} recoveries, "
              f"{eng.quarantined} quarantined, {eng.expired} expired, "
              f"{eng.shed} shed, "
              f"{len(eng.monitor.flagged)} straggler steps")
        if eng.faults is not None:
            print(f"injected: {eng.faults.counts}")


def _print_router_summary(router, prompts, dt):
    s = router.summary()
    ec = router.econfig
    total = s["prefill_tokens"] + s["decode_tokens"]
    print(f"disagg: {ec.prefill_workers} prefill x "
          f"{ec.prefill_slots or ec.batch_size} slots -> "
          f"{ec.decode_workers} decode x "
          f"{ec.decode_slots or ec.batch_size} slots, "
          f"page {router.page_size} toks")
    print(f"{s['prefill_tokens']} prefill toks + {s['decode_tokens']} "
          f"decode toks / {len(prompts)} requests in {dt:.2f}s "
          f"({total / dt:.0f} tok/s)")
    print(f"migration: {s['migrations']} handoffs, {s['pages_moved']} "
          f"pages moved, {s['remigrations']} re-migrations, "
          f"{s['duplicate_handoffs']} duplicates dropped")
    ttfts = [r.ttft_s for r in router.finished.values()
             if r.first_token_t > 0]
    if ttfts:
        print(f"ttft: mean {np.mean(ttfts) * 1e3:.1f} ms, "
              f"p99 {np.percentile(ttfts, 99) * 1e3:.1f} ms")
    statuses = Counter(r.status.value for r in router.finished.values())
    print(f"robustness: statuses {dict(statuses)}, "
          f"{s['failures']} worker failures / {s['recoveries']} "
          f"recoveries, {s['quarantined']} quarantined, "
          f"{s['expired']} expired, {s['shed']} shed")
    for name, w in s["per_worker"].items():
        print(f"  {name}: {w}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    wl = ap.add_argument_group("workload")
    wl.add_argument("--requests", type=int, default=0,
                    help="total requests to serve (default: --batch)")
    wl.add_argument("--max-new", type=int, default=16)
    wl.add_argument("--prompt-len", type=int, default=12,
                    help="prompt tokens (disagg: mean of a 0.5x-2x mix)")
    from repro.serving import EngineConfig
    EngineConfig.add_cli_args(ap)
    args = ap.parse_args()

    from repro.configs.base import get_config

    cfg = get_config(args.arch)
    ec = EngineConfig.from_cli_args(
        args, chaos_horizon=4 * (args.max_new + args.prompt_len))
    if args.chaos > 0:
        inj = ec.make_faults()
        print(f"chaos: {inj.plan.summary()} over {ec.chaos_horizon} steps "
              f"(seed {args.chaos_seed})")
    eng = ec.build(cfg)
    prompts = _make_trace(cfg, args, mixed=ec.disagg)

    t0 = time.perf_counter()
    rids = [eng.submit(p, max_new=args.max_new) for p in prompts]
    eng.run()
    dt = time.perf_counter() - t0
    for i, rid in enumerate(rids):
        r = eng.finished[rid]
        tag = "" if r.status.value == "ok" else f"  [{r.status.value}]"
        print(f"req{i} (len {len(prompts[i])}): {r.tokens}{tag}")
    if ec.disagg:
        _print_router_summary(eng, prompts, dt)
    else:
        _print_engine_summary(eng, prompts, args, dt)


if __name__ == "__main__":
    main()
