"""Serving launcher CLI: continuous-batching engine (chunked prefill +
slot-based decode), with tuned per-phase plans.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m-smoke \
      --batch 4 --max-new 16 --plan-cache plans/tpu_v5e.json --plan-hw tpu_v5e
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (requests in flight)")
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests to serve (default: --batch)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk size (0 = min(32, max_seq))")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache page size in tokens (0 = contiguous "
                         "per-slot regions); legalized to a divisor of "
                         "--max-seq")
    ap.add_argument("--pages", type=int, default=0,
                    help="total KV pages incl. the null page (0 = parity "
                         "capacity: slots * max_seq/page + 1)")
    ap.add_argument("--admit-k", type=int, default=0,
                    help="max requests admitted per step in one stacked "
                         "chunk call (0 = up to every free slot)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-cache", default=None,
                    help="tuned plan cache JSON; phase-qualified entries "
                         "(:phprefill/:phdecode) schedule the serving steps")
    ap.add_argument("--plan-hw", default="",
                    help="hardware key for plan lookup (default tpu_v5e)")
    # -- robustness knobs ---------------------------------------------------
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request total-latency deadline in seconds "
                         "(expired requests retire with status=expired)")
    ap.add_argument("--ttft-deadline", type=float, default=None,
                    help="per-request first-token deadline in seconds")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded queue depth (0 = unbounded)")
    ap.add_argument("--shed", default="reject",
                    choices=["reject", "deadline"],
                    help="shedding policy when the bounded queue is full: "
                         "reject the new request, or drop the queued "
                         "request with the least deadline slack")
    ap.add_argument("--snapshot-dir", default=None,
                    help="crash-recovery snapshot directory (enables "
                         "periodic snapshot + restore/replay on failure)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="steps between snapshots")
    ap.add_argument("--chaos", type=float, default=0.0,
                    help="inject a seeded Poisson fault trace at this "
                         "per-step rate (crashes + NaN rows + latency "
                         "spikes) to exercise the recovery machinery")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_config
    from repro.serving import FaultInjector, FaultPlan, ServeEngine

    cfg = get_config(args.arch)
    injector = None
    if args.chaos > 0:
        horizon = 4 * (args.max_new + args.prompt_len)
        plan = FaultPlan.poisson(args.chaos_seed, horizon,
                                 crash_rate=args.chaos, nan_rate=args.chaos,
                                 spike_rate=2 * args.chaos)
        injector = FaultInjector(plan)
        print(f"chaos: {plan.summary()} over {horizon} steps "
              f"(seed {args.chaos_seed})")
    eng = ServeEngine(cfg, max_seq=args.max_seq, batch_size=args.batch,
                      seed=args.seed, plan_cache=args.plan_cache,
                      plan_hw=args.plan_hw, chunk=args.chunk,
                      page_size=args.page_size, n_pages=args.pages,
                      admit_k=args.admit_k, max_queue=args.max_queue,
                      shed_policy=args.shed, deadline_s=args.deadline,
                      ttft_deadline_s=args.ttft_deadline,
                      snapshot_dir=args.snapshot_dir,
                      snapshot_every=args.snapshot_every,
                      faults=injector,
                      recover=True if injector is not None else None)
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or args.batch
    prompts = [rng.integers(1, cfg.vocab_size, size=args.prompt_len).tolist()
               for _ in range(n_req)]
    t0 = time.perf_counter()
    rids = [eng.submit(p, max_new=args.max_new) for p in prompts]
    eng.run()
    dt = time.perf_counter() - t0
    reqs = [eng.finished[rid] for rid in rids]
    for i, r in enumerate(reqs):
        tag = "" if r.status.value == "ok" else f"  [{r.status.value}]"
        print(f"req{i}: {r.tokens}{tag}")
    n_prefill = sum(len(p) for p in prompts)
    tput = (n_prefill + eng.decode_tokens) / dt
    print(f"{n_prefill} prefill toks + {eng.decode_steps} decode "
          f"steps ({eng.decode_tokens} toks) across {args.batch} slots / "
          f"{n_req} requests in {dt:.2f}s  ({tput:.0f} tok/s)")
    print(f"phase timings: prefill {eng.prefill_s:.2f}s "
          f"({eng.prefill_tokens / max(eng.prefill_s, 1e-9):.0f} tok/s), "
          f"decode {eng.decode_s:.2f}s "
          f"({eng.decode_s / max(eng.decode_steps, 1) * 1e3:.1f} ms/step)")
    if eng.paged:
        print(f"paged cache: page {eng.page_size} toks, "
              f"{eng.n_pages - 1} usable pages "
              f"({eng.free_pages} free after drain), "
              f"{eng.admissions} admissions")
    if injector is not None or eng.failures or eng.expired or \
            eng.quarantined or eng.shed:
        from collections import Counter
        statuses = Counter(r.status.value for r in eng.finished.values())
        print(f"robustness: statuses {dict(statuses)}, "
              f"{eng.failures} step failures / {eng.recoveries} recoveries, "
              f"{eng.quarantined} quarantined, {eng.expired} expired, "
              f"{eng.shed} shed, "
              f"{len(eng.monitor.flagged)} straggler steps")
        if injector is not None:
            print(f"injected: {injector.counts}")


if __name__ == "__main__":
    main()
