"""Serving launcher CLI: batched prefill + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m-smoke \
      --batch 4 --max-new 16
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_config
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    eng = ServeEngine(cfg, max_seq=args.max_seq, batch_size=args.batch,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=args.prompt_len).tolist()
               for _ in range(args.batch)]
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    for i, row in enumerate(res.tokens):
        print(f"req{i}: {row.tolist()}")
    print(f"{res.prefill_tokens} prefill toks + {res.decode_steps} decode "
          f"steps x{args.batch} in {dt:.2f}s")


if __name__ == "__main__":
    main()
