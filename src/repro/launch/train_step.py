"""jit-compiled step builders: train (grad-accum, AdamW), prefill, decode.

Each builder returns (jitted_fn, in_shardings, out_shardings, abstract_inputs)
so the dry-run can ``.lower().compile()`` without allocating, and the trainer
can run the identical function for real.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import specs as SP
from repro.models import lm
from repro.models.common import specs_from_schema
from repro.optim.adamw import AdamW
from repro.parallel.mesh import AxisCtx
from repro.parallel.sharding import make_ctx, param_specs

Pytree = Any


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _with_plan_cache(cfg: ModelConfig, plan_cache: Optional[str],
                     plan_hw: str = "",
                     phase: str = "train") -> ModelConfig:
    """Thread a tuned-plan cache path + latency phase into the MoE config so
    every moe_ffn under this step resolves its transport schedule from the
    phase-qualified cache entry (decode steps get latency-ranked plans,
    prefill chunk-throughput ones, train fwd+bwd)."""
    if not plan_cache or cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, plan_cache=plan_cache,
                                     plan_hw=plan_hw, plan_override=False,
                                     plan_phase=phase))


def state_specs(cfg: ModelConfig, ctx: AxisCtx, fsdp: bool = True):
    schema = lm.model_schema(cfg, ctx)
    pspecs = param_specs(schema, ctx.mesh, fsdp)
    return {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "count": P()},
        "step": P(),
    }


def abstract_state(cfg: ModelConfig, ctx: AxisCtx):
    params = lm.abstract_params(cfg, ctx)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "params": params,
        "opt": {"m": jax.tree_util.tree_map(f32, params),
                "v": jax.tree_util.tree_map(f32, params),
                "count": jax.ShapeDtypeStruct((), jnp.int32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_fn(cfg: ModelConfig, ctx: AxisCtx, optim: AdamW, accum: int):
    def loss_fn(params, batch):
        return lm.loss_fn(cfg, params, batch, ctx)

    def step(state, batch):
        params = state["params"]
        if accum > 1:
            def mb(carry, b):
                gsum, lsum = carry
                (lo, met), gr = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, gr)
                return (gsum, lsum + lo), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(mb, (zeros, jnp.zeros((), jnp.float32)),
                                            batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = lsum / accum
        else:
            (loss, met), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, stats = optim.update(grads, state["opt"], params)
        # non-finite guard: a NaN/inf loss or grad anywhere (grad_norm
        # covers every leaf) skips the whole update IN-GRAPH — the state is
        # donated, so host-side "don't apply" is not an option. The raw
        # loss still reaches the metrics; the trainer counts skips.
        ok = jnp.isfinite(loss) & jnp.isfinite(stats["grad_norm"])
        keep = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
        new_params = jax.tree_util.tree_map(keep, new_params, params)
        new_opt = jax.tree_util.tree_map(keep, new_opt, state["opt"])
        metrics = {"loss": loss, **stats,
                   "skipped": (1 - ok).astype(jnp.int32)}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + ok.astype(state["step"].dtype)}, \
            metrics

    return step


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                     optim: Optional[AdamW] = None, accum: int = 0,
                     fsdp: bool = True, seq_shard: bool = True,
                     plan_cache: Optional[str] = None, plan_hw: str = "",
                     schedule: str = ""):
    """Returns dict with fn/jitted/in_shardings/abstract inputs.

    ``schedule`` sits beside ``plan_cache``: "" keeps the scanned
    layer-at-a-time forward, "sequential"/"overlap" route the step through
    the block-schedule IR (core/schedule.py; layers unroll — see
    ``lm.forward_scheduled``). Numerics are identical either way."""
    cfg = _with_plan_cache(cfg, plan_cache, plan_hw)
    if schedule:
        cfg = dataclasses.replace(cfg, block_schedule=schedule)
    optim = optim or AdamW()
    accum = accum or SP.TRAIN_ACCUM.get(shape.name, 1)
    ctx = make_ctx(cfg, mesh, seq_shard=seq_shard)
    step = make_train_fn(cfg, ctx, optim, accum)
    dp_axes = ctx.dp_axes if ctx.active else ("pod", "data")
    batch_structs, batch_pspecs = SP.train_batch_specs(cfg, shape, accum,
                                                       dp_axes=dp_axes)

    if mesh is None:
        return {"fn": step, "jit": jax.jit(step, donate_argnums=0),
                "batch_structs": batch_structs, "ctx": ctx, "accum": accum,
                "state_abstract": abstract_state(cfg, ctx)}

    sspecs = state_specs(cfg, ctx, fsdp)
    in_sh = (_named(mesh, sspecs), _named(mesh, batch_pspecs))
    out_sh = (_named(mesh, sspecs), None)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=0)
    return {"fn": step, "jit": jitted, "batch_structs": batch_structs,
            "state_specs": sspecs, "batch_pspecs": batch_pspecs, "ctx": ctx,
            "accum": accum, "state_abstract": abstract_state(cfg, ctx)}


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                       mesh: Optional[Mesh], fsdp: bool = True,
                       plan_cache: Optional[str] = None, plan_hw: str = ""):
    cfg = _with_plan_cache(cfg, plan_cache, plan_hw, phase="prefill")
    ctx = make_ctx(cfg, mesh, seq_shard=True)

    def fn(params, batch):
        return lm.prefill(cfg, params, batch, ctx)

    batch_structs, batch_pspecs = SP.prefill_batch_specs(
        cfg, shape, dp_axes=ctx.dp_axes if ctx.active else ("pod", "data"))
    params_abs = lm.abstract_params(cfg, ctx)
    if mesh is None:
        return {"fn": fn, "jit": jax.jit(fn), "batch_structs": batch_structs,
                "params_abstract": params_abs, "ctx": ctx}
    schema = lm.model_schema(cfg, ctx)
    pspecs = param_specs(schema, mesh, fsdp)
    in_sh = (_named(mesh, pspecs), _named(mesh, batch_pspecs))
    jitted = jax.jit(fn, in_shardings=in_sh)
    return {"fn": fn, "jit": jitted, "batch_structs": batch_structs,
            "params_abstract": params_abs, "param_pspecs": pspecs, "ctx": ctx}


def build_prefill_chunk_step(cfg: ModelConfig, shape: ShapeConfig,
                             mesh: Optional[Mesh], chunk: int = 0,
                             fsdp: bool = True,
                             plan_cache: Optional[str] = None,
                             plan_hw: str = ""):
    """Chunked-prefill step for the continuous-batching engine: one prompt
    chunk (``chunk`` tokens, batch 1; 0 = min(32, seq_len)) against one
    SLOT of the decode cache described by ``shape`` — the SAME
    (global_batch slots, seq_len cache) geometry as ``build_decode_step``,
    so on a mesh both steps compile identical shardings for the donated
    cache they share. The slot index is a traced argument, so a single
    compiled function admits requests into any slot. Prefill-phase plans
    (chunk-throughput objective) resolve from the cache when threaded in."""
    cfg = _with_plan_cache(cfg, plan_cache, plan_hw, phase="prefill")
    ctx = make_ctx(cfg, mesh, seq_shard=False)
    C = chunk or min(32, shape.seq_len)

    if shape.paged:
        def fn(params, cache, tokens, pos_off, valid_len, slot,
               block_tables):
            return lm.prefill_chunk(cfg, params, cache, tokens, pos_off,
                                    valid_len, ctx, slot=slot,
                                    block_tables=block_tables)
    else:
        def fn(params, cache, tokens, pos_off, valid_len, slot):
            return lm.prefill_chunk(cfg, params, cache, tokens, pos_off,
                                    valid_len, ctx, slot=slot)

    cache_abs, cspecs, _tok, _tok_spec = SP.decode_inputs(cfg, shape, ctx)
    params_abs = lm.abstract_params(cfg, ctx)
    tokens = SP.sds((1, C), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    base = {"fn": fn, "cache_abstract": cache_abs, "tokens": tokens,
            "params_abstract": params_abs, "ctx": ctx, "chunk": C,
            "scalar": scalar}
    if mesh is None:
        base["jit"] = jax.jit(fn, donate_argnums=1)
        return base
    schema = lm.model_schema(cfg, ctx)
    pspecs = param_specs(schema, mesh, fsdp)
    cache_sh = _named(mesh, SP.cache_leaf_specs(cache_abs, cspecs))
    rep = NamedSharding(mesh, P())
    in_sh = (_named(mesh, pspecs), cache_sh,
             NamedSharding(mesh, P(None, None)), rep, rep, rep)
    if shape.paged:
        in_sh = in_sh + (NamedSharding(mesh, P(None, None)),)
    out_sh = (NamedSharding(mesh, P(None, None)), cache_sh)
    base["jit"] = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=1)
    base["param_pspecs"] = pspecs
    base["cache_pspecs"] = cspecs
    return base


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                      mesh: Optional[Mesh], fsdp: bool = True,
                      plan_cache: Optional[str] = None, plan_hw: str = ""):
    """Slot-based decode step: per-row positions (every in-flight request at
    its own sequence index), a live-slot mask (retired/free slots emit token
    0 and are ignored by the scheduler), donated cache. Decode-phase plans
    (latency objective) resolve from the cache when one is threaded in."""
    cfg = _with_plan_cache(cfg, plan_cache, plan_hw, phase="decode")
    ctx = make_ctx(cfg, mesh, seq_shard=False)
    B = shape.global_batch

    if shape.paged:
        def fn(params, cache, tokens, pos, live, block_tables):
            logits, new_cache = lm.decode_step(cfg, params, cache, tokens,
                                               pos, ctx,
                                               block_tables=block_tables)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            next_tok = jnp.where(live[:, None], next_tok, 0)
            return next_tok, logits, new_cache
    else:
        def fn(params, cache, tokens, pos, live):
            logits, new_cache = lm.decode_step(cfg, params, cache, tokens,
                                               pos, ctx)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            next_tok = jnp.where(live[:, None], next_tok, 0)
            return next_tok, logits, new_cache

    cache_abs, cspecs, tok, tok_spec = SP.decode_inputs(cfg, shape, ctx)
    params_abs = lm.abstract_params(cfg, ctx)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    live = jax.ShapeDtypeStruct((B,), jnp.bool_)
    if mesh is None:
        return {"fn": fn, "jit": jax.jit(fn, donate_argnums=1),
                "cache_abstract": cache_abs, "tok": tok,
                "params_abstract": params_abs, "ctx": ctx, "pos": pos,
                "live": live}
    schema = lm.model_schema(cfg, ctx)
    pspecs = param_specs(schema, mesh, fsdp)
    cache_sh = _named(mesh, SP.cache_leaf_specs(cache_abs, cspecs))
    row_spec = NamedSharding(mesh, P(*tok_spec[:1]))
    in_sh = (_named(mesh, pspecs), cache_sh, NamedSharding(mesh, tok_spec),
             row_spec, row_spec)
    if shape.paged:
        in_sh = in_sh + (NamedSharding(mesh, P(tok_spec[0], None)),)
    out_sh = (NamedSharding(mesh, tok_spec), None, cache_sh)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=1)
    return {"fn": fn, "jit": jitted, "cache_abstract": cache_abs, "tok": tok,
            "params_abstract": params_abs, "param_pspecs": pspecs,
            "cache_pspecs": cspecs, "ctx": ctx, "pos": pos, "live": live}
