import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# (Tests may shrink the placeholder fleet via REPRO_DRYRUN_DEVICES.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh, the jitted step
(train_step for train shapes, prefill/serve_step for inference shapes),
lowers it against ShapeDtypeStruct inputs (no allocation), compiles, and
prints ``memory_analysis()`` + ``cost_analysis()`` + the three roofline
terms. Failures (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the exit code reflects them.

Usage:
  python -m repro.launch.dryrun --arch all --shape all            # single-pod
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape train_4k \
      --impl comet --out experiments/dryrun
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(cfg, shape, mesh, n_chips, impl, out_dir=None, verbose=True):
    import dataclasses

    import jax

    from repro.analysis import roofline as RL
    from repro.configs.base import shape_applicable
    from repro.launch.train_step import (build_decode_step,
                                         build_prefill_step,
                                         build_train_step)

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}

    if cfg.moe is not None and impl:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                               impl=impl))
    t0 = time.time()
    if shape.kind == "train":
        built = build_train_step(cfg, shape, mesh)
        args = (built["state_abstract"], built["batch_structs"])
        jitted = built["jit"]
    elif shape.kind == "prefill":
        built = build_prefill_step(cfg, shape, mesh)
        args = (built["params_abstract"], built["batch_structs"])
        jitted = built["jit"]
    else:  # decode: serve_step = one new token against a seq_len KV cache
        built = build_decode_step(cfg, shape, mesh)
        args = (built["params_abstract"], built["cache_abstract"],
                built["tok"], built["pos"], built["live"])
        jitted = built["jit"]

    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    elapsed = time.time() - t0

    report = RL.analyze(compiled, n_chips, cfg=cfg, shape=shape)
    report["status"] = "ok"
    report["compile_s"] = elapsed
    report["impl"] = impl or (cfg.moe.impl if cfg.moe else "-")
    name = f"{cfg.name}/{shape.name}/{n_chips}chips"
    if verbose:
        print(RL.fmt_report(name, report))
        print(f"  compile: {elapsed:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{cfg.name}_{shape.name}_{n_chips}_{report['impl']}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(report, f, indent=1)
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--impl", default="",
                    help="MoE transport override: naive|coarse|comet")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--include-paper-archs", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.configs.base import (ASSIGNED_ARCHS, LM_SHAPES, PAPER_ARCHS,
                                    get_config)
    from repro.launch.mesh import make_production_mesh

    n_dev = len(jax.devices())
    archs = ([args.arch] if args.arch != "all" else
             list(ASSIGNED_ARCHS) +
             (PAPER_ARCHS if args.include_paper_archs else []))
    shapes = [args.shape] if args.shape != "all" else list(LM_SHAPES)
    meshes = []
    if args.both_meshes or not args.multi_pod:
        meshes.append(("single-pod", False))
    if args.both_meshes or args.multi_pod:
        meshes.append(("multi-pod", True))

    failures, cells = [], 0
    for mesh_name, multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        n_chips = mesh.devices.size
        print(f"\n#### mesh {mesh_name} {dict(mesh.shape)} "
              f"({n_chips} chips) ####")
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                shape = LM_SHAPES[shape_name]
                cells += 1
                try:
                    r = run_cell(cfg, shape, mesh, n_chips, args.impl,
                                 args.out or None)
                    if r["status"] == "skipped":
                        print(f"== {arch}/{shape_name} == SKIPPED: "
                              f"{r['reason'][:90]}")
                except Exception as e:
                    failures.append((mesh_name, arch, shape_name))
                    print(f"== {arch}/{shape_name} == FAILED: {e}")
                    traceback.print_exc()

    print(f"\n{cells} cells, {len(failures)} failures")
    for f in failures:
        print("  FAIL:", *f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
