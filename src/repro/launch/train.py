"""Training launcher CLI.

Single host (CPU/debug):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \
      --steps 50 --batch 4 --seq 64

Multi-host TPU fleet: run the same command per host under your cluster
runner; jax.distributed.initialize() picks coordinator/host ids from the TPU
environment. --mesh data,model sizes must multiply to the global device
count. Checkpoints are restart-safe (see training/trainer.py).
"""
import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="", help="e.g. 16,16 (data,model); "
                    "empty = single device, no mesh")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--impl", default="",
                    help="MoE transport override: naive|coarse|comet")
    ap.add_argument("--plan-cache", default="",
                    help="tuned adaptive-transport plan cache (JSON); the "
                         "train step resolves fwd+bwd MoE schedules from it")
    ap.add_argument("--plan-hw", default="",
                    help="hardware key for plan lookup (default tpu_v5e)")
    ap.add_argument("--sp-residual", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (TPU fleet)")
    args = ap.parse_args()

    import jax
    if args.distributed:
        jax.distributed.initialize()

    from repro.configs.base import ShapeConfig, get_config
    from repro.parallel.mesh import make_mesh
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.impl and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl=args.impl))
    if args.sp_residual:
        cfg = dataclasses.replace(cfg, sp_residual=True)

    mesh = None
    if args.mesh:
        sizes = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[:len(sizes)] if len(sizes) <= 2 else \
            ("pod", "data", "model")
        mesh = make_mesh(sizes, axes)

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         plan_cache=args.plan_cache, plan_hw=args.plan_hw)
    out = Trainer(cfg, shape, mesh, tcfg).run(args.steps)
    ls = [m["loss"] for m in out["metrics"]]
    print(f"final_step={out['final_step']} restarts={out['restarts']} "
          f"loss {ls[0]:.4f} -> {ls[-1]:.4f}" if ls else "no steps run")


if __name__ == "__main__":
    main()
