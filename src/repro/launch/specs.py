"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape) cell.

Used by the dry-run (no allocation) and by the data pipeline (shape contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.parallel.mesh import AxisCtx
from repro.parallel.sharding import cache_specs

# default grad-accumulation per train shape (microbatch count)
TRAIN_ACCUM = {"train_4k": 8, "smoke": 1}
WHISPER_DEC_RATIO = 4          # decoder text length = seq_len // ratio
WHISPER_ENC_LEN_DECODE = 4096  # encoder frames cached during decode


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, accum: int,
                      dp_axes: Tuple[str, ...] = ("pod", "data")
                      ) -> Tuple[Dict[str, Any], Dict[str, P]]:
    """Returns (ShapeDtypeStructs, PartitionSpecs) for one train batch.
    Leading dims: (accum, microbatch, seq)."""
    B, S = shape.global_batch, shape.seq_len
    while accum > 1 and B % accum:
        accum -= 1
    mb = B // accum
    dt_tok = jnp.int32
    dp = dp_axes if len(dp_axes) != 1 else dp_axes[0]

    def shp(*tail):
        return (accum, mb) + tail if accum > 1 else (mb,) + tail

    def spec(*tail):
        lead = (None, dp) if accum > 1 else (dp,)
        return P(*(lead + tail))

    structs: Dict[str, Any] = {}
    specs: Dict[str, P] = {}
    if cfg.family == "vlm":
        structs["embeds"] = sds(shp(S, cfg.d_model), cfg.compute_dtype)
        specs["embeds"] = spec(None, None)
        structs["labels"] = sds(shp(S), dt_tok)
        specs["labels"] = spec(None)
    elif cfg.n_enc_layers:                          # whisper
        Sd = max(64, S // WHISPER_DEC_RATIO)
        structs["frames"] = sds(shp(S, cfg.d_model), cfg.compute_dtype)
        specs["frames"] = spec(None, None)
        structs["tokens"] = sds(shp(Sd), dt_tok)
        specs["tokens"] = spec(None)
        structs["labels"] = sds(shp(Sd), dt_tok)
        specs["labels"] = spec(None)
    else:
        structs["tokens"] = sds(shp(S), dt_tok)
        specs["tokens"] = spec(None)
        structs["labels"] = sds(shp(S), dt_tok)
        specs["labels"] = spec(None)
    return structs, specs


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                        dp_axes: Tuple[str, ...] = ("pod", "data")):
    s = dataclasses.replace(shape, kind="train")
    structs, specs = train_batch_specs(cfg, s, accum=1, dp_axes=dp_axes)
    structs.pop("labels", None)
    specs.pop("labels", None)
    return structs, specs


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, ctx: AxisCtx):
    """Returns (cache_structs, cache_specs_tree, token_struct, token_spec).
    shape.page_size > 0 switches to the paged block-table cache layout
    (shared K/V page pools; see lm.init_paged_cache)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.paged:
        from repro.parallel.sharding import paged_cache_specs
        cache = jax.eval_shape(
            lambda: lm.init_paged_cache(cfg, B, shape.pages_total(),
                                        shape.page_size))
        cspecs = paged_cache_specs(cfg, ctx, B)
    else:
        enc_len = WHISPER_ENC_LEN_DECODE if cfg.n_enc_layers else 0
        cache = jax.eval_shape(
            lambda: lm.init_cache(cfg, B, S, enc_len=enc_len))
        cspecs = cache_specs(cfg, ctx, B, S, enc_len=enc_len)
    # init_cache entries: attach specs per leaf by structure
    tok = sds((B, 1), jnp.int32)
    dp_ok = B % max(1, ctx.dp_size) == 0 and B > 1
    tok_spec = P(ctx.dp_axes if dp_ok else None, None)
    return cache, cspecs, tok, tok_spec


def cache_leaf_specs(cache_structs, cspecs):
    """Expand per-entry dict specs to match the full cache pytree."""
    out = []
    for entry, spec_entry in zip(cache_structs, cspecs):
        out.append({k: spec_entry[k] for k in entry})
    return tuple(out)
