"""Multi-device self-test: runs under forced host-platform device count.

Invoked as:  python -m repro.launch.selftest --devices 8 [--case all]

Exit code 0 iff every check passes. Used by the pytest suite via subprocess
(the main test process must keep seeing 1 device).
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--case", default="all")
    args = ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    import dataclasses
    from repro.configs import get_config
    from repro.core.moe_layer import moe_ffn, pack_expert_weights
    from repro.models.common import init_from_schema
    from repro.core.moe_layer import moe_schema
    from repro.parallel.compat import use_mesh
    from repro.parallel.mesh import AxisCtx, choose_ep, make_mesh

    failures = []

    def check(name, cond, detail=""):
        status = "PASS" if cond else "FAIL"
        print(f"[{status}] {name} {detail}")
        if not cond:
            failures.append(name)

    # ---- build a small MoE problem ----------------------------------------
    cfg = get_config("granite-moe-3b-a800m-smoke")
    d = cfg.d_model
    E = 8
    f = 64
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 8)
    full = {
        "w_gate": jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.05,
    }
    router_w = jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1

    Bsz, Ssz = 4, 32
    x = jax.random.normal(ks[4], (Bsz, Ssz, d), jnp.float32)

    # no-drop capacity so local and sharded paths agree exactly
    mcfg0 = dataclasses.replace(
        cfg.moe, num_experts=E, d_expert=f, capacity_factor=float(E),
        top_k=2)

    # ---- reference: local single-device -----------------------------------
    params_local = {"router": router_w,
                    "experts": {k: v[None] for k, v in full.items()}}
    mref = dataclasses.replace(mcfg0, impl="naive")
    y_ref, aux_ref = jax.jit(
        lambda xx: moe_ffn(cfg, mref, params_local, xx, AxisCtx()))(x)

    n_dev = args.devices
    for dp, mp in [(n_dev // 4, 4), (n_dev // 8, 8)] if n_dev >= 8 else [(1, n_dev)]:
        if dp < 1:
            continue
        mesh = make_mesh((dp, mp), ("data", "model"))
        ep_candidates = {choose_ep(E, mp)[0]}
        if mp >= 2:
            ep_candidates.add(mp // 2)          # forces etp == 2
        for ep_req in sorted(c for c in ep_candidates if c >= 1):
            ep, etp = ep_req, mp // ep_req
            if E % ep or f % etp:
                continue
            ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
                          ep=ep, etp=etp, seq_shard=False)
            packed = pack_expert_weights(full, ep, etp)
            params = {"router": router_w, "experts": packed}
            for impl, rg in (("naive", 1), ("comet", 1), ("comet", 2),
                             ("coarse", 1)):
                for seq_shard in (False, True):
                    if seq_shard and Ssz % mp:
                        continue
                    c2 = dataclasses.replace(ctx, seq_shard=seq_shard)
                    m2 = dataclasses.replace(mcfg0, impl=impl, ring_group=rg,
                                             n_col_blocks=2 if impl == "comet" else 0)
                    with use_mesh(mesh):
                        y, aux = jax.jit(
                            lambda xx: moe_ffn(cfg, m2, params, xx, c2))(x)
                    err = float(jnp.max(jnp.abs(y - y_ref)))
                    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
                    tag = (f"dp{dp} mp{mp} ep{ep} etp{etp} {impl}"
                           f"{'-rg' + str(rg) if rg > 1 else ''} "
                           f"sp={int(seq_shard)}")
                    check(f"moe_fwd {tag}", err / scale < 2e-5,
                          f"rel_err={err/scale:.2e}")
                    check(f"moe_aux {tag}",
                          abs(float(aux - aux_ref)) < 1e-4,
                          f"aux={float(aux):.5f} ref={float(aux_ref):.5f}")

            # ---- gradient equivalence (comet custom-VJP ring vs naive vs
            # local) — "comet" covers the default backward ring, "cometbwd"
            # the streamed variant (ring_group=2, n_col=2, fused_combine)
            def loss(params, m2, c):
                y, aux = moe_ffn(cfg, m2, params, x, c)
                return jnp.sum(y ** 2) + aux

            m_naive = dataclasses.replace(mcfg0, impl="naive")
            m_comet = dataclasses.replace(mcfg0, impl="comet")
            m_cbwd = dataclasses.replace(mcfg0, impl="comet", ring_group=2,
                                         n_col_blocks=2, fused_combine=True)
            with use_mesh(mesh):
                g_naive = jax.jit(jax.grad(lambda p: loss(p, m_naive, ctx)))(params)
                g_comet = jax.jit(jax.grad(lambda p: loss(p, m_comet, ctx)))(params)
                g_cbwd = jax.jit(jax.grad(lambda p: loss(p, m_cbwd, ctx)))(params)
            g_local = jax.jit(jax.grad(
                lambda p: loss(p, m_naive, AxisCtx())))(params_local)
            gl_packed = pack_expert_weights(
                {k: v[0] for k, v in g_local["experts"].items()}, ep, etp)

            for k in packed:
                e1 = float(jnp.max(jnp.abs(g_naive["experts"][k] - gl_packed[k])))
                e2 = float(jnp.max(jnp.abs(g_comet["experts"][k] - gl_packed[k])))
                e3 = float(jnp.max(jnp.abs(g_cbwd["experts"][k] - gl_packed[k])))
                s = float(jnp.max(jnp.abs(gl_packed[k]))) + 1e-9
                check(f"moe_grad[{k}] ep{ep} etp{etp} naive-vs-local", e1 / s < 5e-5,
                      f"rel={e1/s:.2e}")
                check(f"moe_grad[{k}] ep{ep} etp{etp} comet-vs-local", e2 / s < 5e-5,
                      f"rel={e2/s:.2e}")
                check(f"moe_grad[{k}] ep{ep} etp{etp} cometbwd-vs-local",
                      e3 / s < 5e-5, f"rel={e3/s:.2e}")
            er = float(jnp.max(jnp.abs(g_naive["router"] - g_local["router"])))
            sr = float(jnp.max(jnp.abs(g_local["router"]))) + 1e-9
            check(f"moe_grad[router] ep{ep} etp{etp}", er / sr < 5e-5,
                  f"rel={er/sr:.2e}")

        # ---- decode (S=1) bcast path ---------------------------------------
        x1 = x[:, :1]
        y1_ref, _ = jax.jit(
            lambda xx: moe_ffn(cfg, mref, params_local, xx, AxisCtx()))(x1)
        ep, etp = choose_ep(E, mp)
        ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
                      ep=ep, etp=etp)
        packed = pack_expert_weights(full, ep, etp)
        params = {"router": router_w, "experts": packed}
        m2 = dataclasses.replace(mcfg0, impl="comet")
        with use_mesh(mesh):
            y1, _ = jax.jit(lambda xx: moe_ffn(cfg, m2, params, xx, ctx))(x1)
        err = float(jnp.max(jnp.abs(y1 - y1_ref)))
        s = float(jnp.max(jnp.abs(y1_ref))) + 1e-9
        check(f"moe_decode_bcast mp{mp} ep{ep} etp{etp}", err / s < 2e-5,
              f"rel={err/s:.2e}")

    # ---- full train-step on mesh for a couple of smoke archs ---------------
    if args.case in ("all", "train"):
        from repro.launch.train_step import build_train_step  # noqa
        from repro.training.trainer import smoke_mesh_train
        for arch in ("granite-moe-3b-a800m-smoke", "jamba-v0.1-52b-smoke"):
            try:
                loss0, loss1 = smoke_mesh_train(arch, n_dev)
                check(f"mesh_train {arch}",
                      np.isfinite(loss0) and np.isfinite(loss1) and loss1 < loss0 + 1.0,
                      f"loss {loss0:.3f} -> {loss1:.3f}")
            except Exception as e:  # pragma: no cover
                import traceback
                traceback.print_exc()
                check(f"mesh_train {arch}", False, str(e)[:200])

    print(f"\n{'OK' if not failures else 'FAILURES'}: {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
