"""MoE transports: how the shared tensor moves between ranks.

All functions take the dispatch buffer ``send`` of shape (ep, E_loc, C, d)
(chunked by destination expert-group — the paper's M-dimension decomposition)
and local expert weights, and return ``recv_out`` of shape (ep, E_loc, C, d)
holding this rank's tokens' expert outputs, plus the ring rotation needed by
``combine``.

  naive   — single all_to_all in, grouped MLP, single all_to_all back
            (Megatron-style non-overlapped baseline).
  coarse  — FasterMoE/Tutel-style: token range split into ``n`` slices, each
            slice runs the naive schedule; slices pipeline at kernel level.
            (Implemented at the layer level in moe_layer.py.)
  comet   — the paper: decomposed collectives. Dispatch is ep-1 ring steps of
            collective-permute; the chunk at ICI distance 0 (local) computes
            first (paper's "sort by source rank / local tiles first"), each
            chunk's expert MLP is fused GEMM1→act→GEMM2 and its *output is
            returned immediately* via a reverse permute — both directions
            overlap the next chunk's compute (XLA async collective-permute).
            Layer-1's N-dimension decomposition: the second GEMM produces
            ``n_col_blocks`` column blocks, each combined/returned as soon as
            it completes (paper Fig. 6 column-major GroupGEMM traversal).
  bcast   — decode-shape path: tokens replicated over the model axis, each
            rank computes its experts, psum combines. No dispatch collective.

ETP (> 1) shards every expert's hidden dim across ``etp`` adjacent ranks of
the model axis; chunks are replicated across the etp subgroup (collectives
use axis_index_groups), partial GEMM2 outputs psum over the subgroup.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import activate, is_glu
from repro.parallel.mesh import AxisCtx


# ---------------------------------------------------------------------------
# Expert MLP (GroupGEMM over local experts)
# ---------------------------------------------------------------------------

# GroupGEMM backend:
#   "xla"          — einsum; XLA fuses + reorders freely.
#   "pallas"       — kernels/grouped_gemm.py with Comet traversal orders (on
#                    TPU this pins tile completion order; layer-1 uses
#                    order="n_major" per Fig. 6).
#   "pallas_fused" — kernels/fused_mlp.py: GEMM1→activation→GEMM2 in one
#                    kernel, hidden activations VMEM-resident (no
#                    (E_loc, R, f_loc) HBM round trip).
GEMM_BACKENDS = ("xla", "pallas", "pallas_fused")
GEMM_IMPL = "xla"


def set_gemm_impl(name: str):
    global GEMM_IMPL
    assert name in GEMM_BACKENDS, name
    GEMM_IMPL = name


def _gg(rows, w, order="expert_major"):
    if GEMM_IMPL == "pallas":
        from repro.kernels import ops
        return ops.grouped_gemm(rows, w, order=order)
    # one contraction covers both layouts — (E,R,d)@(E,d,f) and
    # (E,R,f)@(E,f,d) differ only in axis naming
    return jnp.einsum("erk,ekn->ern", rows, w)


def expert_gemm1(rows, w, activation: str):
    """rows: (E_loc, R, d) -> h: (E_loc, R, f_loc)."""
    if is_glu(activation):
        gate = _gg(rows, w["w_gate"])
        up = _gg(rows, w["w_up"])
        return activate(activation, gate, up)
    up = _gg(rows, w["w_up"])
    return activate(activation, None, up)


def expert_gemm2(h, w, col_slice: Optional[Tuple[int, int]] = None):
    """h: (E_loc, R, f_loc) -> (E_loc, R, d_block)."""
    wd = w["w_down"]
    if col_slice is not None:
        wd = lax.dynamic_slice_in_dim(wd, col_slice[0], col_slice[1], axis=2)
    return _gg(h, wd, order="n_major")


def _mlp_out(rows, w, activation: str):
    """Full-width expert MLP under the active backend: one fused kernel call
    (hidden stays in VMEM) or the two-GEMM pipeline (hidden through HBM)."""
    if GEMM_IMPL == "pallas_fused":
        from repro.kernels import ops
        return ops.fused_mlp(rows, w, activation)
    return expert_gemm2(expert_gemm1(rows, w, activation), w)


def mlp_col_blocks(rows, w, activation: str, n_col: int, blk: int):
    """Per-column-block expert MLP outputs — the layer-1 producer interface
    for the comet schedule. Returns a list of ``n_col`` arrays
    (E_loc, R, blk). Unfused backends share one HBM-resident hidden across
    the blocks (each GEMM2 call re-reads it); the fused backend issues one
    col-sliced kernel per block, recomputing the hidden in VMEM — the
    recompute-vs-HBM-traffic trade the adaptive cost model ranks."""
    if GEMM_IMPL == "pallas_fused":
        from repro.kernels import ops
        return [ops.fused_mlp(rows, w, activation, col_slice=(b * blk, blk),
                              order="n_major")
                for b in range(n_col)]
    h = expert_gemm1(rows, w, activation)
    return [expert_gemm2(h, w, (b * blk, blk)) for b in range(n_col)]


def _etp_psum(ctx: AxisCtx, x):
    if ctx.etp == 1:
        return x
    return lax.psum(x, ctx.model_axis, axis_index_groups=ctx.etp_groups())


def expert_mlp(ctx: AxisCtx, rows, w, activation: str):
    return _etp_psum(ctx, _mlp_out(rows, w, activation))


# ---------------------------------------------------------------------------
# naive: one all_to_all each way
# ---------------------------------------------------------------------------


def transport_naive(ctx: AxisCtx, send, w, activation: str):
    ep, E_loc, C, d = send.shape
    ax = ctx.model_axis
    if not ctx.active or ctx.world == 1:
        rows = send.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
        out = expert_mlp(ctx, rows, w, activation)
        out = out.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
        return out, None

    if ctx.etp == 1:
        recv = lax.all_to_all(send, ax, 0, 0, tiled=True)           # (ep,E_loc,C,d)
        rows = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
        out = expert_mlp(ctx, rows, w, activation)
        out = out.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
        ret = lax.all_to_all(out, ax, 0, 0, tiled=True)
        return ret, None

    # ETP > 1: replicate chunks across the etp subgroup, exchange within
    # same-tp groups, psum partials, return from the tp-matching rank.
    etp, ep_g = ctx.etp, ctx.ep
    gathered = lax.all_gather(send, ax, axis_index_groups=ctx.etp_groups())
    # (etp, ep, E_loc, C, d): gathered[t] = send buffer of subgroup member t
    recv = lax.all_to_all(gathered, ax, 1, 1, axis_index_groups=ctx.tp_groups(),
                          tiled=True)                               # (etp,ep,...)
    rows = recv.transpose(2, 0, 1, 3, 4).reshape(E_loc, etp * ep_g * C, d)
    out = expert_mlp(ctx, rows, w, activation)                      # psum'd
    out = out.reshape(E_loc, etp, ep_g, C, d)
    my_tp = lax.axis_index(ax) % etp
    mine = jnp.take(out, my_tp, axis=1)                             # (E_loc,ep,C,d)
    mine = mine.transpose(1, 0, 2, 3)
    ret = lax.all_to_all(mine, ax, 0, 0, axis_index_groups=ctx.tp_groups(),
                         tiled=True)
    return ret, None


# ---------------------------------------------------------------------------
# comet: decomposed ring with fused per-chunk MLP + early column-block return
# ---------------------------------------------------------------------------


def _perm(ctx: AxisCtx, group_shift: int, tp_shift: int):
    """Permutation over the model axis: (g, t) -> ((g+group_shift)%ep, (t+tp_shift)%etp)."""
    W, etp, ep = ctx.world, ctx.etp, ctx.ep
    pairs = []
    for r in range(W):
        g, t = r // etp, r % etp
        dst = ((g + group_shift) % ep) * etp + (t + tp_shift) % etp
        pairs.append((r, dst))
    return pairs


def transport_comet_blocks(ctx: AxisCtx, send, w, activation: str,
                           n_col_blocks: int = 1, ring_group: int = 1):
    """The comet ring, exposing the layer-1 N-decomposition to the caller:
    returns (blocks, rot) where ``blocks`` is a list of ``n_col`` arrays
    (ep, E_loc, C, blk) — column block b of every chunk's expert output —
    and chunk slot s holds outputs for destination group (rot - s) % ep.

    This is the streaming-consumer interface: block b's array depends only
    on block-b compute and return permutes, so a per-block combine (the
    paper's layer-1 consumer) can start as soon as its block arrives and
    overlap the remaining blocks' GEMM + return traffic, instead of waiting
    for the full-width concatenation.

    ring_group g: number of source-rank chunks fused into ONE GroupGEMM
    macro-step (ep/g steps total). g=1 is the finest overlap (paper default);
    larger g trades overlap granularity for arithmetic intensity — each
    macro-step reads the expert weights once for g chunks, so weight HBM
    traffic and backward dW-accumulator traffic scale ×(g/ep) relative to
    ×1. The adaptive layer picks g from the roofline balance (§3.2.2: the
    same compute-vs-comm division the paper tunes with thread-block counts).
    """
    ep, E_loc, C, d = send.shape
    ax = ctx.model_axis
    etp = ctx.etp

    n_col = max(1, min(n_col_blocks, 8))
    while d % n_col:
        n_col -= 1
    blk = d // n_col

    if not ctx.active or ctx.world == 1:
        out, _ = transport_naive(ctx, send, w, activation)
        return [lax.slice_in_dim(out, b * blk, (b + 1) * blk, axis=-1)
                for b in range(n_col)], None

    r = lax.axis_index(ax)
    g_r = r // etp
    g = max(1, min(ring_group, ep))
    while ep % g:
        g -= 1
    n_steps = ep // g

    # col_blocks[b][s]: (E_loc, C, blk) — filled in ascending chunk-slot order
    col_blocks: List[List[jnp.ndarray]] = [[] for _ in range(n_col)]
    for step in range(n_steps):
        # ---- dispatch: receive g source groups' chunks ---------------------
        chunk_rows = []
        for j in range(g):
            s = step * g + j
            to_send = _dyn_chunk(send, (g_r - s) % ep)              # (E_loc,C,d)
            recvs = []
            for o in range(etp):
                if s == 0 and o == 0:
                    recvs.append(to_send)                           # local chunk first
                else:
                    recvs.append(lax.ppermute(to_send, ax, _perm(ctx, -s, o)))
            if etp == 1:
                chunk_rows.append(recvs[0])                         # (E_loc,C,d)
            else:
                stacked = jnp.stack(recvs)                          # (etp,E_loc,C,d)
                # reorder by true source tp: chunk from source tp u sits at
                # position o = (t_r - u) % etp
                t_r = r % etp
                order = (t_r - jnp.arange(etp)) % etp
                by_u = jnp.take(stacked, order, axis=0)
                chunk_rows.append(
                    by_u.transpose(1, 0, 2, 3).reshape(E_loc, etp * C, d))
        rows = (chunk_rows[0] if g == 1 else
                jnp.concatenate(chunk_rows, axis=1))   # (E_loc, g*etp*C, d)

        # ---- macro-step expert MLP, N-decomposed (layer0 + layer1) ---------
        # fused backend: one VMEM-resident kernel per column block;
        # unfused: GEMM1 once (hidden through HBM), GEMM2 per block
        Rc = etp * C                                    # rows per source chunk
        for b, ob in enumerate(mlp_col_blocks(rows, w, activation,
                                              n_col, blk)):
            ob = _etp_psum(ctx, ob)                     # (E_loc, g*Rc, blk)
            for j in range(g):
                s = step * g + j
                obj = lax.slice_in_dim(ob, j * Rc, (j + 1) * Rc, axis=1)
                if etp > 1:
                    ob_u = obj.reshape(E_loc, etp, C, blk)
                    t_r = r % etp
                    ob_mine = jnp.take(ob_u, t_r, axis=1)           # (E_loc,C,blk)
                else:
                    ob_mine = obj
                if s == 0:
                    col_blocks[b].append(ob_mine)
                else:
                    col_blocks[b].append(
                        lax.ppermute(ob_mine, ax, _perm(ctx, s, 0)))

    return [jnp.stack(cb) for cb in col_blocks], g_r    # n_col × (ep,E_loc,C,blk)


def transport_comet(ctx: AxisCtx, send, w, activation: str,
                    n_col_blocks: int = 1, ring_group: int = 1):
    """Full-width comet transport: returns (recv_out (ep, E_loc, C, d), rot).
    Concatenates the streamed column blocks — callers wanting the per-block
    overlap (plan knob ``fused_combine``) use ``transport_comet_blocks``."""
    blocks, rot = transport_comet_blocks(ctx, send, w, activation,
                                         n_col_blocks=n_col_blocks,
                                         ring_group=ring_group)
    out = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=-1)
    return out, rot


def _dyn_chunk(send, g):
    """send: (ep, E_loc, C, d); g traced -> (E_loc, C, d)."""
    return lax.dynamic_index_in_dim(send, g, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# bcast: decode path — tokens replicated over the model axis
# ---------------------------------------------------------------------------


def transport_bcast(ctx: AxisCtx, buf_full, w, activation: str):
    """buf_full: (E, C, d) — identical on every model rank. Each rank runs its
    own expert slice; a single psum over the model axis both sums ETP partials
    and merges expert groups. Returns (E, C, d) fully combined."""
    E, C, d = buf_full.shape
    if not ctx.active or ctx.world == 1:
        rows = buf_full
        out = expert_mlp(ctx, rows, w, activation)
        return out
    ax = ctx.model_axis
    E_loc = E // ctx.ep
    r = lax.axis_index(ax)
    g_r = r // ctx.etp
    mine = lax.dynamic_slice_in_dim(buf_full, g_r * E_loc, E_loc, axis=0)
    out = _mlp_out(mine, w, activation)                             # partial
    full = jnp.zeros((E, C, d), out.dtype)
    full = lax.dynamic_update_slice_in_dim(full, out, g_r * E_loc, axis=0)
    return lax.psum(full, ax)
