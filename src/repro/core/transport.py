"""MoE transports: how the shared tensor moves between ranks.

All functions take the dispatch buffer ``send`` of shape (ep, E_loc, C, d)
(chunked by destination expert-group — the paper's M-dimension decomposition)
and local expert weights, and return ``recv_out`` of shape (ep, E_loc, C, d)
holding this rank's tokens' expert outputs, plus the ring rotation needed by
``combine``.

  naive   — single all_to_all in, grouped MLP, single all_to_all back
            (Megatron-style non-overlapped baseline).
  coarse  — FasterMoE/Tutel-style: token range split into ``n`` slices, each
            slice runs the naive schedule; slices pipeline at kernel level.
            (Implemented at the layer level in moe_layer.py.)
  comet   — the paper: decomposed collectives. Dispatch is ep-1 ring steps of
            collective-permute; the chunk at ICI distance 0 (local) computes
            first (paper's "sort by source rank / local tiles first"), each
            chunk's expert MLP is fused GEMM1→act→GEMM2 and its *output is
            returned immediately* via a reverse permute — both directions
            overlap the next chunk's compute (XLA async collective-permute).
            Layer-1's N-dimension decomposition: the second GEMM produces
            ``n_col_blocks`` column blocks, each combined/returned as soon as
            it completes (paper Fig. 6 column-major GroupGEMM traversal).
  bcast   — decode-shape path: tokens replicated over the model axis, each
            rank computes its experts, psum combines. No dispatch collective.

ETP (> 1) shards every expert's hidden dim across ``etp`` adjacent ranks of
the model axis; chunks are replicated across the etp subgroup (collectives
use axis_index_groups), partial GEMM2 outputs psum over the subgroup.

Backward (PR 3): ``transport_comet_blocks`` carries a ``jax.custom_vjp``
that schedules the backward as its OWN decomposed ring instead of XLA's
transposed program (which serializes every reverse ppermute after the
forward completes). dY chunks travel the reverse permutes while the
previous chunk's dgrad GEMMs (w_downᵀ/w_upᵀ) and dW accumulation run, dX
chunks return along the transposed dispatch permutes, and the layer-1
N-decomposition applies to the dcombine stream: each column block's dY is
consumed (dh accumulation + per-column-block dw_down) as it arrives,
mirroring ``fused_combine``. Residuals: the fused backend saves only the
per-step dispatched rows — its explicit ``fused_mlp_dgrad``/
``fused_mlp_wgrad`` kernels rematerialize the hidden in VMEM; unfused
backends additionally save the layer-0 pre-activations (exactly what XLA
autodiff would save), so their backward spends no GEMM recompute.

The GroupGEMM backend is threaded EXPLICITLY (``gemm_impl=``) through every
entry point; a caller that does not choose gets the static ``"xla"``
default (``DEFAULT_GEMM_IMPL`` — a constant, not a mutable global).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.adaptive import (WIRE_DTYPES, hier_step_order,
                                 legalize_intra_group, legalize_n_col,
                                 legalize_ring_group)
from repro.models.common import activate, is_glu
from repro.parallel.mesh import AxisCtx


# ---------------------------------------------------------------------------
# Expert MLP (GroupGEMM over local experts)
# ---------------------------------------------------------------------------

# GroupGEMM backend:
#   "xla"          — einsum; XLA fuses + reorders freely.
#   "pallas"       — kernels/grouped_gemm.py with Comet traversal orders (on
#                    TPU this pins tile completion order; layer-1 uses
#                    order="n_major" per Fig. 6).
#   "pallas_fused" — kernels/fused_mlp.py: GEMM1→activation→GEMM2 in one
#                    kernel, hidden activations VMEM-resident (no
#                    (E_loc, R, f_loc) HBM round trip).
GEMM_BACKENDS = ("xla", "pallas", "pallas_fused")
DEFAULT_GEMM_IMPL = "xla"


def _impl(gemm_impl: Optional[str]) -> str:
    """Resolve a caller's backend choice; None/"" is the STATIC "xla"
    default — there is no mutable ambient global, the backend is always
    either explicit (MoEConfig.gemm_impl, set by Plan.apply) or "xla"."""
    if gemm_impl is None or gemm_impl == "":
        return DEFAULT_GEMM_IMPL
    assert gemm_impl in GEMM_BACKENDS, gemm_impl
    return gemm_impl


def _gg(rows, w, order="expert_major", gemm_impl: Optional[str] = None):
    if _impl(gemm_impl) == "pallas":
        from repro.kernels import ops
        return ops.grouped_gemm(rows, w, order=order)
    # one contraction covers both layouts — (E,R,d)@(E,d,f) and
    # (E,R,f)@(E,f,d) differ only in axis naming
    return jnp.einsum("erk,ekn->ern", rows, w)


def expert_gemm1(rows, w, activation: str, gemm_impl: Optional[str] = None):
    """rows: (E_loc, R, d) -> h: (E_loc, R, f_loc)."""
    if is_glu(activation):
        gate = _gg(rows, w["w_gate"], gemm_impl=gemm_impl)
        up = _gg(rows, w["w_up"], gemm_impl=gemm_impl)
        return activate(activation, gate, up)
    up = _gg(rows, w["w_up"], gemm_impl=gemm_impl)
    return activate(activation, None, up)


def expert_gemm2(h, w, col_slice: Optional[Tuple[int, int]] = None,
                 gemm_impl: Optional[str] = None):
    """h: (E_loc, R, f_loc) -> (E_loc, R, d_block)."""
    wd = w["w_down"]
    if col_slice is not None:
        wd = lax.dynamic_slice_in_dim(wd, col_slice[0], col_slice[1], axis=2)
    return _gg(h, wd, order="n_major", gemm_impl=gemm_impl)


def _mlp_out(rows, w, activation: str, gemm_impl: Optional[str] = None):
    """Full-width expert MLP under the chosen backend: one fused kernel call
    (hidden stays in VMEM) or the two-GEMM pipeline (hidden through HBM)."""
    if _impl(gemm_impl) == "pallas_fused":
        from repro.kernels import ops
        return ops.fused_mlp(rows, w, activation)
    return expert_gemm2(expert_gemm1(rows, w, activation, gemm_impl), w,
                        gemm_impl=gemm_impl)


def mlp_col_blocks(rows, w, activation: str, n_col: int, blk: int,
                   gemm_impl: Optional[str] = None):
    """Per-column-block expert MLP outputs — the layer-1 producer interface
    for the comet schedule. Returns a list of ``n_col`` arrays
    (E_loc, R, blk). Unfused backends share one HBM-resident hidden across
    the blocks (each GEMM2 call re-reads it); the fused backend issues one
    col-sliced kernel per block, recomputing the hidden in VMEM — the
    recompute-vs-HBM-traffic trade the adaptive cost model ranks."""
    if _impl(gemm_impl) == "pallas_fused":
        from repro.kernels import ops
        return [ops.fused_mlp(rows, w, activation, col_slice=(b * blk, blk),
                              order="n_major")
                for b in range(n_col)]
    h = expert_gemm1(rows, w, activation, gemm_impl)
    return [expert_gemm2(h, w, (b * blk, blk), gemm_impl)
            for b in range(n_col)]


def _mlp_preacts(rows, w, activation: str, gemm_impl: Optional[str] = None):
    """Layer-0 pre-activations (gate, up) — what the unfused forward ring
    SAVES for its backward (the same tensors XLA autodiff would save), so
    the backward spends no GEMM recompute. gate is None for non-GLU."""
    up = _gg(rows, w["w_up"], gemm_impl=gemm_impl)
    gate = (_gg(rows, w["w_gate"], gemm_impl=gemm_impl)
            if is_glu(activation) else None)
    return gate, up


def _mlp_bwd(rows, w, activation: str, dys, blk: int,
             gemm_impl: Optional[str] = None, preacts=None):
    """Per-chunk MLP backward with per-column-block dY consumption (the
    layer-1 N-decomposition applied to the dcombine stream).

    rows: (E_loc, R, d); dys: list of n_col column-block cotangents
    (E_loc, R, blk) partitioning the output width. Returns
    (d_rows (E_loc, R, d), dw dict matching ``w``'s keys).

    Fused backend: each block runs the explicit col-sliced dgrad/wgrad
    kernels (hidden recomputed in VMEM, matching the forward's
    ``col_slice``/``n_major`` traversal — the forward never materialized
    it); per-block dX / dw_up / dw_gate partials sum to the full gradients
    (linearity in dY). Unfused backends reuse the saved ``preacts``
    (recomputing them only when the caller saved nothing), stream the dY
    blocks into the dh accumulator and the per-block dw_down columns, then
    run one activation VJP and the transposed layer-0 GEMMs. The
    ``"pallas"`` backend shares this einsum backward with ``"xla"``: the
    grouped-GEMM kernel is a forward-layout kernel, and the transposed
    contractions here deliberately stay in XLA (identical numerics; only
    the forward's tile-completion order needed pinning)."""
    impl = _impl(gemm_impl)
    n_col = len(dys)
    glu = is_glu(activation)
    if impl == "pallas_fused":
        from repro.kernels import ops
        d_rows = None
        dwg = dwu = None
        dwd_blocks = []
        for b, dy in enumerate(dys):
            cs = (b * blk, blk) if n_col > 1 else None
            dx = ops.fused_mlp_dgrad(rows, w, dy, activation, col_slice=cs)
            g_, u_, d_ = ops.fused_mlp_wgrad(rows, w, dy, activation,
                                             col_slice=cs)
            d_rows = dx if d_rows is None else d_rows + dx
            dwu = u_ if dwu is None else dwu + u_
            if glu:
                dwg = g_ if dwg is None else dwg + g_
            dwd_blocks.append(d_)
        dwd = dwd_blocks[0] if n_col == 1 \
            else jnp.concatenate(dwd_blocks, axis=2)
        dw = {"w_up": dwu, "w_down": dwd}
        if glu:
            dw["w_gate"] = dwg
        return d_rows, dw

    if preacts is None:
        preacts = _mlp_preacts(rows, w, activation, impl)
    gate, up = preacts
    if glu:
        h, act_vjp = jax.vjp(lambda g, u: activate(activation, g, u),
                             gate, up)
    else:
        h, act_vjp = jax.vjp(lambda u: activate(activation, None, u), up)
    h_cast = h.astype(rows.dtype)       # the forward's pre-GEMM2 cast
    dh = None
    dwd_blocks = []
    for b, dy in enumerate(dys):
        wd_b = (lax.dynamic_slice_in_dim(w["w_down"], b * blk, blk, axis=2)
                if n_col > 1 else w["w_down"])
        dh_b = jnp.einsum("erb,efb->erf", dy, wd_b)
        dh = dh_b if dh is None else dh + dh_b
        dwd_blocks.append(jnp.einsum("erf,erb->efb", h_cast, dy))
    dwd = dwd_blocks[0] if n_col == 1 else jnp.concatenate(dwd_blocks, axis=2)
    dh = dh.astype(h.dtype)
    if glu:
        dgate, dup = act_vjp(dh)
        d_rows = (jnp.einsum("erf,edf->erd", dup, w["w_up"])
                  + jnp.einsum("erf,edf->erd", dgate, w["w_gate"]))
        dw = {"w_up": jnp.einsum("erd,erf->edf", rows, dup),
              "w_gate": jnp.einsum("erd,erf->edf", rows, dgate),
              "w_down": dwd}
    else:
        dup, = act_vjp(dh)
        d_rows = jnp.einsum("erf,edf->erd", dup, w["w_up"])
        dw = {"w_up": jnp.einsum("erd,erf->edf", rows, dup), "w_down": dwd}
    return d_rows.astype(rows.dtype), dw


def _cast_like(dw: Dict, w: Dict) -> Dict:
    return {k: dw[k].astype(w[k].dtype) for k in w}


def _etp_psum(ctx: AxisCtx, x):
    if ctx.etp == 1:
        return x
    return lax.psum(x, ctx.model_axis, axis_index_groups=ctx.etp_groups())


def expert_mlp(ctx: AxisCtx, rows, w, activation: str,
               gemm_impl: Optional[str] = None):
    return _etp_psum(ctx, _mlp_out(rows, w, activation, gemm_impl))


# ---------------------------------------------------------------------------
# naive: one all_to_all each way
# ---------------------------------------------------------------------------


def transport_naive(ctx: AxisCtx, send, w, activation: str,
                    gemm_impl: Optional[str] = None):
    ep, E_loc, C, d = send.shape
    ax = ctx.model_axis
    if not ctx.active or ctx.world == 1:
        rows = send.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
        out = expert_mlp(ctx, rows, w, activation, gemm_impl)
        out = out.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
        return out, None

    if ctx.etp == 1:
        recv = lax.all_to_all(send, ax, 0, 0, tiled=True)           # (ep,E_loc,C,d)
        rows = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
        out = expert_mlp(ctx, rows, w, activation, gemm_impl)
        out = out.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
        ret = lax.all_to_all(out, ax, 0, 0, tiled=True)
        return ret, None

    # ETP > 1: replicate chunks across the etp subgroup, exchange within
    # same-tp groups, psum partials, return from the tp-matching rank.
    etp, ep_g = ctx.etp, ctx.ep
    gathered = lax.all_gather(send, ax, axis_index_groups=ctx.etp_groups())
    # (etp, ep, E_loc, C, d): gathered[t] = send buffer of subgroup member t
    recv = lax.all_to_all(gathered, ax, 1, 1, axis_index_groups=ctx.tp_groups(),
                          tiled=True)                               # (etp,ep,...)
    rows = recv.transpose(2, 0, 1, 3, 4).reshape(E_loc, etp * ep_g * C, d)
    out = expert_mlp(ctx, rows, w, activation, gemm_impl)           # psum'd
    out = out.reshape(E_loc, etp, ep_g, C, d)
    my_tp = lax.axis_index(ax) % etp
    mine = jnp.take(out, my_tp, axis=1)                             # (E_loc,ep,C,d)
    mine = mine.transpose(1, 0, 2, 3)
    ret = lax.all_to_all(mine, ax, 0, 0, axis_index_groups=ctx.tp_groups(),
                         tiled=True)
    return ret, None


# ---------------------------------------------------------------------------
# comet: decomposed ring with fused per-chunk MLP + early column-block return
# ---------------------------------------------------------------------------


def _perm(ctx: AxisCtx, group_shift: int, tp_shift: int):
    """Permutation over the model axis: (g, t) -> ((g+group_shift)%ep, (t+tp_shift)%etp)."""
    W, etp, ep = ctx.world, ctx.etp, ctx.ep
    pairs = []
    for r in range(W):
        g, t = r // etp, r % etp
        dst = ((g + group_shift) % ep) * etp + (t + tp_shift) % etp
        pairs.append((r, dst))
    return pairs


def comet_ring_segments(ep: int, ring_group: int, n_col_blocks: int) -> dict:
    """Segment counts of one forward ring as `_comet_ring_fwd` actually
    executes it (etp=1 view): ep//ring_group GroupGEMM macro-steps, each
    consuming ring_group source chunks; chunk slot 0 is local so ep-1
    dispatch ppermutes cross the link; every non-local chunk returns
    n_col_blocks combine ppermutes. core/schedule.py lowers whole-graph
    schedules from these same counts (see comet_ring_counts) and
    tests/test_schedule.py asserts the two never drift apart."""
    g = legalize_ring_group(ep, ring_group)
    return {
        "n_steps": max(1, ep // g),
        "dispatch_hops": max(0, ep - 1),
        "expert_gemms": max(1, ep // g),
        "combine_hops": max(1, n_col_blocks) * max(0, ep - 1),
    }


def _census_note(census, op: str, x, pairs):
    """Record one executed ppermute (payload bytes + permutation pairs) in
    a caller-supplied census list — the interpret-mode traffic measurement
    benchmarks/run.py prices per link class. An explicit argument, never a
    module global; None (the default everywhere) records nothing."""
    if census is not None:
        census.append({"op": op, "bytes": int(x.size) * x.dtype.itemsize,
                       "pairs": [list(p) for p in pairs]})


def _comet_ring_fwd(ctx: AxisCtx, send, w, activation: str, n_col: int,
                    blk: int, g: int, gemm_impl: Optional[str],
                    census=None):
    """The forward ring. Returns (blocks, rows_steps, preacts_steps):
    ``blocks`` is the n_col-tuple of (ep, E_loc, C, blk) streamed column
    blocks; ``rows_steps`` stacks each macro-step's dispatched rows and
    ``preacts_steps`` its layer-0 pre-activations — the backward's saved
    residuals. The fused backend saves rows only (its dgrad/wgrad kernels
    recompute the hidden in VMEM, so ``preacts_steps`` is None); unfused
    backends save (gate, up) exactly as XLA autodiff would, spending no
    backward GEMM recompute."""
    ep, E_loc, C, d = send.shape
    ax = ctx.model_axis
    etp = ctx.etp
    n_steps = ep // g
    r = lax.axis_index(ax)
    g_r = r // etp
    fused = _impl(gemm_impl) == "pallas_fused"

    # col_blocks[b][s]: (E_loc, C, blk) — filled in ascending chunk-slot order
    col_blocks: List[List[jnp.ndarray]] = [[] for _ in range(n_col)]
    rows_steps = []
    gate_steps, up_steps = [], []
    for step in range(n_steps):
        # ---- dispatch: receive g source groups' chunks ---------------------
        chunk_rows = []
        for j in range(g):
            s = step * g + j
            to_send = _dyn_chunk(send, (g_r - s) % ep)              # (E_loc,C,d)
            recvs = []
            for o in range(etp):
                if s == 0 and o == 0:
                    recvs.append(to_send)                           # local chunk first
                else:
                    pairs = _perm(ctx, -s, o)
                    _census_note(census, "disp", to_send, pairs)
                    recvs.append(lax.ppermute(to_send, ax, pairs))
            if etp == 1:
                chunk_rows.append(recvs[0])                         # (E_loc,C,d)
            else:
                stacked = jnp.stack(recvs)                          # (etp,E_loc,C,d)
                # reorder by true source tp: chunk from source tp u sits at
                # position o = (t_r - u) % etp
                t_r = r % etp
                order = (t_r - jnp.arange(etp)) % etp
                by_u = jnp.take(stacked, order, axis=0)
                chunk_rows.append(
                    by_u.transpose(1, 0, 2, 3).reshape(E_loc, etp * C, d))
        rows = (chunk_rows[0] if g == 1 else
                jnp.concatenate(chunk_rows, axis=1))   # (E_loc, g*etp*C, d)
        rows_steps.append(rows)

        # ---- macro-step expert MLP, N-decomposed (layer0 + layer1) ---------
        # fused backend: one VMEM-resident kernel per column block;
        # unfused: GEMM1 once (hidden through HBM), GEMM2 per block — with
        # the pre-activations kept as backward residuals
        Rc = etp * C                                    # rows per source chunk
        if fused:
            obs = mlp_col_blocks(rows, w, activation, n_col, blk, gemm_impl)
        else:
            gate, up = _mlp_preacts(rows, w, activation, gemm_impl)
            h = activate(activation, gate, up)
            obs = [expert_gemm2(h, w, (b * blk, blk), gemm_impl)
                   for b in range(n_col)]
            if gate is not None:
                gate_steps.append(gate)
            up_steps.append(up)
        for b, ob in enumerate(obs):
            ob = _etp_psum(ctx, ob)                     # (E_loc, g*Rc, blk)
            for j in range(g):
                s = step * g + j
                obj = lax.slice_in_dim(ob, j * Rc, (j + 1) * Rc, axis=1)
                if etp > 1:
                    ob_u = obj.reshape(E_loc, etp, C, blk)
                    t_r = r % etp
                    ob_mine = jnp.take(ob_u, t_r, axis=1)           # (E_loc,C,blk)
                else:
                    ob_mine = obj
                if s == 0:
                    col_blocks[b].append(ob_mine)
                else:
                    pairs = _perm(ctx, s, 0)
                    _census_note(census, "comb", ob_mine, pairs)
                    col_blocks[b].append(
                        lax.ppermute(ob_mine, ax, pairs))

    blocks = tuple(jnp.stack(cb) for cb in col_blocks)  # n_col × (ep,E_loc,C,blk)
    preacts_steps = None if fused else (
        jnp.stack(gate_steps) if gate_steps else None, jnp.stack(up_steps))
    return blocks, jnp.stack(rows_steps), preacts_steps


def _comet_ring_bwd(ctx: AxisCtx, rows_steps, preacts_steps, w, cts,
                    activation: str, n_col: int, blk: int, g: int,
                    send_shape, send_dtype, gemm_impl: Optional[str]):
    """The backward ring — the same decomposed schedule run in reverse
    roles. Per macro-step: the dY column blocks for its chunk slots travel
    the reverse return-permutes (slot 0 is local) and, under ETP, are
    re-assembled by a scatter-at-my-tp + subgroup psum (the transpose of
    the forward's psum + take); the per-chunk dgrad/wgrad then consumes
    them block by block while the dX chunks ride the transposed dispatch
    permutes back to their source rank — each of those transfers overlaps
    the next macro-step's GEMMs exactly as in the forward. dW accumulates
    across macro-steps in fp32 and flushes once."""
    ep, E_loc, C, d = send_shape
    ax = ctx.model_axis
    etp = ctx.etp
    n_steps = ep // g
    Rc = etp * C
    r = lax.axis_index(ax)
    g_r = r // etp
    t_r = r % etp

    d_send = jnp.zeros(send_shape, send_dtype)
    dw_acc: Dict[str, jnp.ndarray] = {
        k: jnp.zeros(v.shape, jnp.float32) for k, v in w.items()}
    for step in range(n_steps):
        # ---- dY: reverse return-permutes, per column block ----------------
        dys = []
        for b in range(n_col):
            parts = []
            for j in range(g):
                s = step * g + j
                dy_src = cts[b][s]                      # (E_loc, C, blk)
                if s == 0:
                    dy_j = dy_src
                else:
                    dy_j = lax.ppermute(dy_src, ax, _perm(ctx, -s, 0))
                if etp > 1:
                    full = jnp.zeros((E_loc, etp, C, blk), dy_j.dtype)
                    dy_j = full.at[:, t_r].set(dy_j).reshape(E_loc, Rc, blk)
                parts.append(dy_j if etp > 1 else dy_j.reshape(E_loc, C, blk))
            dy_b = parts[0] if g == 1 else jnp.concatenate(parts, axis=1)
            if etp > 1:
                # transpose of (psum over the subgroup → take my tp slice)
                dy_b = lax.psum(dy_b, ax, axis_index_groups=ctx.etp_groups())
            dys.append(dy_b)                            # (E_loc, g*Rc, blk)

        # ---- per-chunk dgrad + wgrad ---------------------------------------
        rows = rows_steps[step]                         # (E_loc, g*Rc, d)
        preacts = None if preacts_steps is None else (
            None if preacts_steps[0] is None else preacts_steps[0][step],
            preacts_steps[1][step])
        d_rows, dw = _mlp_bwd(rows, w, activation, dys, blk, gemm_impl,
                              preacts)
        for k in dw_acc:
            dw_acc[k] = dw_acc[k] + dw[k].astype(jnp.float32)

        # ---- dX: transposed dispatch permutes back to the source ----------
        for j in range(g):
            s = step * g + j
            dcr = lax.slice_in_dim(d_rows, j * Rc, (j + 1) * Rc, axis=1)
            if etp > 1:
                by_u = dcr.reshape(E_loc, etp, C, d)
            arrivals = None
            for o in range(etp):
                if etp > 1:
                    piece = jnp.take(by_u, (t_r - o) % etp, axis=1)
                else:
                    piece = dcr
                if s == 0 and o == 0:
                    got = piece
                else:
                    got = lax.ppermute(piece, ax, _perm(ctx, s, -o))
                arrivals = got if arrivals is None else arrivals + got
            # the summed arrivals are the gradient of the chunk THIS rank
            # dispatched at slot s (summing also merges the etp partials)
            d_send = lax.dynamic_update_index_in_dim(
                d_send, arrivals.astype(send_dtype), (g_r - s) % ep, axis=0)
    return d_send, _cast_like(dw_acc, w)


def transport_comet_blocks(ctx: AxisCtx, send, w, activation: str,
                           n_col_blocks: int = 1, ring_group: int = 1,
                           gemm_impl: Optional[str] = None,
                           custom_vjp: bool = True, census=None):
    """The comet ring, exposing the layer-1 N-decomposition to the caller:
    returns (blocks, rot) where ``blocks`` is a list of ``n_col`` arrays
    (ep, E_loc, C, blk) — column block b of every chunk's expert output —
    and chunk slot s holds outputs for destination group (rot - s) % ep.

    This is the streaming-consumer interface: block b's array depends only
    on block-b compute and return permutes, so a per-block combine (the
    paper's layer-1 consumer) can start as soon as its block arrives and
    overlap the remaining blocks' GEMM + return traffic, instead of waiting
    for the full-width concatenation.

    ring_group g: number of source-rank chunks fused into ONE GroupGEMM
    macro-step (ep/g steps total). g=1 is the finest overlap (paper default);
    larger g trades overlap granularity for arithmetic intensity — each
    macro-step reads the expert weights once for g chunks, so weight HBM
    traffic and backward dW-accumulator traffic scale ×(g/ep) relative to
    ×1. The adaptive layer picks g from the roofline balance (§3.2.2: the
    same compute-vs-comm division the paper tunes with thread-block counts).

    Knob legalization is the adaptive layer's shared helpers — identical to
    what the tuner ranked and persisted, so plan and execution agree.

    ``custom_vjp=True`` (default) installs the decomposed backward ring
    (module docstring); False leaves XLA autodiff's transposed program —
    the baseline the gradient-equivalence tests difference against."""
    ep, E_loc, C, d = send.shape

    n_col = legalize_n_col(d, n_col_blocks)
    blk = d // n_col

    if not ctx.active or ctx.world == 1:
        if not custom_vjp:
            out, _ = transport_naive(ctx, send, w, activation, gemm_impl)
            return [lax.slice_in_dim(out, b * blk, (b + 1) * blk, axis=-1)
                    for b in range(n_col)], None

        # Degenerate (single-rank) ring: the forward is exactly the naive
        # path; the backward still runs the decomposed per-column-block
        # consumption so the dgrad/wgrad machinery is exercised (and tested)
        # without a mesh.
        @jax.custom_vjp
        def local(send_, w_):
            out, _ = transport_naive(ctx, send_, w_, activation, gemm_impl)
            return tuple(
                lax.slice_in_dim(out, b * blk, (b + 1) * blk, axis=-1)
                for b in range(n_col))

        def local_fwd(send_, w_):
            return local(send_, w_), (send_, w_)

        def local_bwd(res, cts):
            send_, w_ = res
            ep_, E_loc_, C_, d_ = send_.shape
            rows = send_.transpose(1, 0, 2, 3).reshape(E_loc_, ep_ * C_, d_)
            dys = [ct.transpose(1, 0, 2, 3).reshape(E_loc_, ep_ * C_, blk)
                   for ct in cts]
            d_rows, dw = _mlp_bwd(rows, w_, activation, dys, blk, gemm_impl)
            d_send = d_rows.reshape(E_loc_, ep_, C_, d_).transpose(1, 0, 2, 3)
            return d_send.astype(send_.dtype), _cast_like(dw, w_)

        local.defvjp(local_fwd, local_bwd)
        return list(local(send, w)), None

    g = legalize_ring_group(ep, ring_group)
    ax = ctx.model_axis
    rot = lax.axis_index(ax) // ctx.etp

    if not custom_vjp:
        blocks, _, _ = _comet_ring_fwd(ctx, send, w, activation, n_col, blk,
                                       g, gemm_impl, census=census)
        return list(blocks), rot

    send_shape, send_dtype = send.shape, send.dtype

    @jax.custom_vjp
    def ring(send_, w_):
        blocks, _, _ = _comet_ring_fwd(ctx, send_, w_, activation, n_col,
                                       blk, g, gemm_impl)
        return blocks

    def ring_fwd(send_, w_):
        blocks, rows_steps, preacts_steps = _comet_ring_fwd(
            ctx, send_, w_, activation, n_col, blk, g, gemm_impl)
        return blocks, (rows_steps, preacts_steps, w_)

    def ring_bwd(res, cts):
        rows_steps, preacts_steps, w_ = res
        return _comet_ring_bwd(ctx, rows_steps, preacts_steps, w_, cts,
                               activation, n_col, blk, g, send_shape,
                               send_dtype, gemm_impl)

    ring.defvjp(ring_fwd, ring_bwd)
    return list(ring(send, w)), rot


def transport_comet(ctx: AxisCtx, send, w, activation: str,
                    n_col_blocks: int = 1, ring_group: int = 1,
                    gemm_impl: Optional[str] = None,
                    custom_vjp: bool = True):
    """Full-width comet transport: returns (recv_out (ep, E_loc, C, d), rot).
    Concatenates the streamed column blocks — callers wanting the per-block
    overlap (plan knob ``fused_combine``) use ``transport_comet_blocks``."""
    blocks, rot = transport_comet_blocks(ctx, send, w, activation,
                                         n_col_blocks=n_col_blocks,
                                         ring_group=ring_group,
                                         gemm_impl=gemm_impl,
                                         custom_vjp=custom_vjp)
    out = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=-1)
    return out, rot


def _dyn_chunk(send, g):
    """send: (ep, E_loc, C, d); g traced -> (E_loc, C, d)."""
    return lax.dynamic_index_in_dim(send, g, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# comet_hier: the two-level (intra-node × inter-node) decomposed ring, with
# an optional low-precision wire format for dispatch payloads and combine
# partials.
#
# The EP axis is factored as ep = n_nodes × intra_group (rank r -> node
# r // intra_group, local slot r % intra_group). Every hop either stays
# inside a node (both endpoints share the node index — the fast NVLink/ICI
# class) or crosses nodes (the slow RDMA/DCN class); a flat comet shift
# s >= 1 always has SOME cross-node pair when intra_group < ep, so a flat
# ppermute completes at the slow class on every remote step. The two-level
# ring instead decomposes each shift into (node_shift, local_shift): of the
# ep-1 remote sub-steps, intra_group-1 are pure intra-node. Sub-steps run
# inter-node FIRST (core/adaptive.hier_step_order) so the slow hops overlap
# the most remaining compute and the cheap intra hops land in the tail.
# Per-chunk GEMM overlap, ring_group macro-step fusion, the streamed
# per-column-block combine and the custom-VJP backward ring all mirror the
# flat comet schedule — only the permutations (and the wire bytes) change.
#
# Wire format (``wire_dtype``): dispatch chunks are quantized ONCE from the
# pre-ring buffer (so the bytes of a chunk are identical no matter which
# sub-step carries it — the rotation-determinism the tests assert) and
# dequantized in fp32 on receive; each combine partial is quantized once
# before its single return hop. Gradients are NEVER wire-quantized: the
# backward ring moves native-width dY/dX and is the gradient of the
# UNQUANTIZED math (straight-through, the standard estimator).
# ---------------------------------------------------------------------------

_FP8_WIRE_MAX = 448.0                  # |max finite| of float8_e4m3fn
_FP8_WIRE_OK = hasattr(jnp, "float8_e4m3fn")


def wire_dtype_supported(wire_dtype: str) -> bool:
    return wire_dtype in WIRE_DTYPES and (
        wire_dtype != "fp8_e4m3" or _FP8_WIRE_OK)


def _wire_encode(x, wire_dtype: str, per_chunk: bool = False):
    """Quantize a payload for the wire. Returns (payload, scale) — scale is
    None for the scale-free formats. ``per_chunk=True`` keeps one symmetric
    scale per leading-axis chunk (the dispatch buffer's ep chunks);
    otherwise one scale covers the tensor (a single combine partial). The
    fp8 path is optim/compression.py's symmetric-amax scheme at fp8 range."""
    if wire_dtype == "fp32":           # identity: native payload dtype
        return x, None
    if wire_dtype == "bf16":
        return x.astype(jnp.bfloat16), None
    assert wire_dtype == "fp8_e4m3", wire_dtype
    xf = x.astype(jnp.float32)
    axes = tuple(range(1, x.ndim)) if per_chunk else tuple(range(x.ndim))
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / _FP8_WIRE_MAX
    q = jnp.clip(xf / scale, -_FP8_WIRE_MAX, _FP8_WIRE_MAX)
    return q.astype(jnp.float8_e4m3fn), scale


def _wire_decode(payload, scale, out_dtype):
    """Dequantize a received payload: the scale multiply runs in fp32 (the
    documented fp32-accumulation point) before the cast to ``out_dtype``."""
    if scale is None:
        return payload.astype(out_dtype)
    return (payload.astype(jnp.float32) * scale).astype(out_dtype)


def _hier_perm(ctx: AxisCtx, ig: int, node_shift: int, loc_shift: int,
               tp_shift: int):
    """Permutation over the model axis with the EP group index factored as
    (node, local): (node, loc, t) -> ((node+node_shift) % n_nodes,
    (loc+loc_shift) % ig, (t+tp_shift) % etp)."""
    W, etp, ep = ctx.world, ctx.etp, ctx.ep
    nn = ep // ig
    pairs = []
    for r in range(W):
        grp, t = r // etp, r % etp
        nd, lc = grp // ig, grp % ig
        dg = ((nd + node_shift) % nn) * ig + (lc + loc_shift) % ig
        pairs.append((r, dg * etp + (t + tp_shift) % etp))
    return pairs


def _hier_dst(g_r, sn: int, sl: int, ig: int, nn: int):
    """Chunk slot this rank dispatches at hier sub-step (sn, sl): the
    destination group reached by shifting -sn nodes / -sl local slots.
    ``g_r`` is the (traced) EP group index."""
    return ((g_r // ig - sn) % nn) * ig + (g_r % ig - sl) % ig


def comet_hier_segments(ep: int, ring_group: int, n_col_blocks: int,
                        intra_group: int) -> dict:
    """Segment counts of one hierarchical forward ring. The loop structure
    (macro-steps, dispatch hops, combine hops) is IDENTICAL to the flat
    ring — the hierarchy re-routes hops, it does not add or remove any —
    plus the per-class split the topology cost model prices."""
    seg = comet_ring_segments(ep, ring_group, n_col_blocks)
    ig = legalize_intra_group(ep, intra_group)
    seg["intra_hops"] = ig - 1
    seg["inter_hops"] = max(0, ep - ig)
    return seg


def _comet_hier_fwd(ctx: AxisCtx, send, w, activation: str, n_col: int,
                    blk: int, g: int, ig: int, wire_dtype: str,
                    gemm_impl: Optional[str], census=None):
    """The hierarchical forward ring. Identical schedule to
    ``_comet_ring_fwd`` — per macro-step: receive g chunks, one GroupGEMM,
    stream n_col column blocks back — but every permute decomposes into the
    two-level (node_shift, local_shift) map and payloads ride the wire
    format. Returns (blocks, rows_steps, preacts_steps) with ``blocks`` in
    HIER SUB-STEP order (the wrapper reorders to destination order)."""
    ep, E_loc, C, d = send.shape
    ax = ctx.model_axis
    etp = ctx.etp
    nn = ep // ig
    n_steps = ep // g
    r = lax.axis_index(ax)
    g_r = r // etp
    t_r = r % etp
    fused = _impl(gemm_impl) == "pallas_fused"
    shifts = hier_step_order(ep, ig)

    # quantize ALL dispatch chunks once, before any permute: the bytes of a
    # chunk are the same no matter which sub-step (or link class) carries
    # it, and the per-chunk scales travel with their payloads
    pay, scales = _wire_encode(send, wire_dtype, per_chunk=True)

    col_blocks: List[List[jnp.ndarray]] = [[] for _ in range(n_col)]
    rows_steps = []
    gate_steps, up_steps = [], []
    for step in range(n_steps):
        # ---- dispatch: receive g source groups' chunks ---------------------
        chunk_rows = []
        for j in range(g):
            s = step * g + j
            sn, sl = shifts[s]
            hd = _hier_dst(g_r, sn, sl, ig, nn)
            to_send = _dyn_chunk(pay, hd)                           # (E_loc,C,d)
            sc = None if scales is None else _dyn_chunk(scales, hd)
            recvs = []
            for o in range(etp):
                if s == 0 and o == 0:
                    recvs.append(_wire_decode(to_send, sc, send.dtype))
                else:
                    pairs = _hier_perm(ctx, ig, -sn, -sl, o)
                    _census_note(census, "disp", to_send, pairs)
                    got = lax.ppermute(to_send, ax, pairs)
                    gsc = (None if sc is None
                           else lax.ppermute(sc, ax, pairs))
                    recvs.append(_wire_decode(got, gsc, send.dtype))
            if etp == 1:
                chunk_rows.append(recvs[0])                         # (E_loc,C,d)
            else:
                stacked = jnp.stack(recvs)                          # (etp,E_loc,C,d)
                order = (t_r - jnp.arange(etp)) % etp
                by_u = jnp.take(stacked, order, axis=0)
                chunk_rows.append(
                    by_u.transpose(1, 0, 2, 3).reshape(E_loc, etp * C, d))
        rows = (chunk_rows[0] if g == 1 else
                jnp.concatenate(chunk_rows, axis=1))   # (E_loc, g*etp*C, d)
        rows_steps.append(rows)

        # ---- macro-step expert MLP, N-decomposed ---------------------------
        Rc = etp * C
        if fused:
            obs = mlp_col_blocks(rows, w, activation, n_col, blk, gemm_impl)
        else:
            gate, up = _mlp_preacts(rows, w, activation, gemm_impl)
            h = activate(activation, gate, up)
            obs = [expert_gemm2(h, w, (b * blk, blk), gemm_impl)
                   for b in range(n_col)]
            if gate is not None:
                gate_steps.append(gate)
            up_steps.append(up)
        for b, ob in enumerate(obs):
            ob = _etp_psum(ctx, ob)                     # (E_loc, g*Rc, blk)
            for j in range(g):
                s = step * g + j
                sn, sl = shifts[s]
                obj = lax.slice_in_dim(ob, j * Rc, (j + 1) * Rc, axis=1)
                if etp > 1:
                    ob_u = obj.reshape(E_loc, etp, C, blk)
                    ob_mine = jnp.take(ob_u, t_r, axis=1)           # (E_loc,C,blk)
                else:
                    ob_mine = obj
                if s == 0:
                    col_blocks[b].append(ob_mine)
                else:
                    # one combine partial = one hop: quantize once before
                    # its return permute, dequantize (fp32 multiply) on
                    # arrival — combine accumulation order is untouched
                    pb, psc = _wire_encode(ob_mine, wire_dtype)
                    pairs = _hier_perm(ctx, ig, sn, sl, 0)
                    _census_note(census, "comb", pb, pairs)
                    got = lax.ppermute(pb, ax, pairs)
                    gsc = (None if psc is None
                           else lax.ppermute(psc, ax, pairs))
                    col_blocks[b].append(
                        _wire_decode(got, gsc, ob_mine.dtype))

    blocks = tuple(jnp.stack(cb) for cb in col_blocks)  # n_col × (ep,E_loc,C,blk)
    preacts_steps = None if fused else (
        jnp.stack(gate_steps) if gate_steps else None, jnp.stack(up_steps))
    return blocks, jnp.stack(rows_steps), preacts_steps


def _comet_hier_bwd(ctx: AxisCtx, rows_steps, preacts_steps, w, cts,
                    activation: str, n_col: int, blk: int, g: int, ig: int,
                    send_shape, send_dtype, gemm_impl: Optional[str]):
    """The hierarchical backward ring — ``_comet_ring_bwd`` on the
    two-level permutes. ``cts`` arrive in HIER SUB-STEP order (the
    destination-order reorder lives OUTSIDE the custom_vjp, so autodiff
    transposes it before this runs). dY rides the inverse return permutes,
    dX the inverse dispatch permutes, both at NATIVE width — gradients are
    never wire-quantized (straight-through w.r.t. the wire format)."""
    ep, E_loc, C, d = send_shape
    ax = ctx.model_axis
    etp = ctx.etp
    nn = ep // ig
    n_steps = ep // g
    Rc = etp * C
    r = lax.axis_index(ax)
    g_r = r // etp
    t_r = r % etp
    shifts = hier_step_order(ep, ig)

    d_send = jnp.zeros(send_shape, send_dtype)
    dw_acc: Dict[str, jnp.ndarray] = {
        k: jnp.zeros(v.shape, jnp.float32) for k, v in w.items()}
    for step in range(n_steps):
        # ---- dY: inverse return-permutes, per column block ----------------
        dys = []
        for b in range(n_col):
            parts = []
            for j in range(g):
                s = step * g + j
                sn, sl = shifts[s]
                dy_src = cts[b][s]                      # (E_loc, C, blk)
                if s == 0:
                    dy_j = dy_src
                else:
                    dy_j = lax.ppermute(dy_src, ax,
                                        _hier_perm(ctx, ig, -sn, -sl, 0))
                if etp > 1:
                    full = jnp.zeros((E_loc, etp, C, blk), dy_j.dtype)
                    dy_j = full.at[:, t_r].set(dy_j).reshape(E_loc, Rc, blk)
                parts.append(dy_j if etp > 1 else dy_j.reshape(E_loc, C, blk))
            dy_b = parts[0] if g == 1 else jnp.concatenate(parts, axis=1)
            if etp > 1:
                dy_b = lax.psum(dy_b, ax, axis_index_groups=ctx.etp_groups())
            dys.append(dy_b)                            # (E_loc, g*Rc, blk)

        # ---- per-chunk dgrad + wgrad ---------------------------------------
        rows = rows_steps[step]                         # (E_loc, g*Rc, d)
        preacts = None if preacts_steps is None else (
            None if preacts_steps[0] is None else preacts_steps[0][step],
            preacts_steps[1][step])
        d_rows, dw = _mlp_bwd(rows, w, activation, dys, blk, gemm_impl,
                              preacts)
        for k in dw_acc:
            dw_acc[k] = dw_acc[k] + dw[k].astype(jnp.float32)

        # ---- dX: inverse dispatch permutes back to the source -------------
        for j in range(g):
            s = step * g + j
            sn, sl = shifts[s]
            dcr = lax.slice_in_dim(d_rows, j * Rc, (j + 1) * Rc, axis=1)
            if etp > 1:
                by_u = dcr.reshape(E_loc, etp, C, d)
            arrivals = None
            for o in range(etp):
                if etp > 1:
                    piece = jnp.take(by_u, (t_r - o) % etp, axis=1)
                else:
                    piece = dcr
                if s == 0 and o == 0:
                    got = piece
                else:
                    got = lax.ppermute(piece, ax,
                                       _hier_perm(ctx, ig, sn, sl, -o))
                arrivals = got if arrivals is None else arrivals + got
            d_send = lax.dynamic_update_index_in_dim(
                d_send, arrivals.astype(send_dtype),
                _hier_dst(g_r, sn, sl, ig, nn), axis=0)
    return d_send, _cast_like(dw_acc, w)


def _hier_dest_order(g_r, ep: int, ig: int):
    """Traced index array mapping destination order to hier sub-step order:
    ``order[dest]`` = the sub-step whose shift carried this rank's chunk
    for destination group ``dest`` (the inverse of ``_hier_dst`` under the
    ``hier_step_order`` enumeration)."""
    nn = ep // ig
    dd = jnp.arange(ep)
    sn = (g_r // ig - dd // ig) % nn
    sl = (g_r % ig - dd % ig) % ig
    return jnp.where(sn == 0,
                     jnp.where(sl == 0, 0, (nn - 1) * ig + sl),
                     (sn - 1) * ig + sl + 1)


def transport_comet_hier(ctx: AxisCtx, send, w, activation: str,
                         n_col_blocks: int = 1, ring_group: int = 1,
                         intra_group: int = 1, wire_dtype: str = "fp32",
                         gemm_impl: Optional[str] = None,
                         custom_vjp: bool = True, census=None):
    """The fifth transport: comet's decomposed schedule on the two-level
    intra/inter-node ring with an optional low-precision wire format (see
    the section comment above). Returns (blocks, rot) exactly like
    ``transport_comet_blocks``, with ``rot=None``: the streamed column
    blocks are reordered on-rank into DESTINATION order (slot s holds the
    output of this rank's tokens for destination group s), so ``combine``
    consumes them with its naive-order slot map unchanged.

    ``intra_group``/``wire_dtype`` are plan knobs (plan cache v6),
    legalized/validated here with the SAME shared helpers the tuner uses
    (``legalize_intra_group``; ``WIRE_DTYPES``)."""
    ep, E_loc, C, d = send.shape
    if not wire_dtype_supported(wire_dtype):
        raise ValueError(
            f"wire_dtype {wire_dtype!r} not supported here (known: "
            f"{WIRE_DTYPES}; fp8_e4m3 needs a jax with float8_e4m3fn)")

    n_col = legalize_n_col(d, n_col_blocks)
    blk = d // n_col

    if not ctx.active or ctx.world == 1:
        # Single-rank degenerate path: no hop crosses a wire, but the wire
        # QUANTIZATION must still apply (numerics match a real mesh run) —
        # straight-through, mirroring the mesh backward's unquantized ring.
        if wire_dtype != "fp32":
            pay, sc = _wire_encode(send, wire_dtype, per_chunk=True)
            deq = _wire_decode(pay, sc, send.dtype)
            send = send + lax.stop_gradient(deq - send)
        return transport_comet_blocks(ctx, send, w, activation,
                                      n_col_blocks=n_col_blocks,
                                      ring_group=ring_group,
                                      gemm_impl=gemm_impl,
                                      custom_vjp=custom_vjp)

    g = legalize_ring_group(ep, ring_group)
    ig = legalize_intra_group(ep, intra_group)
    ax = ctx.model_axis
    g_r = lax.axis_index(ax) // ctx.etp
    order = _hier_dest_order(g_r, ep, ig)

    if not custom_vjp:
        blocks, _, _ = _comet_hier_fwd(ctx, send, w, activation, n_col, blk,
                                       g, ig, wire_dtype, gemm_impl,
                                       census=census)
        return [jnp.take(bk, order, axis=0) for bk in blocks], None

    send_shape, send_dtype = send.shape, send.dtype

    @jax.custom_vjp
    def ring(send_, w_):
        blocks, _, _ = _comet_hier_fwd(ctx, send_, w_, activation, n_col,
                                       blk, g, ig, wire_dtype, gemm_impl)
        return blocks

    def ring_fwd(send_, w_):
        blocks, rows_steps, preacts_steps = _comet_hier_fwd(
            ctx, send_, w_, activation, n_col, blk, g, ig, wire_dtype,
            gemm_impl)
        return blocks, (rows_steps, preacts_steps, w_)

    def ring_bwd(res, cts):
        rows_steps, preacts_steps, w_ = res
        return _comet_hier_bwd(ctx, rows_steps, preacts_steps, w_, cts,
                               activation, n_col, blk, g, ig, send_shape,
                               send_dtype, gemm_impl)

    ring.defvjp(ring_fwd, ring_bwd)
    # the destination-order reorder stays OUTSIDE the custom_vjp: autodiff
    # transposes the take, so the backward ring sees sub-step-order cts
    return [jnp.take(bk, order, axis=0) for bk in ring(send, w)], None


# ---------------------------------------------------------------------------
# bcast: decode path — tokens replicated over the model axis
# ---------------------------------------------------------------------------


def transport_bcast(ctx: AxisCtx, buf_full, w, activation: str,
                    gemm_impl: Optional[str] = None):
    """buf_full: (E, C, d) — identical on every model rank. Each rank runs its
    own expert slice; a single psum over the model axis both sums ETP partials
    and merges expert groups. Returns (E, C, d) fully combined."""
    E, C, d = buf_full.shape
    if not ctx.active or ctx.world == 1:
        rows = buf_full
        out = expert_mlp(ctx, rows, w, activation, gemm_impl)
        return out
    ax = ctx.model_axis
    E_loc = E // ctx.ep
    r = lax.axis_index(ax)
    g_r = r // ctx.etp
    mine = lax.dynamic_slice_in_dim(buf_full, g_r * E_loc, E_loc, axis=0)
    out = _mlp_out(mine, w, activation, gemm_impl)                  # partial
    full = jnp.zeros((E, C, d), out.dtype)
    full = lax.dynamic_update_slice_in_dim(full, out, g_r * E_loc, axis=0)
    return lax.psum(full, ax)
