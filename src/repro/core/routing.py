"""Routing + shared-tensor construction (the paper's §3.1 substrate).

The *shared tensor* between dispatch (producer) and expert GEMM (consumer) is
the ``(E, C, d)`` dispatch buffer: decomposed along the token dim **M** into
per-destination-group chunks (layer 0), and along the hidden dim **N** into
column blocks (layer 1). All transports (naive / coarse / comet / bcast) use
*identical* routing, capacity and slot assignment so their outputs are
numerically identical — the equivalence tests rely on this.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass
class DispatchInfo:
    flat_e: jnp.ndarray      # (T*k,) expert id per (token, choice)
    pos: jnp.ndarray         # (T*k,) slot within expert queue
    keep: jnp.ndarray        # (T*k,) bool, False = dropped by capacity
    T: int
    k: int


def capacity(T: int, k: int, E: int, factor: float, multiple: int = 4) -> int:
    c = math.ceil(T * k / E * factor)
    c = max(multiple, multiple * math.ceil(c / multiple))
    return c


def router(x, w_router, mcfg, token_axes=()):
    """x: (T, d). Returns (idx (T,k), weights (T,k), aux_loss scalar fp32).

    token_axes: mesh axis names over which tokens are sharded; the Switch
    load-balance statistics (me, ce) are psum-averaged over them *before*
    taking the product, so the aux loss is identical under any sharding.
    """
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, mcfg.top_k)
    if mcfg.router_norm_topk:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    E = logits.shape[-1]
    # Switch-style load-balance loss
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(idx.size, 1)
    if token_axes:
        me = jax.lax.pmean(me, token_axes)
        ce = jax.lax.pmean(ce, token_axes)
    aux = E * jnp.sum(me * ce) * mcfg.aux_loss_coef
    return idx, w, aux


def build_dispatch(x, idx, E: int, C: int) -> Tuple[jnp.ndarray, DispatchInfo]:
    """x: (T, d); idx: (T, k). Builds the shared tensor (E, C, d) with tokens
    sorted by (expert, arrival order) — slot = position in expert queue.

    Sort-based slot assignment: ranks come from an argsort over the composite
    key ``expert_id * T*k + arrival``, so the rank-in-queue of a (token,
    choice) is its position in the sorted order minus its expert's segment
    offset — O(T·k·log(T·k)) work instead of the O(T·k·E) one-hot cumsum.
    The buffer is then filled by ONE (E*C, d) gather through the inverse
    slot→token map; the (T*k, d) ``jnp.repeat`` copy of all activations the
    one-hot path needed is never materialized. Bit-identical to the one-hot
    reference (tests/test_fused_pipeline.py checks exactness)."""
    T, k = idx.shape
    d = x.shape[-1]
    TK = T * k
    flat_e = idx.reshape(-1).astype(jnp.int32)                     # (T*k,)
    # jnp.argsort is stable (lax.sort is_stable), so equal expert ids keep
    # arrival order — no composite key needed (one would overflow int32 at
    # E*T*k >= 2^31)
    order = jnp.argsort(flat_e)                                    # (T*k,)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])            # (E,)
    rank_sorted = jnp.arange(TK, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((TK,), jnp.int32).at[order].set(rank_sorted)   # (T*k,)
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + jnp.minimum(pos, C - 1), E * C)
    # inverse map slot -> source token row; dropped (token, choice) pairs
    # scatter to the out-of-bounds slot E*C and vanish under mode="drop"
    tok = jnp.arange(TK, dtype=jnp.int32) // k
    src = jnp.zeros((E * C,), jnp.int32).at[slot].set(tok, mode="drop")
    filled = jnp.zeros((E * C,), jnp.bool_).at[slot].set(True, mode="drop")
    buf = jnp.where(filled[:, None], x[src], jnp.zeros((), x.dtype))
    return buf.reshape(E, C, d), DispatchInfo(flat_e, pos, keep, T, k)


def combine(recv_flat, info: DispatchInfo, weights, E_loc: int, C: int,
            rot: Optional[jnp.ndarray], ep: int) -> jnp.ndarray:
    """recv_flat: (ep*E_loc*C, d) expert outputs; slot layout (s, l, c) where
    chunk index s ↔ destination group g via ``g == s`` (naive; rot None) or
    ``s == (rot - g) % ep`` (comet ring rotation, rot = my group index).
    Returns (T, d) = top-k weighted sum, dropped slots contribute zero.

    The gather (slot → token rows) stays in XLA's gather engine; the fp32
    weighted reduction runs in the Pallas ``topk_combine`` kernel (the
    paper's layer-1 consumer), differentiable via its custom VJP — on TPU,
    or in interpret mode on CPU. Other backends (e.g. CUDA jax, where the
    Pallas TPU lowering does not exist) keep the pure-jnp reduction, same
    numerics. In the comet schedule ``d`` may be a single column block —
    the reduction is columnwise, so per-block combines concatenate to the
    full-width result."""
    g = info.flat_e // E_loc
    l = info.flat_e % E_loc
    s_idx = g if rot is None else (rot - g) % ep
    idx = (s_idx * E_loc + l) * C + jnp.minimum(info.pos, C - 1)
    rows = recv_flat[idx]                                          # (T*k, d)
    rows = jnp.where(info.keep[:, None], rows, 0)
    rows = rows.reshape(info.T, info.k, -1)
    if jax.default_backend() in ("cpu", "tpu"):
        from repro.kernels import ops
        return ops.topk_combine_diff(rows, weights)
    w = weights.astype(jnp.float32)[..., None]
    return jnp.sum(rows.astype(jnp.float32) * w, axis=1).astype(recv_flat.dtype)


def moe_flops(T: int, k: int, d: int, f: int, glu: bool) -> int:
    """Active FLOPs of one MoE FFN on T tokens (for roofline / adaptive)."""
    n_mat = 3 if glu else 2
    return 2 * T * k * n_mat * d * f
