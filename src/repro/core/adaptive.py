"""Adaptive workload assignment (paper §3.2.2, TPU-native).

The paper balances communication vs computation by moving SMs between
thread-block roles (n_c comm blocks out of 132). On TPU the ICI DMA engines
are disjoint from the MXU, so there is no SM budget to split — the balancing
knob that remains is the PIPELINE GEOMETRY:

* ``n_col_blocks`` — layer-1 N-decomposition granularity (paper Fig. 6 T_N):
  more blocks → earlier first-combine and finer return-traffic interleave,
  but smaller GEMM tiles (alignment floor: blocks of ≥128 columns keep the
  MXU full, the exact analogue of the paper's tile-efficiency constraint).
* ring chunking is fixed by EP (ep-1 hops), and the per-chunk compute is
  M/ep rows — the dispatch-side balance is achieved when per-chunk GEMM time
  ≈ per-hop ICI time, which the cost model reports as ``dispatch_balance``.

Two layers, same as the paper:
1. an ANALYTICAL model (roofline arithmetic from hardware constants) picks a
   starting config — this replaces profiling where no hardware is attached;
2. a PROFILE CACHE stores measured-best configs keyed by
   (M, N, K, E, topk, ep, etp, hw) — the direct analogue of Comet's
   pre-compiled kernel metadata, filled by ``tune()`` when a timing callback
   is available (real TPU runs; benchmarks/ wires the simulator in).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import re
from typing import Callable, Dict, Iterable, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float                 # peak dense bf16 FLOP/s per chip
    hbm_bw: float                # bytes/s
    link_bw: float               # bytes/s per interconnect link/direction
    links: int = 1               # usable links per chip for the ring
    gemm_eff: float = 0.7        # sustained fraction of peak for big GEMMs
    small_tile_penalty: float = 0.55   # efficiency when M-tile < 128 rows
    # per-core VMEM budget for a Pallas kernel's working set (the verify
    # pass and candidate_plans both gate tilings on this)
    vmem_bytes: int = 32 * 2**20
    # --- topology descriptor (two link classes) -----------------------
    # A flat fabric leaves these at their defaults: intra_bw/inter_bw of
    # 0.0 mean "same as link_bw", intra_group=1 means every hop is
    # inter-class. An asymmetric preset sets link_bw = inter_bw so every
    # FLAT transport (whose ppermutes always span node boundaries)
    # automatically prices at the slow class with no code changes.
    intra_bw: float = 0.0        # bytes/s within a node (NVLink/ICI pod)
    inter_bw: float = 0.0        # bytes/s across nodes (RDMA/DCN)
    intra_group: int = 1         # devices per node on the EP axis
    # fixed software/DMA-setup latency per fine-grained transfer: this is
    # what makes the optimal decomposition COARSER at small M and FINER at
    # large M (the paper's Fig. 8 shift of the optimal division point)
    hop_latency_s: float = 5e-6


TPU_V5E = Hardware("tpu_v5e", flops=197e12, hbm_bw=819e9, link_bw=50e9,
                   links=2)
H100_NVL = Hardware("h100_nvlink", flops=990e12, hbm_bw=3.35e12,
                    link_bw=377e9, links=1, gemm_eff=0.65)
L20_PCIE = Hardware("l20_pcie", flops=119e12, hbm_bw=864e9, link_bw=25e9,
                    links=1, gemm_eff=0.6)
# asymmetric topology: 4-GPU NVLink nodes joined by RDMA — the regime
# MoNTA/MegaScale-MoE target. link_bw == inter_bw so every flat transport
# prices at the slow class (its ppermutes always have a cross-node pair).
H100_CROSSNODE = Hardware("h100_crossnode", flops=990e12, hbm_bw=3.35e12,
                          link_bw=50e9, links=1, gemm_eff=0.65,
                          intra_bw=377e9, inter_bw=50e9, intra_group=4)

HW = {h.name: h for h in (TPU_V5E, H100_NVL, L20_PCIE, H100_CROSSNODE)}


# ---------------------------------------------------------------------------
# Analytical cost terms for one MoE layer (per device)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEShape:
    M: int          # tokens on this device's group before dispatch
    N: int          # d_model
    K: int          # d_expert (per-device after ETP split)
    E: int          # global experts
    topk: int
    ep: int
    etp: int
    glu: bool = True
    bytes_per_elt: int = 2


# kept for import compatibility; the knob itself is now the per-hardware
# ``Hardware.hop_latency_s`` field (a module-level tunable skirts the
# mutable-global lint's spirit)
HOP_LATENCY_S = 5e-6

# wire formats for the hierarchical transport's dispatch payloads and
# per-column-block combine partials. "fp32" is the identity format (native
# payload dtype on the wire; the name records the dequant/accum width);
# "bf16" is a plain cast; "fp8_e4m3" is per-chunk symmetric-scale
# quantization (optim/compression.py's scale machinery at fp8 range).
WIRE_DTYPES = ("fp32", "bf16", "fp8_e4m3")

# bytes/element on the wire; None = the payload's native width
_WIRE_BYTES = {"fp32": None, "bf16": 2, "fp8_e4m3": 1}


def wire_bytes_per_elt(s: "MoEShape", wire_dtype: str) -> float:
    b = _WIRE_BYTES.get(wire_dtype)
    return float(s.bytes_per_elt if b is None else b)


@functools.lru_cache(maxsize=1)
def _fp8_wire_available() -> bool:
    """fp8 wire candidates need a jax with float8_e4m3fn (checked lazily —
    this module must stay importable without jax)."""
    try:
        import jax.numpy as jnp
        return hasattr(jnp, "float8_e4m3fn")
    except Exception:
        return False


def gemm_time(hw: Hardware, rows: int, n: int, k: int, n_mats: int = 1) -> float:
    """Time for rows×k @ k×n (n_mats of them), with small-tile derating."""
    eff = hw.gemm_eff if rows >= 128 else hw.gemm_eff * hw.small_tile_penalty
    return n_mats * 2.0 * rows * n * k / (hw.flops * eff)


def layer_times(hw: Hardware, s: MoEShape) -> Dict[str, float]:
    """Per-chunk / per-hop costs for the comet schedule (fwd and bwd)."""
    rows_per_chunk = s.M * s.topk / s.ep          # expert rows from one source group
    n_l0 = 2 if s.glu else 1                       # gate+up vs up
    t_gemm1 = gemm_time(hw, rows_per_chunk, s.K, s.N, n_l0)
    t_gemm2 = gemm_time(hw, rows_per_chunk, s.N, s.K)
    chunk_bytes = rows_per_chunk * s.N * s.bytes_per_elt
    t_hop = hw.hop_latency_s + chunk_bytes / (hw.link_bw * hw.links)
    # backward per-chunk GEMM work: dgrad (dh = dY·w_downᵀ, dX = dh·w_l0ᵀ)
    # + wgrad (dw_down = hᵀ·dY, dw_l0 = xᵀ·dh) ≈ 2× forward. The fused
    # backend's in-VMEM hidden recompute is an extra t_gemm1 charged where
    # the backend is known (unfused backends save the pre-activations).
    t_bwd_gemm = 2.0 * (t_gemm1 + t_gemm2)
    return {
        "t_gemm1": t_gemm1, "t_gemm2": t_gemm2,
        "t_chunk_compute": t_gemm1 + t_gemm2,
        "t_hop": t_hop,
        "dispatch_balance": t_hop / max(t_gemm1 + t_gemm2, 1e-12),
        "t_bwd_gemm": t_bwd_gemm,
        # reverse-hop balance: each backward chunk moves dY in AND dX out
        "bwd_balance": 2.0 * t_hop / max(t_bwd_gemm, 1e-12),
    }


# ---------------------------------------------------------------------------
# Knob legalization — the ONE place transport geometry is made legal. Both
# the tuner (before ranking/persisting) and the transports (at trace time)
# use these, so the cost model, hot_path_hbm_bytes and execution can never
# disagree about the knobs that actually run.
# ---------------------------------------------------------------------------

MAX_COL_BLOCKS = 8


def legalize_n_col(d_model: int, n_col: int,
                   max_blocks: int = MAX_COL_BLOCKS) -> int:
    """Largest legal layer-1 column split ≤ the requested one: clamped to
    [1, max_blocks] and decremented until it divides d_model."""
    n = max(1, min(int(n_col), max_blocks))
    while d_model % n:
        n -= 1
    return n


def legalize_ring_group(ep: int, ring_group: int) -> int:
    """Largest legal macro-step fusion ≤ the requested one: clamped to
    [1, ep] and decremented until it divides ep."""
    ep = max(1, ep)
    g = max(1, min(int(ring_group), ep))
    while ep % g:
        g -= 1
    return g


def legalize_intra_group(ep: int, intra_group: int) -> int:
    """Largest legal node size ≤ the requested one: clamped to [1, ep] and
    decremented until it divides ep. Shared by the tuner, the cost model
    and transport_comet_hier (same convention as legalize_ring_group)."""
    ep = max(1, ep)
    ig = max(1, min(int(intra_group), ep))
    while ep % ig:
        ig -= 1
    return ig


def legalize_plan(plan: "Plan", d_model: int, ep: int) -> "Plan":
    """Return ``plan`` with executable knobs — what transport_comet_blocks
    / transport_comet_hier will actually run for this (d_model, ep).
    ``intra_group`` is a hier-only knob: hier plans get it legalized
    against ep, every other transport normalizes it to 1."""
    n = legalize_n_col(d_model, plan.n_col_blocks)
    g = legalize_ring_group(ep, plan.ring_group)
    ig = (legalize_intra_group(ep, plan.intra_group)
          if plan.impl == "comet_hier" else 1)
    if (n, g, ig) == (plan.n_col_blocks, plan.ring_group, plan.intra_group):
        return plan
    return dataclasses.replace(plan, n_col_blocks=n, ring_group=g,
                               intra_group=ig)


def choose_n_col(hw: Hardware, s: MoEShape, max_blocks: int = 8,
                 align: int = 128) -> int:
    """Pick the layer-1 N-decomposition: the finest column split whose
    per-block GEMM still runs at full tile efficiency (block ≥ align cols)
    and whose per-block return-hop stays ≤ per-block compute (no comm-bound
    tail). Mirrors the paper's observation that the optimal n_c grows with M
    and with communication burden (lower TP / higher bandwidth need)."""
    best = 1
    for n_col in range(1, max_blocks + 1):
        blk = s.N // n_col
        if blk < align or s.N % n_col:
            continue
        rows = s.M * s.topk / s.ep
        t_blk_gemm = gemm_time(hw, rows, blk, s.K)
        t_blk_hop = (hw.hop_latency_s
                     + rows * blk * s.bytes_per_elt / (hw.link_bw * hw.links))
        if t_blk_hop <= t_blk_gemm * 1.05:
            best = n_col
    return best


# ---------------------------------------------------------------------------
# Profile cache (the paper's pre-compiled kernel metadata analogue)
# ---------------------------------------------------------------------------


class AdaptiveCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.table: Dict[str, Dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self.table = json.load(f)

    @staticmethod
    def key(s: MoEShape, hw: Hardware, phase: str = "train") -> str:
        base = (f"{hw.name}:M{s.M}:N{s.N}:K{s.K}:E{s.E}:k{s.topk}"
                f":ep{s.ep}:etp{s.etp}")
        # the train phase keeps the historical unqualified key so every
        # pre-v4 cache entry keeps resolving; serving phases qualify it
        return base if phase in ("", "train") else f"{base}:ph{phase}"

    def get(self, s: MoEShape, hw: Hardware) -> Optional[Dict]:
        return self.table.get(self.key(s, hw))

    def put(self, s: MoEShape, hw: Hardware, cfg: Dict):
        self.table[self.key(s, hw)] = cfg
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.table, f, indent=1)

    def tune(self, s: MoEShape, hw: Hardware,
             candidates: Iterable[Dict],
             measure: Callable[[Dict], float]) -> Dict:
        """Profile-guided: measure each candidate once, cache the argmin."""
        hit = self.get(s, hw)
        if hit is not None:
            return hit
        best_cfg, best_t = None, math.inf
        for cfg in candidates:
            t = measure(cfg)
            if t < best_t:
                best_cfg, best_t = dict(cfg), t
        best_cfg["measured_s"] = best_t
        self.put(s, hw, best_cfg)
        return best_cfg


def default_candidates(s: MoEShape, max_blocks: int = 8):
    for n_col in range(1, max_blocks + 1):
        if s.N % n_col == 0 and s.N // n_col >= 128:
            yield {"n_col_blocks": n_col}


def resolve_n_col(mcfg, cfg_d_model: int, tokens_local: int,
                  ep: int, etp: int, hw: Hardware = TPU_V5E) -> int:
    """Entry used by moe_layer when mcfg.n_col_blocks == 0 (adaptive)."""
    if mcfg.n_col_blocks:
        return mcfg.n_col_blocks
    s = plan_shape(mcfg, cfg_d_model, tokens_local, ep, etp)
    return choose_n_col(hw, s)


# ---------------------------------------------------------------------------
# Adaptive transport plans (the tentpole): a full schedule — transport impl ×
# ring_group × n_col_blocks × gemm backend — tuned per shape and persisted.
# ``tune_plan`` measures real shard_map executions when a timing callback is
# supplied and falls back to the discrete-event simulator / roofline model
# otherwise, so the same cache format serves offline (tools/tune.py) and
# attached-hardware tuning.
# ---------------------------------------------------------------------------


# Schema history:
#   v2 (PR 2) — plans gained ``gemm_impl="pallas_fused"`` and the
#     ``fused_combine`` flag.
#   v3 (PR 3) — plans are ranked on FORWARD + BACKWARD step time (the
#     custom-VJP comet backward ring); ``measured_s`` is the fwd+bwd total,
#     ``t_bwd_s`` its backward component (0 when only the total was timed),
#     and ``objective`` records what the ranking covered. Knobs are stored
#     LEGALIZED (see ``legalize_plan``). v1/v2 caches load unchanged —
#     ``Plan.from_json`` defaults the missing fields (objective="fwd").
#   v4 (PR 4) — keys gained a LATENCY PHASE: ``train`` plans keep the
#     unqualified v3 key (ranked fwd+bwd as before, so every pre-v4 cache
#     still loads and resolves), while ``:phprefill`` / ``:phdecode``
#     entries rank on forward-only objectives — decode on per-step latency
#     (tiny-M shapes where the constant terms legalize toward bcast /
#     small ring groups; no backward exists at inference), prefill on
#     chunk throughput. ``Plan.phase`` records which ranking produced it.
#   v5 (PR 6) — WHOLE-GRAPH schedules rank beside per-layer plans: plans
#     gained ``schedule`` ("" = per-layer execution; "overlap" = the
#     block-schedule IR's cross-layer order, core/schedule.py) and
#     ``n_slices`` (Lancet-style token micro-slicing that creates the
#     legal cross-layer motion). Graph candidates are ranked on the
#     two-block whole-graph model (``modeled_graph_step_time``), per-layer
#     candidates exactly as in v4. v4 and older caches load unchanged —
#     ``Plan.from_json`` defaults schedule=""/n_slices=1 (per-layer).
#   v6 (PR 9) — TOPOLOGY-AWARE plans: the ``comet_hier`` transport (two-
#     level intra/inter-node ring) joins TRANSPORTS, and plans gained
#     ``intra_group`` (devices per node on the EP axis; hier-only knob,
#     stored legalized via the shared ``legalize_intra_group``) and
#     ``wire_dtype`` (dispatch/combine wire format: fp32 | bf16 |
#     fp8_e4m3 — non-fp32 only legal on comet_hier). v5 and older caches
#     load unchanged — ``Plan.from_json`` defaults intra_group=1 /
#     wire_dtype="fp32" (the flat, full-precision schedule).
PLAN_CACHE_VERSION = 6

TRANSPORTS = ("naive", "coarse", "comet", "comet_hier", "bcast")
PLAN_PHASES = ("train", "prefill", "decode")

# what each phase's ranking objective covers (persisted in Plan.objective)
PHASE_OBJECTIVES = {"train": "fwd_bwd", "prefill": "prefill_tput",
                    "decode": "decode_latency"}


@dataclasses.dataclass(frozen=True)
class Plan:
    """One concrete MoE-layer schedule. ``measured_s`` is the winning latency
    under the measure that selected it; ``source`` records whether that was a
    real timed execution ("measured") or the analytical model ("model")."""
    impl: str = "comet"
    ring_group: int = 1
    n_col_blocks: int = 1
    gemm_impl: str = "xla"
    fused_combine: bool = False
    measured_s: float = 0.0
    source: str = "model"
    t_bwd_s: float = 0.0               # backward component of measured_s
    objective: str = "fwd_bwd"         # what measured_s ranked: fwd |
                                       # fwd_bwd | prefill_tput | decode_latency
    phase: str = "train"               # latency phase the plan was ranked for
    schedule: str = ""                 # "" = per-layer execution; "overlap"
                                       # = whole-graph block-schedule order
                                       # (core/schedule.py)
    n_slices: int = 1                  # token micro-slices creating the
                                       # cross-layer overlap freedom
    intra_group: int = 1               # devices per node on the EP axis
                                       # (comet_hier two-level ring; 1 on
                                       # every other transport)
    wire_dtype: str = "fp32"           # dispatch/combine wire format
                                       # (comet_hier; fp32 = native bytes)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "Plan":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        # pre-v3 entries were ranked on forward time only; say so rather
        # than defaulting to the v3 objective
        kw.setdefault("objective", "fwd")
        plan = cls(**kw)
        # dataclasses don't type-check: a hand-edited / bit-rotted entry
        # with a string where a knob belongs must raise HERE (the cache
        # loader skips it) rather than explode deep inside plan resolution
        for f, ty in (("impl", str), ("ring_group", int),
                      ("n_col_blocks", int), ("n_slices", int),
                      ("intra_group", int), ("wire_dtype", str),
                      ("measured_s", (int, float)),
                      ("t_bwd_s", (int, float))):
            if not isinstance(getattr(plan, f), ty):
                raise ValueError(f"plan field {f}={getattr(plan, f)!r} "
                                 f"is not {ty}")
        return plan

    def validate(self, d_model: Optional[int] = None,
                 ep: Optional[int] = None) -> list:
        """Static legality of the knob settings — everything checkable
        without hardware. Returns a list of problem strings (empty =
        legal). With ``d_model``/``ep`` supplied, also requires the knobs
        to be PRE-legalized (v3+ caches store legalized knobs; an entry
        that re-legalizes differently would execute different geometry
        than the tuner ranked)."""
        bad = []
        if self.impl not in TRANSPORTS:
            bad.append(f"impl {self.impl!r} not in {TRANSPORTS}")
        if not 1 <= self.n_col_blocks <= MAX_COL_BLOCKS:
            bad.append(f"n_col_blocks {self.n_col_blocks} outside "
                       f"[1, {MAX_COL_BLOCKS}]")
        if self.ring_group < 1:
            bad.append(f"ring_group {self.ring_group} < 1")
        if self.intra_group < 1:
            bad.append(f"intra_group {self.intra_group} < 1")
        if self.wire_dtype not in WIRE_DTYPES:
            bad.append(f"wire_dtype {self.wire_dtype!r} not in {WIRE_DTYPES}")
        elif self.wire_dtype != "fp32" and self.impl != "comet_hier":
            bad.append(f"wire_dtype {self.wire_dtype!r} requires the "
                       "comet_hier transport")
        if self.gemm_impl not in ("", "xla", "pallas", "pallas_fused"):
            bad.append(f"unknown gemm_impl {self.gemm_impl!r}")
        if self.phase not in PLAN_PHASES:
            bad.append(f"phase {self.phase!r} not in {PLAN_PHASES}")
        if self.schedule not in ("", "overlap"):
            bad.append(f"unknown schedule {self.schedule!r}")
        if self.n_slices < 1:
            bad.append(f"n_slices {self.n_slices} < 1")
        if self.schedule == "" and self.n_slices != 1:
            bad.append("per-layer schedule with n_slices != 1")
        if self.schedule == "overlap" and (self.n_slices < 2
                                           or self.impl != "comet"):
            bad.append("overlap schedule requires comet with >= 2 slices")
        if not bad and d_model is not None and ep is not None:
            lg = legalize_plan(self, d_model, ep)
            if ((lg.n_col_blocks, lg.ring_group, lg.intra_group)
                    != (self.n_col_blocks, self.ring_group,
                        self.intra_group)):
                bad.append(
                    f"knobs ({self.n_col_blocks}, {self.ring_group}, "
                    f"{self.intra_group}) not legal for d_model={d_model}, "
                    f"ep={ep} (legalize to ({lg.n_col_blocks}, "
                    f"{lg.ring_group}, {lg.intra_group}))")
        return bad

    def apply(self, mcfg):
        """Return ``mcfg`` running this plan's schedule. Sets
        ``plan_override`` so nested calls do not re-resolve the plan."""
        return dataclasses.replace(
            mcfg, impl=self.impl, ring_group=self.ring_group,
            n_col_blocks=max(1, self.n_col_blocks),
            fused_combine=self.fused_combine, gemm_impl=self.gemm_impl,
            intra_group=max(1, self.intra_group),
            wire_dtype=self.wire_dtype, plan_override=True)


def plan_shape(mcfg, d_model: int, tokens_local: int, ep: int,
               etp: int) -> MoEShape:
    """The (M, d, f, E, topk, ep, etp) key shape for plan lookup — must be
    built identically by the tuner and by moe_layer's resolution. With
    BigMac descend-ascend experts (``mcfg.wire_dim``) the ring moves
    wire-width rows, so N IS the wire width: the cost model, the plan key,
    and knob legalization (n_col divides the combine width) all follow."""
    wire = getattr(mcfg, "wire_dim", 0)
    return MoEShape(M=tokens_local, N=wire or d_model,
                    K=mcfg.d_expert // max(1, etp), E=mcfg.num_experts,
                    topk=mcfg.top_k, ep=ep, etp=etp)


_KEY_GEOM_RE = re.compile(r":N(\d+):.*:ep(\d+):")


def _key_geometry(key: str) -> Tuple[Optional[int], Optional[int]]:
    """(d_model, ep) parsed from a cache key, (None, None) if the key is
    not in the canonical format — validation then skips the legality-vs-
    geometry part and checks only the static ranges."""
    m = _KEY_GEOM_RE.search(key)
    return (int(m.group(1)), max(1, int(m.group(2)))) if m else (None, None)


class PlanCache:
    """JSON-backed map  shape-key -> Plan  (Comet's pre-compiled kernel
    metadata analogue, but holding full transport schedules)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.plans: Dict[str, Plan] = {}
        if path and os.path.exists(path):
            self.load(path)

    @staticmethod
    def key(s: MoEShape, hw: Hardware, phase: str = "train") -> str:
        return AdaptiveCache.key(s, hw, phase)

    def load(self, path: str):
        import warnings
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError) as e:
            # a corrupt/unreadable cache must not take the run down — behave
            # like a missing file (analytical fallback) and say so
            warnings.warn(f"plan cache {path!r} unreadable ({e}); starting "
                          "empty — plans fall back to the analytical model",
                          stacklevel=2)
            self.plans = {}
            return
        version = raw.get("version", 0) if isinstance(raw, dict) else 0
        if isinstance(version, (int, float)) and version > PLAN_CACHE_VERSION:
            # a future format may mean anything; retuning is cheap, silently
            # misreading a newer schema is not
            warnings.warn(f"plan cache {path!r} has version {version} > "
                          f"supported {PLAN_CACHE_VERSION}; starting empty",
                          stacklevel=2)
            self.plans = {}
            return
        table = raw.get("plans", raw) if isinstance(raw, dict) else {}
        self.plans = {}
        bad = 0
        for k, v in table.items():
            if not (isinstance(v, dict) and "impl" in v):
                bad += 1
                continue
            try:
                plan = Plan.from_json(v)
            except (TypeError, ValueError, KeyError):
                bad += 1        # one mangled entry must not drop the rest
                continue
            geom = _key_geometry(k)
            problems = plan.validate(*geom)
            if problems and not plan.validate():
                # knobs are statically fine but not pre-legalized (a
                # hand-written or pre-v3 entry): resolve to the executable
                # schedule the transport would run, same as resolve-time
                # legalization always has
                plan = legalize_plan(plan, *geom)
                problems = plan.validate(*geom)
            if problems:
                # an illegal entry (hand-edited, or written by a broken
                # tuner) would execute geometry nobody ranked — skip it
                warnings.warn(f"plan cache {path!r}: entry {k!r} illegal "
                              f"({'; '.join(problems)}); skipped",
                              stacklevel=2)
                bad += 1
                continue
            self.plans[k] = plan
        if bad:
            warnings.warn(f"plan cache {path!r}: skipped {bad} malformed "
                          f"entr{'y' if bad == 1 else 'ies'}", stacklevel=2)

    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            raise ValueError("PlanCache has no path to save to")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # atomic: a concurrent load_plan_cache must never see a torn file
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": PLAN_CACHE_VERSION,
                       "plans": {k: p.to_json()
                                 for k, p in sorted(self.plans.items())}},
                      f, indent=1)
        os.replace(tmp, path)

    def get(self, s: MoEShape, hw: Hardware,
            phase: str = "train") -> Optional[Plan]:
        return self.plans.get(self.key(s, hw, phase))

    def put(self, s: MoEShape, hw: Hardware, plan: Plan, save: bool = True,
            phase: str = "train"):
        problems = plan.validate(s.N, max(1, s.ep))
        if problems:
            raise ValueError(f"refusing to cache illegal plan for "
                             f"{self.key(s, hw, phase)}: "
                             f"{'; '.join(problems)}")
        self.plans[self.key(s, hw, phase)] = plan
        if save and self.path:
            self.save()


def candidate_plans(s: MoEShape, max_col_blocks: int = 8,
                    max_ring_group: int = 4,
                    gemm_impls: Tuple[str, ...] = ("xla", "pallas_fused"),
                    include_bcast: bool = True,
                    include_graph: bool = False,
                    hw: Optional[Hardware] = None) -> Iterable[Plan]:
    """The search space: every transport with its legal knob settings.

    The default backend set omits ``"pallas"`` — the analytical model rates
    it identically to ``"xla"`` (same GEMMs, same HBM traffic), so including
    it only duplicates candidates; measured tuning (tools/tune.py --gemm)
    can add it. ``"pallas_fused"`` IS modeled (the saved hidden HBM round
    trip vs. the per-column-block GEMM1 recompute), as is the comet
    ``fused_combine`` streaming-consumer flag.

    ``include_graph=True`` adds WHOLE-GRAPH variants of every comet
    candidate: ``schedule="overlap"`` with 2 or 4 token micro-slices
    (n_slices=1 has no cross-layer freedom — attn_{i+1} truly depends on
    combine_i — so it is never a distinct candidate). These rank on the
    two-block graph model (``modeled_graph_step_time``) against the
    per-layer candidates.

    ``hw`` (default TPU_V5E) gates Pallas candidates on its VMEM budget:
    a tiling whose double-buffered working set cannot fit is rejected
    HERE, statically, so the tuner never ranks — and the cache never
    persists — a plan that would fault at trace time. A Hardware with
    ``vmem_bytes=0`` disables the gate (the verify pass uses this to
    test the filter itself)."""
    hw = TPU_V5E if hw is None else hw
    from repro.analysis.verify.kernel_check import plan_vmem_ok
    n_cols = [n for n in range(1, max_col_blocks + 1)
              if s.N % n == 0 and s.N // n >= 128] or [1]
    rings = [g for g in range(1, min(max_ring_group, s.ep) + 1)
             if s.ep % g == 0] or [1]
    for gi in gemm_impls:
        for p in (Plan("naive", 1, 1, gi), Plan("coarse", 1, 1, gi)):
            if plan_vmem_ok(s, p, hw):
                yield p
        for rg in rings:
            for n_col in n_cols:
                for fc in (False, True):
                    p = Plan("comet", rg, n_col, gi, fc)
                    if not plan_vmem_ok(s, p, hw):
                        continue
                    yield p
                    if include_graph:
                        for ns in (2, 4):
                            yield Plan("comet", rg, n_col, gi, fc,
                                       schedule="overlap", n_slices=ns)
        # hierarchical variants only where the topology declares real node
        # structure (1 < intra_group < ep after legalization): a flat
        # fabric gains nothing and the flat presets stay byte-identical in
        # the candidate stream. Wire formats are a hier-only knob; fp8 is
        # enumerated only when this jax can represent it.
        ig = legalize_intra_group(s.ep, hw.intra_group)
        if 1 < ig < s.ep:
            wires = ["fp32", "bf16"]
            if _fp8_wire_available():
                wires.append("fp8_e4m3")
            for rg in rings:
                for n_col in n_cols:
                    for fc in (False, True):
                        for wd in wires:
                            p = Plan("comet_hier", rg, n_col, gi, fc,
                                     intra_group=ig, wire_dtype=wd)
                            if plan_vmem_ok(s, p, hw):
                                yield p
        if include_bcast:
            p = Plan("bcast", 1, 1, gi)
            if plan_vmem_ok(s, p, hw):
                yield p


# ---------------------------------------------------------------------------
# Topology-aware hop pricing (the comet_hier two-level ring). One shared
# overlap formula — ``exposed_comm_from_hops`` — consumes per-sub-step hop
# times from EITHER the analytical profile below (modeled) or from a census
# of executed ppermutes (benchmarks/run.py's interpret measurement), so the
# two exposed-comm figures differ only in where the traffic came from.
# ---------------------------------------------------------------------------


def hier_step_order(ep: int, intra_group: int) -> list:
    """Sub-step (node_shift, local_shift) sequence of the two-level ring.

    Step 0 is the local chunk. The inter-node steps come FIRST (the slow
    hops overlap the most remaining compute), the intra-node steps land in
    the tail where little compute is left to hide them — which is also why
    the hierarchical ring's unavoidable last-return-hop exposure is priced
    at the fast class while flat comet pays the slow one."""
    ep = max(1, ep)
    ig = legalize_intra_group(ep, intra_group)
    nn = ep // ig
    order = [(0, 0)]
    for sn in range(1, nn):
        for sl in range(ig):
            order.append((sn, sl))
    for sl in range(1, ig):
        order.append((0, sl))
    return order


def hier_step_classes(ep: int, intra_group: int) -> list:
    """Per-sub-step link class: "local" | "intra" | "inter"."""
    out = []
    for sn, sl in hier_step_order(ep, intra_group):
        if sn == 0 and sl == 0:
            out.append("local")
        elif sn == 0:
            out.append("intra")
        else:
            out.append("inter")
    return out


def link_class_bw(hw: Hardware, cls: str) -> float:
    """Raw bytes/s of one link class (falls back to the flat link_bw when
    the topology descriptor leaves a class unset)."""
    if cls == "intra":
        return (hw.intra_bw or hw.link_bw) * hw.links
    return (hw.inter_bw or hw.link_bw) * hw.links


def hop_time_profile(hw: Hardware, s: MoEShape, plan: "Plan") -> list:
    """Per-sub-step one-way hop times (len ep; index 0 = the local chunk,
    cost 0) for a ring transport. Flat comet pays link_bw on every remote
    hop; comet_hier prices each hop by its class and shrinks the payload
    by the wire format (dispatch and combine both ride the wire dtype)."""
    ep = max(1, s.ep)
    rows = s.M * s.topk / ep
    if plan.impl != "comet_hier":
        t = layer_times(hw, s)["t_hop"]
        return [0.0] + [t] * (ep - 1)
    chunk_bytes = rows * s.N * wire_bytes_per_elt(s, plan.wire_dtype)
    out = []
    for cls in hier_step_classes(ep, plan.intra_group):
        if cls == "local":
            out.append(0.0)
        else:
            out.append(hw.hop_latency_s + chunk_bytes / link_class_bw(hw, cls))
    return out


def exposed_comm_from_hops(hop_in: list, hop_out: list, t_comp: float,
                           ring_group: int) -> float:
    """Exposed comm of one decomposed ring: pipeline end time minus pure
    compute, on a three-resource machine (link_in, compute, link_out;
    in-order FIFO per link — the schedule IR's resource model in
    miniature). ``hop_in``/``hop_out`` are per-sub-step one-way hop times
    (index 0 = local, 0.0); ``t_comp`` is one macro-step's GEMM time."""
    ep = len(hop_in)
    g = max(1, ring_group)
    n_steps = max(1, ep // g)
    t_in = 0.0
    core = 0.0
    t_out = 0.0
    for m in range(n_steps):
        for j in range(g):
            t_in += hop_in[m * g + j]
        core = max(core, t_in) + t_comp
        for j in range(g):
            t_out = max(t_out, core) + hop_out[m * g + j]
    return max(0.0, max(core, t_out) - n_steps * t_comp)


def fwd_exposed_comm_time(hw: Hardware, s: MoEShape, plan: "Plan") -> float:
    """Forward communication NOT hidden behind compute for the ring
    transports, priced per link class (the hier figure's modeled side)."""
    hops = hop_time_profile(hw, s, plan)
    g = max(1, plan.ring_group)
    t_comp = g * layer_times(hw, s)["t_chunk_compute"]
    return exposed_comm_from_hops(hops, hops, t_comp, g)


def _weight_read_time(hw: Hardware, s: MoEShape, reads: float) -> float:
    """HBM time to stream the local expert weights ``reads`` times — the
    ring_group trade-off (transport_comet docstring): g source chunks fused
    per GroupGEMM macro-step means ep/g weight reads instead of ep."""
    n_mats = (2 if s.glu else 1) + 1
    w_bytes = (s.E / max(1, s.ep)) * n_mats * s.N * s.K * s.bytes_per_elt
    return reads * w_bytes / hw.hbm_bw


def _layer0_weight_bytes(s: MoEShape) -> float:
    """Local layer-0 expert weights (w_gate + w_up), one full read."""
    n_l0 = 2 if s.glu else 1
    return (s.E / max(1, s.ep)) * n_l0 * s.N * s.K * s.bytes_per_elt


def _hidden_traffic_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Time attributable to the inter-GEMM hidden tensor h (rows_total, K).

    Unfused backends (xla / pallas) write h to HBM once and re-read it per
    GEMM2 call — the comet schedule's N-decomposition re-reads ALL of h for
    every column block. The fused backend never gives h an HBM address, but
    each extra column block is a separate col-sliced kernel call that
    recomputes GEMM1: it re-spends the FLOPs AND re-streams the layer-0
    weights (whichever bounds) — this term is what lets the tuner rank the
    backends, and what pushes the fused schedule toward n_col == 1 (where
    the kernel's n_major traversal supplies the early tile completion)."""
    rows = s.M * s.topk                     # expert rows per device (a2a paths)
    if plan.impl == "bcast":
        rows /= max(1, s.ep)                # each rank only its expert slice
    n_col = (max(1, plan.n_col_blocks)
             if plan.impl in ("comet", "comet_hier") else 1)
    if plan.gemm_impl == "pallas_fused":
        n_l0 = 2 if s.glu else 1
        n_steps = max(1, s.ep // max(1, plan.ring_group)) \
            if plan.impl in ("comet", "comet_hier") else 1
        recompute = gemm_time(hw, rows, s.K, s.N, n_l0)
        reread = n_steps * _layer0_weight_bytes(s) / hw.hbm_bw
        return (n_col - 1) * max(recompute, reread)
    h_bytes = rows * s.K * s.bytes_per_elt
    return h_bytes * (1 + n_col) / hw.hbm_bw


def _combine_stage_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Extra HBM staging for the comet combine: without ``fused_combine``
    the n_col column blocks are concatenated into a full-width
    (M·topk, N) buffer (write + read) before one combine; the streaming
    per-block combine consumes each block in place."""
    if plan.impl not in ("comet", "comet_hier") or plan.fused_combine \
            or max(1, plan.n_col_blocks) == 1:
        return 0.0
    return 2.0 * s.M * s.topk * s.N * s.bytes_per_elt / hw.hbm_bw


def hot_path_hbm_bytes(s: MoEShape, plan: Plan) -> int:
    """Modeled HBM bytes moved by one MoE layer's hot path under ``plan`` —
    the figure benchmarks/run.py --json reports so the fused pipeline's
    traffic saving is visible next to the latency model. Terms: dispatch
    buffer (write + read), inter-GEMM hidden (0 when fused), expert output
    (write + combine read), comet combine staging (0 when streaming), and
    expert-weight reads — ×ep/ring_group macro-steps for comet, with the
    layer-0 weights re-streamed (n_col - 1) extra times under the fused
    backend (each col-sliced kernel call recomputes GEMM1). The fused
    schedule therefore minimizes its bytes at n_col == 1, where the
    kernel's n_major traversal supplies the early tile completion."""
    rows = s.M * s.topk
    if plan.impl == "bcast":
        rows /= max(1, s.ep)                # matches _hidden_traffic_time
    bpe = s.bytes_per_elt
    ring = plan.impl in ("comet", "comet_hier")
    n_col = max(1, plan.n_col_blocks) if ring else 1
    dispatch = 2 * rows * s.N * bpe
    hidden = (0 if plan.gemm_impl == "pallas_fused"
              else rows * s.K * bpe * (1 + n_col))
    out = 2 * rows * s.N * bpe
    stage = (0 if not ring or plan.fused_combine or n_col == 1
             else 2 * rows * s.N * bpe)
    n_steps = (max(1, s.ep // max(1, plan.ring_group)) if ring else 1)
    n_mats = (2 if s.glu else 1) + 1
    weights = n_steps * (s.E / max(1, s.ep)) * n_mats * s.N * s.K * bpe
    if plan.gemm_impl == "pallas_fused":
        weights += n_steps * (n_col - 1) * _layer0_weight_bytes(s)
    return int(dispatch + hidden + out + stage + weights)


def modeled_plan_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Analytical latency for one MoE layer under ``plan`` — the fallback
    measure when no device mesh is attached. Built on the discrete-event
    simulator (analysis/simulator.py) plus HBM-traffic terms the simulator
    does not model: expert-weight reads (differentiates ring_group), the
    inter-GEMM hidden round trip (differentiates the fused backend), and
    the comet combine staging (differentiates ``fused_combine``)."""
    from repro.analysis import simulator as SIM  # lazy: simulator imports us
    tpu = hw.name.startswith("tpu")
    extra = _hidden_traffic_time(hw, s, plan) + _combine_stage_time(hw, s, plan)
    if plan.impl == "naive":
        return (SIM.sim_megatron(hw, s)["total"]
                + _weight_read_time(hw, s, 1) + extra)
    if plan.impl == "coarse":
        n = 2
        return (SIM.sim_pipeline(hw, s, n_chunks=n)["total"]
                + _weight_read_time(hw, s, n) + extra)
    if plan.impl == "bcast":
        # tokens replicated over the model axis: no dispatch, every rank runs
        # its expert slice over the full token set, one psum combines.
        rows = s.M * s.topk / max(1, s.ep)
        n_l0 = 2 if s.glu else 1
        t_g = (gemm_time(hw, rows, s.K, s.N, n_l0)
               + gemm_time(hw, rows, s.N, s.K))
        W = s.ep * s.etp
        ar = (2.0 * (W - 1) / W * s.M * s.topk * s.N * s.bytes_per_elt
              / SIM.link_rate(hw)) if W > 1 else 0.0
        return t_g + ar + _weight_read_time(hw, s, 1) + extra
    g = max(1, plan.ring_group)
    n_steps = max(1, s.ep // g)
    if plan.impl == "comet_hier":
        t = SIM.sim_comet_hier(hw, s, plan,
                               n_col=max(1, plan.n_col_blocks),
                               tpu=tpu)["total"]
        # pipeline fill under macro-step fusion: the first macro-step's
        # remote sub-steps, priced at their own link classes
        fill = sum(hop_time_profile(hw, s, plan)[1:g])
    else:
        t = SIM.sim_comet(hw, s, n_col=max(1, plan.n_col_blocks),
                          tpu=tpu)["total"]
        # ring_group g: ep/g weight reads (macro-step fusion) but a g-hop
        # pipeline-fill before the first macro-step can start.
        fill = (g - 1) * layer_times(hw, s)["t_hop"]
    return t + _weight_read_time(hw, s, n_steps) + fill + extra


# ---------------------------------------------------------------------------
# Backward-pass cost model (the custom-VJP comet ring vs the XLA-autodiff
# transposed baseline). Plans are ranked on fwd + bwd: the training step is
# the north-star workload and ~2/3 of it is backward.
# ---------------------------------------------------------------------------


def _dw_accum_time(hw: Hardware, s: MoEShape, n_flushes: int) -> float:
    """HBM time for the fp32 dW accumulators: each flush reads + writes the
    local expert-weight footprint. The comet custom VJP flushes once per
    macro-step (×ep/ring_group); the autodiff baseline flushes per chunk
    (×ep) because every reverse step is a separate transposed GroupGEMM."""
    n_mats = (2 if s.glu else 1) + 1
    dw_bytes = (s.E / max(1, s.ep)) * n_mats * s.N * s.K * 4       # fp32
    return n_flushes * 2.0 * dw_bytes / hw.hbm_bw


def _bwd_hidden_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Hidden-tensor HBM traffic during the custom-VJP backward. The fused
    backend recomputes h inside the dgrad/wgrad kernels (VMEM-resident —
    charged as FLOPs where the backend is known); unfused backends re-read
    the SAVED layer-0 pre-activations and stream the dh accumulator."""
    if plan.gemm_impl == "pallas_fused":
        return 0.0
    rows = s.M * s.topk
    n_l0 = 2 if s.glu else 1
    return (1 + n_l0) * rows * s.K * s.bytes_per_elt / hw.hbm_bw


def modeled_plan_time_bwd(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Analytical backward latency of one MoE layer under ``plan``.

    comet runs the custom-VJP ring: dY chunks travel the reverse permutes
    while the per-chunk dgrad/wgrad GEMMs (with VMEM/HBM hidden remat) and
    the next hop overlap — the forward's pipeline geometry with two comm
    streams (dY in, dX out) — and dW flushes once per macro-step.

    naive/coarse keep XLA autodiff: the transposed all_to_all schedule,
    fully serialized, hidden SAVED by the forward and re-read (plus the dh
    round trip) instead of recomputed — except under the fused backend,
    whose dgrad/wgrad kernels recompute in VMEM everywhere.

    bcast's backward is modeled at TRAINING semantics (backward only exists
    in training): every token must be resident on every model rank, so each
    rank back-propagates its expert slice of ALL ep groups' tokens (×ep the
    a2a paths' per-chunk rows) and the dX psum moves the full replicated
    buffer. This is what keeps the tuner from "winning" a training shape
    with the decode path; at decode-sized M the constant terms dominate and
    bcast stays competitive."""
    lt = layer_times(hw, s)
    # the fused dgrad/wgrad kernels recompute the hidden in VMEM (extra
    # GEMM1 FLOPs); unfused custom-VJP paths re-read saved pre-activations
    recomp = lt["t_gemm1"] if plan.gemm_impl == "pallas_fused" else 0.0
    t_chunk_bwd = lt["t_bwd_gemm"] + recomp
    if plan.impl == "bcast":
        W = s.ep * s.etp
        full_bytes = s.ep * s.M * s.topk * s.N * s.bytes_per_elt
        ar = (2.0 * (W - 1) / W * full_bytes / _a2a_rate(hw)) if W > 1 else 0.0
        return (s.ep * t_chunk_bwd + ar + _dw_accum_time(hw, s, 1)
                + _weight_read_time(hw, s, 1) + _bwd_hidden_time(hw, s, plan))
    if plan.impl in ("naive", "coarse", "dense"):
        rows = s.M * s.topk
        W = s.ep * s.etp
        t_comm = (2.0 * rows * s.N * s.bytes_per_elt / _a2a_rate(hw)
                  if W > 1 else 0.0)
        if plan.gemm_impl == "pallas_fused":
            t_h = 0.0
        else:
            # autodiff: saved h re-read + the dh round trip
            t_h = 2.0 * s.M * s.topk * s.K * s.bytes_per_elt / hw.hbm_bw
        n = 2 if plan.impl == "coarse" else 1
        return (t_comm + s.ep * t_chunk_bwd + t_h + _dw_accum_time(hw, s, n)
                + _weight_read_time(hw, s, n))
    g = max(1, plan.ring_group)
    n_steps = max(1, s.ep // g)
    t_macro_comp = g * t_chunk_bwd
    if plan.impl == "comet_hier":
        # the backward rides the hierarchical permutes at NATIVE width
        # (gradients are never wire-quantized), dY in + dX out
        hops = hop_time_profile(
            hw, s, dataclasses.replace(plan, wire_dtype="fp32"))
        exposed = exposed_comm_from_hops(hops, hops, t_macro_comp, g)
        return (n_steps * t_macro_comp + exposed
                + _dw_accum_time(hw, s, n_steps)
                + _weight_read_time(hw, s, n_steps)
                + _bwd_hidden_time(hw, s, plan))
    t_macro_comm = g * 2.0 * lt["t_hop"]               # dY in + dX out
    steady = n_steps * max(t_macro_comp, t_macro_comm)
    fill = min(t_macro_comp, t_macro_comm) + (g - 1) * lt["t_hop"]
    return (steady + fill + _dw_accum_time(hw, s, n_steps)
            + _weight_read_time(hw, s, n_steps)
            + _bwd_hidden_time(hw, s, plan))


def bwd_exposed_comm_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Backward communication NOT hidden behind compute. comet: the pipeline
    fill plus any steady-state comm residual; naive (and the autodiff
    baseline) expose the full reverse collectives."""
    lt = layer_times(hw, s)
    if plan.impl == "bcast":
        return 0.0
    if plan.impl == "comet_hier":
        g = max(1, plan.ring_group)
        recomp = lt["t_gemm1"] if plan.gemm_impl == "pallas_fused" else 0.0
        hops = hop_time_profile(
            hw, s, dataclasses.replace(plan, wire_dtype="fp32"))
        return exposed_comm_from_hops(hops, hops,
                                      g * (lt["t_bwd_gemm"] + recomp), g)
    if plan.impl != "comet":
        return 2.0 * s.M * s.topk * s.N * s.bytes_per_elt / _a2a_rate(hw)
    g = max(1, plan.ring_group)
    n_steps = max(1, s.ep // g)
    recomp = lt["t_gemm1"] if plan.gemm_impl == "pallas_fused" else 0.0
    t_macro_comp = g * (lt["t_bwd_gemm"] + recomp)
    t_macro_comm = g * 2.0 * lt["t_hop"]
    return (g * lt["t_hop"]
            + n_steps * max(0.0, t_macro_comm - t_macro_comp))


def autodiff_bwd_time(hw: Hardware, s: MoEShape) -> float:
    """The XLA-autodiff baseline the custom VJP replaces: the transposed
    ring serializes ALL reverse ppermutes after the forward completes
    (nothing overlaps them), re-reads the saved hidden from HBM, and
    round-trips the fp32 dW accumulator per chunk."""
    lt = layer_times(hw, s)
    t_comm = 2.0 * s.ep * lt["t_hop"]                  # dY + dX, exposed
    t_comp = s.ep * 2.0 * (lt["t_gemm1"] + lt["t_gemm2"])
    h_read = s.M * s.topk * s.K * s.bytes_per_elt / hw.hbm_bw
    return (t_comm + t_comp + h_read + _dw_accum_time(hw, s, s.ep)
            + _weight_read_time(hw, s, s.ep))


def hot_path_hbm_bytes_bwd(s: MoEShape, plan: Plan) -> int:
    """Modeled HBM bytes of one MoE layer's backward under the custom-VJP
    schedule: dY read + dX write, the saved dispatch rows re-read for the
    recompute/wgrad, hidden remat traffic (0 when fused — dgrad/wgrad
    recompute it in VMEM), per-macro-step weight reads, and the fp32 dW
    accumulator round trips ×(ep/ring_group)."""
    rows = s.M * s.topk
    bpe = s.bytes_per_elt
    n_l0 = 2 if s.glu else 1
    n_mats = n_l0 + 1
    dy_dx = 2 * rows * s.N * bpe
    saved = rows * s.N * bpe
    hidden = (0 if plan.gemm_impl == "pallas_fused"
              else (1 + n_l0) * rows * s.K * bpe)
    if plan.impl in ("comet", "comet_hier"):
        n_steps = max(1, s.ep // max(1, plan.ring_group))
    else:
        n_steps = 2 if plan.impl == "coarse" else 1
    w_bytes = (s.E / max(1, s.ep)) * n_mats * s.N * s.K
    weights = n_steps * w_bytes * bpe
    dw = n_steps * 2 * w_bytes * 4
    return int(dy_dx + saved + hidden + weights + dw)


def autodiff_bwd_hbm_bytes(s: MoEShape) -> int:
    """HBM bytes of the autodiff baseline backward: hidden saved by the
    forward is re-read, every reverse chunk re-reads the weights and
    round-trips the dW accumulator."""
    rows = s.M * s.topk
    bpe = s.bytes_per_elt
    n_l0 = 2 if s.glu else 1
    n_mats = n_l0 + 1
    w_bytes = (s.E / max(1, s.ep)) * n_mats * s.N * s.K
    return int(2 * rows * s.N * bpe + rows * s.N * bpe
               + (1 + n_l0) * rows * s.K * bpe
               + s.ep * w_bytes * bpe + s.ep * 2 * w_bytes * 4)


def _a2a_rate(hw: Hardware) -> float:
    from repro.analysis import simulator as SIM  # lazy: simulator imports us
    return SIM.link_rate(hw)


def modeled_step_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """The train-phase ranking metric: one MoE layer's forward + backward."""
    return modeled_plan_time(hw, s, plan) + modeled_plan_time_bwd(hw, s, plan)


# ---------------------------------------------------------------------------
# Cross-layer (whole-graph) cost terms — the block-schedule IR's view.
# core/schedule.py lowers blocks to segments; these wrappers expose its
# bubble/fill accounting to the tuner so whole-graph schedules rank in the
# same candidate stream as per-layer plans (plan cache v5).
# ---------------------------------------------------------------------------


def modeled_graph_step_time(hw: Hardware, s: MoEShape, plan: Plan,
                            d_model: int = 0, n_blocks: int = 2,
                            training: bool = True,
                            scheduled: Optional[bool] = None) -> float:
    """PER-BLOCK modeled time of an ``n_blocks`` whole-graph window under
    ``plan`` (attention + ring segments + lump HBM terms; fwd+bwd when
    ``training``). ``scheduled=None`` follows ``plan.schedule``; False
    forces the layer-at-a-time barrier baseline — the difference of the
    two isolates the cross-layer fill. ``d_model`` defaults to s.N (equal
    except under BigMac wire-width shapes, where callers that know the
    real width should pass it)."""
    from repro.core import schedule as SCH   # lazy: schedule imports us
    if scheduled is None:
        scheduled = plan.schedule == "overlap"
    t = SCH.graph_step_time(hw, s, plan, d_model=d_model or s.N,
                            n_blocks=n_blocks, n_slices=plan.n_slices,
                            training=training, scheduled=scheduled)
    return t["total"] / max(1, n_blocks)


def ring_bubble_time(hw: Hardware, s: MoEShape, plan: Plan,
                     training: bool = False) -> float:
    """Compute-idle time of ONE block's comet ring under per-layer
    execution — the bubble budget cross-layer scheduling can feed with
    neighboring-layer compute (next block's attn/norm forward; previous
    layer's wgrad flush backward)."""
    from repro.core import schedule as SCH
    g = SCH.lower_model_graph(hw, s, plan, d_model=s.N, n_blocks=1,
                              n_slices=1, training=training)
    t = SCH.schedule_time(g, SCH.sequential_order(g), layer_barriers=True)
    return t.get("idle_compute", 0.0)


def cross_layer_fill_time(hw: Hardware, s: MoEShape, plan: Plan,
                          n_blocks: int = 2, n_slices: int = 2,
                          training: bool = False) -> float:
    """What whole-graph scheduling reclaims per block: barrier-baseline
    minus scheduled time for the same window (≥ 0 by construction — the
    scheduler never legalizes a slower order than the baseline)."""
    p = dataclasses.replace(plan, schedule="overlap",
                            n_slices=max(1, n_slices))
    base = modeled_graph_step_time(hw, s, p, n_blocks=n_blocks,
                                   training=training, scheduled=False)
    sched = modeled_graph_step_time(hw, s, p, n_blocks=n_blocks,
                                    training=training, scheduled=True)
    return max(0.0, base - sched)


def phase_measure(hw: Hardware, s: MoEShape,
                  phase: str) -> Callable[[Plan], float]:
    """The analytical ranking objective for a latency phase: training ranks
    fwd+bwd (~2/3 of a step is backward); serving phases rank FORWARD ONLY —
    decode on per-step latency (no backward exists at inference; at tiny M
    the constant terms push toward bcast / small ring groups), prefill on
    chunk walltime (throughput = chunk tokens / this). Whole-graph
    candidates (``plan.schedule``) score as their per-layer base time minus
    the graph model's cross-layer fill — the graph total also carries
    attention + lump terms the per-layer objective never sees, so ranking
    raw graph time against per-layer time would bury every scheduled
    candidate under a constant it cannot influence; differencing the two
    graph runs (barrier vs scheduled, identical lumps) cancels it."""
    def measure(p: Plan) -> float:
        training = phase == "train"
        if p.schedule:
            base_p = dataclasses.replace(p, schedule="", n_slices=1)
            base = (modeled_step_time(hw, s, base_p) if training
                    else modeled_plan_time(hw, s, base_p))
            fill = cross_layer_fill_time(hw, s, p, n_slices=p.n_slices,
                                         training=training)
            return base - fill
        if phase == "train":
            return modeled_step_time(hw, s, p)
        return modeled_plan_time(hw, s, p)
    return measure


def tune_plan(s: MoEShape, hw: Hardware, cache: Optional[PlanCache] = None,
              measure: Optional[Callable[[Plan], float]] = None,
              candidates: Optional[Iterable[Plan]] = None,
              force: bool = False, objective: Optional[str] = None,
              phase: str = "train") -> Plan:
    """Pick the fastest plan for ``s`` on ``hw`` for a latency ``phase``.

    ``measure`` is a callable Plan -> seconds timing a REAL execution (see
    ``make_timing_measure``, which can time a full fwd+bwd); when None the
    analytical model ranks the candidates on the phase objective
    (``phase_measure``: train = fwd+bwd, prefill/decode = fwd-only).
    ``objective`` records what the supplied measure covered — pass "fwd"
    with a forward-only measure so the persisted provenance is truthful;
    None defaults to the phase's objective name. Candidates are legalized
    (``legalize_plan``) before ranking and the winner is stored LEGALIZED
    in ``cache`` (if given) under the phase-qualified
    (M, d, f, E, topk, ep, etp, hw[, phase]) key and returned."""
    assert phase in PLAN_PHASES, phase
    if objective is None:
        objective = PHASE_OBJECTIVES[phase]
    if cache is not None and not force:
        hit = cache.get(s, hw, phase)
        if hit is not None:
            return hit
    cands = list(candidates) if candidates is not None \
        else list(candidate_plans(s, hw=hw))
    # legalize BEFORE ranking so the knobs measured are the knobs that run,
    # then dedupe (legalization can collapse distinct candidates)
    seen = set()
    uniq = []
    for p in cands:
        p = legalize_plan(p, s.N, s.ep)
        k = (p.impl, p.ring_group, p.n_col_blocks, p.gemm_impl,
             p.fused_combine, p.schedule, p.n_slices, p.intra_group,
             p.wire_dtype)
        if k not in seen:
            seen.add(k)
            uniq.append(p)
    cands = uniq
    source = "measured" if measure is not None else "model"
    meas = measure if measure is not None else phase_measure(hw, s, phase)
    best: Optional[Plan] = None
    best_t = math.inf
    failed = []
    for p in cands:
        try:
            t = float(meas(p))
        except Exception as e:            # illegal candidate for this shape
            failed.append((p, e))
            continue
        if t < best_t:
            best, best_t = p, t
    if failed:
        import warnings
        p0, e0 = failed[0]
        warnings.warn(
            f"tune_plan: {len(failed)}/{len(cands)} candidates failed for "
            f"{PlanCache.key(s, hw, phase)} (first: {p0.impl} "
            f"rg{p0.ring_group} nc{p0.n_col_blocks} {p0.gemm_impl}: {e0!r}); "
            "the tuned result only ranks the surviving candidates",
            stacklevel=2)
    if best is None:
        raise RuntimeError(f"no candidate plan measurable for {s}")
    t_bwd = (modeled_plan_time_bwd(hw, s, best)
             if measure is None and phase == "train" else 0.0)
    best = dataclasses.replace(best, measured_s=best_t, source=source,
                               t_bwd_s=t_bwd, objective=objective,
                               phase=phase)
    if cache is not None:
        cache.put(s, hw, best, phase=phase)
    return best


def analytic_plan(s: MoEShape, hw: Hardware, phase: str = "train") -> Plan:
    """Model-ranked plan — what moe_layer falls back to when the configured
    cache file is missing or has no entry for this shape."""
    return tune_plan(s, hw, cache=None, measure=None, phase=phase)


def make_timing_measure(cfg, mcfg, params, x, ctx, iters: int = 3,
                        warmup: int = 1,
                        grad: bool = False) -> Callable[[Plan], float]:
    """Timing callback over real ``shard_map`` executions of the MoE layer.

    Returns measure(plan) -> mean seconds per step, compiling the layer with
    the plan's schedule (impl/ring_group/n_col/gemm backend — carried
    entirely by ``plan.apply``; no module-global backend switching) under
    the caller's mesh context. ``grad=True`` times a full forward+backward
    (``jax.value_and_grad`` through the layer w.r.t. the expert weights) —
    the v3 ranking objective. Used by tools/tune.py on attached hardware
    (or a forced-host-device mesh for functional runs)."""
    import contextlib
    import time as _time

    import jax
    import jax.numpy as jnp

    from repro.parallel.compat import use_mesh

    def measure(plan: Plan) -> float:
        from repro.core.moe_layer import moe_ffn  # lazy: moe_layer imports us
        m2 = plan.apply(mcfg)
        if grad:
            def loss(pp, xx):
                y, aux = moe_ffn(cfg, m2, pp, xx, ctx)
                return jnp.sum(y.astype(jnp.float32) ** 2) + aux

            g_fn = jax.jit(jax.value_and_grad(loss))
            fn = lambda xx: g_fn(params, xx)[0]
        else:
            fn = jax.jit(lambda xx: moe_ffn(cfg, m2, params, xx, ctx)[0])
        cm = use_mesh(ctx.mesh) if ctx.active else contextlib.nullcontext()
        with cm:
            for _ in range(max(1, warmup)):
                fn(x).block_until_ready()
            t0 = _time.perf_counter()
            y = None
            for _ in range(max(1, iters)):
                y = fn(x)
            y.block_until_ready()
            return (_time.perf_counter() - t0) / max(1, iters)

    return measure


# ---------------------------------------------------------------------------
# Plan resolution (moe_layer entry)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _plan_cache_at(path: str, mtime: float) -> PlanCache:
    pc = PlanCache(path if mtime >= 0 else None)
    pc.path = path
    return pc


def load_plan_cache(path: str) -> PlanCache:
    """mtime-memoized cache load; a missing file yields an empty cache (the
    analytical model then supplies plans), and an external rewrite of the
    file is picked up on the next lookup (the mtime is part of the memo
    key, so a stale entry is simply never hit again)."""
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        mtime = -1.0
    return _plan_cache_at(path, mtime)


def plan_lookup_enabled(mcfg) -> bool:
    if getattr(mcfg, "plan_override", False):
        return False
    return bool(getattr(mcfg, "plan_cache", "")
                or os.environ.get("REPRO_PLAN_CACHE", ""))


def resolve_plan(mcfg, d_model: int, tokens_local: int, ep: int, etp: int,
                 hw: Optional[Hardware] = None,
                 phase: Optional[str] = None) -> Optional[Plan]:
    """Schedule lookup for moe_layer. Returns None when plan resolution is
    disabled (no cache configured, or the explicit-override escape hatch is
    set); otherwise the cached plan for this shape and latency phase,
    falling back to the analytical model (phase objective) when the cache
    file or entry is absent. The phase comes from ``phase`` >
    ``mcfg.plan_phase`` > "train" (pre-v4 caches hold only unqualified
    train keys, which keep resolving); the hardware key from ``hw`` >
    ``mcfg.plan_hw`` > $REPRO_PLAN_HW > tpu_v5e."""
    if not plan_lookup_enabled(mcfg):
        return None
    if phase is None:
        phase = getattr(mcfg, "plan_phase", "") or "train"
    if hw is None:
        name = getattr(mcfg, "plan_hw", "") \
            or os.environ.get("REPRO_PLAN_HW", "")
        if name and name not in HW:
            import warnings
            warnings.warn(f"unknown plan hardware {name!r} (have "
                          f"{sorted(HW)}); using tpu_v5e — tuned plans for "
                          f"{name!r} will never match", stacklevel=2)
        hw = HW.get(name, TPU_V5E)
    path = getattr(mcfg, "plan_cache", "") \
        or os.environ.get("REPRO_PLAN_CACHE", "")
    s = plan_shape(mcfg, d_model, tokens_local, ep, etp)
    cache = load_plan_cache(path)
    plan = cache.get(s, hw, phase)
    if plan is None:
        plan = analytic_plan(s, hw, phase)
        # memoize in the loaded (in-memory) cache only — repeated traces of
        # the same shape must not repeat the candidate search, and a later
        # rewrite of the file invalidates this via the mtime check
        cache.plans[cache.key(s, hw, phase)] = plan
    # pre-v3 (or hand-written) cache entries may carry knobs the transport
    # would silently re-legalize; resolve to the executable schedule HERE so
    # the applied plan and the cost model agree with what runs. Legalized
    # against s.N — the COMBINE width n_col must divide, which is the wire
    # width under BigMac descend-ascend experts, not d_model.
    return legalize_plan(plan, s.N, max(1, ep))
