"""Adaptive workload assignment (paper §3.2.2, TPU-native).

The paper balances communication vs computation by moving SMs between
thread-block roles (n_c comm blocks out of 132). On TPU the ICI DMA engines
are disjoint from the MXU, so there is no SM budget to split — the balancing
knob that remains is the PIPELINE GEOMETRY:

* ``n_col_blocks`` — layer-1 N-decomposition granularity (paper Fig. 6 T_N):
  more blocks → earlier first-combine and finer return-traffic interleave,
  but smaller GEMM tiles (alignment floor: blocks of ≥128 columns keep the
  MXU full, the exact analogue of the paper's tile-efficiency constraint).
* ring chunking is fixed by EP (ep-1 hops), and the per-chunk compute is
  M/ep rows — the dispatch-side balance is achieved when per-chunk GEMM time
  ≈ per-hop ICI time, which the cost model reports as ``dispatch_balance``.

Two layers, same as the paper:
1. an ANALYTICAL model (roofline arithmetic from hardware constants) picks a
   starting config — this replaces profiling where no hardware is attached;
2. a PROFILE CACHE stores measured-best configs keyed by
   (M, N, K, E, topk, ep, etp, hw) — the direct analogue of Comet's
   pre-compiled kernel metadata, filled by ``tune()`` when a timing callback
   is available (real TPU runs; benchmarks/ wires the simulator in).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Callable, Dict, Iterable, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float                 # peak dense bf16 FLOP/s per chip
    hbm_bw: float                # bytes/s
    link_bw: float               # bytes/s per interconnect link/direction
    links: int = 1               # usable links per chip for the ring
    gemm_eff: float = 0.7        # sustained fraction of peak for big GEMMs
    small_tile_penalty: float = 0.55   # efficiency when M-tile < 128 rows


TPU_V5E = Hardware("tpu_v5e", flops=197e12, hbm_bw=819e9, link_bw=50e9,
                   links=2)
H100_NVL = Hardware("h100_nvlink", flops=990e12, hbm_bw=3.35e12,
                    link_bw=377e9, links=1, gemm_eff=0.65)
L20_PCIE = Hardware("l20_pcie", flops=119e12, hbm_bw=864e9, link_bw=25e9,
                    links=1, gemm_eff=0.6)

HW = {h.name: h for h in (TPU_V5E, H100_NVL, L20_PCIE)}


# ---------------------------------------------------------------------------
# Analytical cost terms for one MoE layer (per device)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEShape:
    M: int          # tokens on this device's group before dispatch
    N: int          # d_model
    K: int          # d_expert (per-device after ETP split)
    E: int          # global experts
    topk: int
    ep: int
    etp: int
    glu: bool = True
    bytes_per_elt: int = 2


# fixed software/DMA-setup latency per fine-grained transfer: this is what
# makes the optimal decomposition COARSER at small M and FINER at large M
# (the paper's Fig. 8 shift of the optimal division point with M)
HOP_LATENCY_S = 5e-6


def gemm_time(hw: Hardware, rows: int, n: int, k: int, n_mats: int = 1) -> float:
    """Time for rows×k @ k×n (n_mats of them), with small-tile derating."""
    eff = hw.gemm_eff if rows >= 128 else hw.gemm_eff * hw.small_tile_penalty
    return n_mats * 2.0 * rows * n * k / (hw.flops * eff)


def layer_times(hw: Hardware, s: MoEShape) -> Dict[str, float]:
    """Per-chunk / per-hop costs for the comet schedule."""
    rows_per_chunk = s.M * s.topk / s.ep          # expert rows from one source group
    n_l0 = 2 if s.glu else 1                       # gate+up vs up
    t_gemm1 = gemm_time(hw, rows_per_chunk, s.K, s.N, n_l0)
    t_gemm2 = gemm_time(hw, rows_per_chunk, s.N, s.K)
    chunk_bytes = rows_per_chunk * s.N * s.bytes_per_elt
    t_hop = HOP_LATENCY_S + chunk_bytes / (hw.link_bw * hw.links)
    return {
        "t_gemm1": t_gemm1, "t_gemm2": t_gemm2,
        "t_chunk_compute": t_gemm1 + t_gemm2,
        "t_hop": t_hop,
        "dispatch_balance": t_hop / max(t_gemm1 + t_gemm2, 1e-12),
    }


def choose_n_col(hw: Hardware, s: MoEShape, max_blocks: int = 8,
                 align: int = 128) -> int:
    """Pick the layer-1 N-decomposition: the finest column split whose
    per-block GEMM still runs at full tile efficiency (block ≥ align cols)
    and whose per-block return-hop stays ≤ per-block compute (no comm-bound
    tail). Mirrors the paper's observation that the optimal n_c grows with M
    and with communication burden (lower TP / higher bandwidth need)."""
    best = 1
    for n_col in range(1, max_blocks + 1):
        blk = s.N // n_col
        if blk < align or s.N % n_col:
            continue
        rows = s.M * s.topk / s.ep
        t_blk_gemm = gemm_time(hw, rows, blk, s.K)
        t_blk_hop = (HOP_LATENCY_S
                     + rows * blk * s.bytes_per_elt / (hw.link_bw * hw.links))
        if t_blk_hop <= t_blk_gemm * 1.05:
            best = n_col
    return best


# ---------------------------------------------------------------------------
# Profile cache (the paper's pre-compiled kernel metadata analogue)
# ---------------------------------------------------------------------------


class AdaptiveCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.table: Dict[str, Dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self.table = json.load(f)

    @staticmethod
    def key(s: MoEShape, hw: Hardware) -> str:
        return f"{hw.name}:M{s.M}:N{s.N}:K{s.K}:E{s.E}:k{s.topk}:ep{s.ep}:etp{s.etp}"

    def get(self, s: MoEShape, hw: Hardware) -> Optional[Dict]:
        return self.table.get(self.key(s, hw))

    def put(self, s: MoEShape, hw: Hardware, cfg: Dict):
        self.table[self.key(s, hw)] = cfg
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.table, f, indent=1)

    def tune(self, s: MoEShape, hw: Hardware,
             candidates: Iterable[Dict],
             measure: Callable[[Dict], float]) -> Dict:
        """Profile-guided: measure each candidate once, cache the argmin."""
        hit = self.get(s, hw)
        if hit is not None:
            return hit
        best_cfg, best_t = None, math.inf
        for cfg in candidates:
            t = measure(cfg)
            if t < best_t:
                best_cfg, best_t = dict(cfg), t
        best_cfg["measured_s"] = best_t
        self.put(s, hw, best_cfg)
        return best_cfg


def default_candidates(s: MoEShape, max_blocks: int = 8):
    for n_col in range(1, max_blocks + 1):
        if s.N % n_col == 0 and s.N // n_col >= 128:
            yield {"n_col_blocks": n_col}


def resolve_n_col(mcfg, cfg_d_model: int, tokens_local: int,
                  ep: int, etp: int, hw: Hardware = TPU_V5E) -> int:
    """Entry used by moe_layer when mcfg.n_col_blocks == 0 (adaptive)."""
    if mcfg.n_col_blocks:
        return mcfg.n_col_blocks
    s = plan_shape(mcfg, cfg_d_model, tokens_local, ep, etp)
    return choose_n_col(hw, s)


# ---------------------------------------------------------------------------
# Adaptive transport plans (the tentpole): a full schedule — transport impl ×
# ring_group × n_col_blocks × gemm backend — tuned per shape and persisted.
# ``tune_plan`` measures real shard_map executions when a timing callback is
# supplied and falls back to the discrete-event simulator / roofline model
# otherwise, so the same cache format serves offline (tools/tune.py) and
# attached-hardware tuning.
# ---------------------------------------------------------------------------


# v2 (PR 2): plans gained ``gemm_impl="pallas_fused"`` and the
# ``fused_combine`` flag. v1 caches load unchanged — Plan.from_json defaults
# the missing field to False.
PLAN_CACHE_VERSION = 2

TRANSPORTS = ("naive", "coarse", "comet", "bcast")


@dataclasses.dataclass(frozen=True)
class Plan:
    """One concrete MoE-layer schedule. ``measured_s`` is the winning latency
    under the measure that selected it; ``source`` records whether that was a
    real timed execution ("measured") or the analytical model ("model")."""
    impl: str = "comet"
    ring_group: int = 1
    n_col_blocks: int = 1
    gemm_impl: str = "xla"
    fused_combine: bool = False
    measured_s: float = 0.0
    source: str = "model"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "Plan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def apply(self, mcfg):
        """Return ``mcfg`` running this plan's schedule. Sets
        ``plan_override`` so nested calls do not re-resolve the plan."""
        return dataclasses.replace(
            mcfg, impl=self.impl, ring_group=self.ring_group,
            n_col_blocks=max(1, self.n_col_blocks),
            fused_combine=self.fused_combine, plan_override=True)


def plan_shape(mcfg, d_model: int, tokens_local: int, ep: int,
               etp: int) -> MoEShape:
    """The (M, d, f, E, topk, ep, etp) key shape for plan lookup — must be
    built identically by the tuner and by moe_layer's resolution."""
    return MoEShape(M=tokens_local, N=d_model,
                    K=mcfg.d_expert // max(1, etp), E=mcfg.num_experts,
                    topk=mcfg.top_k, ep=ep, etp=etp)


class PlanCache:
    """JSON-backed map  shape-key -> Plan  (Comet's pre-compiled kernel
    metadata analogue, but holding full transport schedules)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.plans: Dict[str, Plan] = {}
        if path and os.path.exists(path):
            self.load(path)

    @staticmethod
    def key(s: MoEShape, hw: Hardware) -> str:
        return AdaptiveCache.key(s, hw)

    def load(self, path: str):
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError) as e:
            # a corrupt/unreadable cache must not take the run down — behave
            # like a missing file (analytical fallback) and say so
            import warnings
            warnings.warn(f"plan cache {path!r} unreadable ({e}); starting "
                          "empty — plans fall back to the analytical model",
                          stacklevel=2)
            self.plans = {}
            return
        table = raw.get("plans", raw) if isinstance(raw, dict) else {}
        self.plans = {k: Plan.from_json(v) for k, v in table.items()
                      if isinstance(v, dict) and "impl" in v}

    def save(self, path: Optional[str] = None):
        path = path or self.path
        if not path:
            raise ValueError("PlanCache has no path to save to")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # atomic: a concurrent load_plan_cache must never see a torn file
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": PLAN_CACHE_VERSION,
                       "plans": {k: p.to_json()
                                 for k, p in sorted(self.plans.items())}},
                      f, indent=1)
        os.replace(tmp, path)

    def get(self, s: MoEShape, hw: Hardware) -> Optional[Plan]:
        return self.plans.get(self.key(s, hw))

    def put(self, s: MoEShape, hw: Hardware, plan: Plan, save: bool = True):
        self.plans[self.key(s, hw)] = plan
        if save and self.path:
            self.save()


def candidate_plans(s: MoEShape, max_col_blocks: int = 8,
                    max_ring_group: int = 4,
                    gemm_impls: Tuple[str, ...] = ("xla", "pallas_fused"),
                    include_bcast: bool = True) -> Iterable[Plan]:
    """The search space: every transport with its legal knob settings.

    The default backend set omits ``"pallas"`` — the analytical model rates
    it identically to ``"xla"`` (same GEMMs, same HBM traffic), so including
    it only duplicates candidates; measured tuning (tools/tune.py --gemm)
    can add it. ``"pallas_fused"`` IS modeled (the saved hidden HBM round
    trip vs. the per-column-block GEMM1 recompute), as is the comet
    ``fused_combine`` streaming-consumer flag."""
    n_cols = [n for n in range(1, max_col_blocks + 1)
              if s.N % n == 0 and s.N // n >= 128] or [1]
    rings = [g for g in range(1, min(max_ring_group, s.ep) + 1)
             if s.ep % g == 0] or [1]
    for gi in gemm_impls:
        yield Plan("naive", 1, 1, gi)
        yield Plan("coarse", 1, 1, gi)
        for rg in rings:
            for n_col in n_cols:
                for fc in (False, True):
                    yield Plan("comet", rg, n_col, gi, fc)
        if include_bcast:
            yield Plan("bcast", 1, 1, gi)


def _weight_read_time(hw: Hardware, s: MoEShape, reads: float) -> float:
    """HBM time to stream the local expert weights ``reads`` times — the
    ring_group trade-off (transport_comet docstring): g source chunks fused
    per GroupGEMM macro-step means ep/g weight reads instead of ep."""
    n_mats = (2 if s.glu else 1) + 1
    w_bytes = (s.E / max(1, s.ep)) * n_mats * s.N * s.K * s.bytes_per_elt
    return reads * w_bytes / hw.hbm_bw


def _layer0_weight_bytes(s: MoEShape) -> float:
    """Local layer-0 expert weights (w_gate + w_up), one full read."""
    n_l0 = 2 if s.glu else 1
    return (s.E / max(1, s.ep)) * n_l0 * s.N * s.K * s.bytes_per_elt


def _hidden_traffic_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Time attributable to the inter-GEMM hidden tensor h (rows_total, K).

    Unfused backends (xla / pallas) write h to HBM once and re-read it per
    GEMM2 call — the comet schedule's N-decomposition re-reads ALL of h for
    every column block. The fused backend never gives h an HBM address, but
    each extra column block is a separate col-sliced kernel call that
    recomputes GEMM1: it re-spends the FLOPs AND re-streams the layer-0
    weights (whichever bounds) — this term is what lets the tuner rank the
    backends, and what pushes the fused schedule toward n_col == 1 (where
    the kernel's n_major traversal supplies the early tile completion)."""
    rows = s.M * s.topk                     # expert rows per device (a2a paths)
    if plan.impl == "bcast":
        rows /= max(1, s.ep)                # each rank only its expert slice
    n_col = max(1, plan.n_col_blocks) if plan.impl == "comet" else 1
    if plan.gemm_impl == "pallas_fused":
        n_l0 = 2 if s.glu else 1
        n_steps = max(1, s.ep // max(1, plan.ring_group)) \
            if plan.impl == "comet" else 1
        recompute = gemm_time(hw, rows, s.K, s.N, n_l0)
        reread = n_steps * _layer0_weight_bytes(s) / hw.hbm_bw
        return (n_col - 1) * max(recompute, reread)
    h_bytes = rows * s.K * s.bytes_per_elt
    return h_bytes * (1 + n_col) / hw.hbm_bw


def _combine_stage_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Extra HBM staging for the comet combine: without ``fused_combine``
    the n_col column blocks are concatenated into a full-width
    (M·topk, N) buffer (write + read) before one combine; the streaming
    per-block combine consumes each block in place."""
    if plan.impl != "comet" or plan.fused_combine \
            or max(1, plan.n_col_blocks) == 1:
        return 0.0
    return 2.0 * s.M * s.topk * s.N * s.bytes_per_elt / hw.hbm_bw


def hot_path_hbm_bytes(s: MoEShape, plan: Plan) -> int:
    """Modeled HBM bytes moved by one MoE layer's hot path under ``plan`` —
    the figure benchmarks/run.py --json reports so the fused pipeline's
    traffic saving is visible next to the latency model. Terms: dispatch
    buffer (write + read), inter-GEMM hidden (0 when fused), expert output
    (write + combine read), comet combine staging (0 when streaming), and
    expert-weight reads — ×ep/ring_group macro-steps for comet, with the
    layer-0 weights re-streamed (n_col - 1) extra times under the fused
    backend (each col-sliced kernel call recomputes GEMM1). The fused
    schedule therefore minimizes its bytes at n_col == 1, where the
    kernel's n_major traversal supplies the early tile completion."""
    rows = s.M * s.topk
    if plan.impl == "bcast":
        rows /= max(1, s.ep)                # matches _hidden_traffic_time
    bpe = s.bytes_per_elt
    n_col = max(1, plan.n_col_blocks) if plan.impl == "comet" else 1
    dispatch = 2 * rows * s.N * bpe
    hidden = (0 if plan.gemm_impl == "pallas_fused"
              else rows * s.K * bpe * (1 + n_col))
    out = 2 * rows * s.N * bpe
    stage = (0 if plan.impl != "comet" or plan.fused_combine or n_col == 1
             else 2 * rows * s.N * bpe)
    n_steps = (max(1, s.ep // max(1, plan.ring_group))
               if plan.impl == "comet" else 1)
    n_mats = (2 if s.glu else 1) + 1
    weights = n_steps * (s.E / max(1, s.ep)) * n_mats * s.N * s.K * bpe
    if plan.gemm_impl == "pallas_fused":
        weights += n_steps * (n_col - 1) * _layer0_weight_bytes(s)
    return int(dispatch + hidden + out + stage + weights)


def modeled_plan_time(hw: Hardware, s: MoEShape, plan: Plan) -> float:
    """Analytical latency for one MoE layer under ``plan`` — the fallback
    measure when no device mesh is attached. Built on the discrete-event
    simulator (analysis/simulator.py) plus HBM-traffic terms the simulator
    does not model: expert-weight reads (differentiates ring_group), the
    inter-GEMM hidden round trip (differentiates the fused backend), and
    the comet combine staging (differentiates ``fused_combine``)."""
    from repro.analysis import simulator as SIM  # lazy: simulator imports us
    tpu = hw.name.startswith("tpu")
    extra = _hidden_traffic_time(hw, s, plan) + _combine_stage_time(hw, s, plan)
    if plan.impl == "naive":
        return (SIM.sim_megatron(hw, s)["total"]
                + _weight_read_time(hw, s, 1) + extra)
    if plan.impl == "coarse":
        n = 2
        return (SIM.sim_pipeline(hw, s, n_chunks=n)["total"]
                + _weight_read_time(hw, s, n) + extra)
    if plan.impl == "bcast":
        # tokens replicated over the model axis: no dispatch, every rank runs
        # its expert slice over the full token set, one psum combines.
        rows = s.M * s.topk / max(1, s.ep)
        n_l0 = 2 if s.glu else 1
        t_g = (gemm_time(hw, rows, s.K, s.N, n_l0)
               + gemm_time(hw, rows, s.N, s.K))
        W = s.ep * s.etp
        ar = (2.0 * (W - 1) / W * s.M * s.topk * s.N * s.bytes_per_elt
              / SIM.link_rate(hw)) if W > 1 else 0.0
        return t_g + ar + _weight_read_time(hw, s, 1) + extra
    g = max(1, plan.ring_group)
    n_steps = max(1, s.ep // g)
    t = SIM.sim_comet(hw, s, n_col=max(1, plan.n_col_blocks), tpu=tpu)["total"]
    # ring_group g: ep/g weight reads (macro-step fusion) but a g-hop
    # pipeline-fill before the first macro-step can start.
    fill = (g - 1) * layer_times(hw, s)["t_hop"]
    return t + _weight_read_time(hw, s, n_steps) + fill + extra


def tune_plan(s: MoEShape, hw: Hardware, cache: Optional[PlanCache] = None,
              measure: Optional[Callable[[Plan], float]] = None,
              candidates: Optional[Iterable[Plan]] = None,
              force: bool = False) -> Plan:
    """Pick the fastest plan for ``s`` on ``hw``.

    ``measure`` is a callable Plan -> seconds timing a REAL execution (see
    ``make_timing_measure``); when None the analytical model ranks the
    candidates instead. The winner is stored in ``cache`` (if given) under
    the (M, d, f, E, topk, ep, etp, hw) key and returned."""
    if cache is not None and not force:
        hit = cache.get(s, hw)
        if hit is not None:
            return hit
    cands = list(candidates) if candidates is not None \
        else list(candidate_plans(s))
    source = "measured" if measure is not None else "model"
    meas = measure if measure is not None \
        else (lambda p: modeled_plan_time(hw, s, p))
    best: Optional[Plan] = None
    best_t = math.inf
    failed = []
    for p in cands:
        try:
            t = float(meas(p))
        except Exception as e:            # illegal candidate for this shape
            failed.append((p, e))
            continue
        if t < best_t:
            best, best_t = p, t
    if failed:
        import warnings
        p0, e0 = failed[0]
        warnings.warn(
            f"tune_plan: {len(failed)}/{len(cands)} candidates failed for "
            f"{PlanCache.key(s, hw)} (first: {p0.impl} rg{p0.ring_group} "
            f"nc{p0.n_col_blocks} {p0.gemm_impl}: {e0!r}); the tuned result "
            "only ranks the surviving candidates", stacklevel=2)
    if best is None:
        raise RuntimeError(f"no candidate plan measurable for {s}")
    best = dataclasses.replace(best, measured_s=best_t, source=source)
    if cache is not None:
        cache.put(s, hw, best)
    return best


def analytic_plan(s: MoEShape, hw: Hardware) -> Plan:
    """Model-ranked plan — what moe_layer falls back to when the configured
    cache file is missing or has no entry for this shape."""
    return tune_plan(s, hw, cache=None, measure=None)


def make_timing_measure(cfg, mcfg, params, x, ctx, iters: int = 3,
                        warmup: int = 1) -> Callable[[Plan], float]:
    """Timing callback over real ``shard_map`` executions of the MoE layer.

    Returns measure(plan) -> mean seconds per forward, compiling the layer
    with the plan's schedule (impl/ring_group/n_col/gemm backend) under the
    caller's mesh context. Used by tools/tune.py on attached hardware (or a
    forced-host-device mesh for functional runs)."""
    import contextlib
    import time as _time

    import jax

    from repro.core import transport as T
    from repro.parallel.compat import use_mesh

    def measure(plan: Plan) -> float:
        from repro.core.moe_layer import moe_ffn  # lazy: moe_layer imports us
        m2 = plan.apply(mcfg)
        old_gemm = T.GEMM_IMPL
        T.set_gemm_impl(plan.gemm_impl)
        try:
            fn = jax.jit(lambda xx: moe_ffn(cfg, m2, params, xx, ctx)[0])
            cm = use_mesh(ctx.mesh) if ctx.active else contextlib.nullcontext()
            with cm:
                for _ in range(max(1, warmup)):
                    fn(x).block_until_ready()
                t0 = _time.perf_counter()
                y = None
                for _ in range(max(1, iters)):
                    y = fn(x)
                y.block_until_ready()
                return (_time.perf_counter() - t0) / max(1, iters)
        finally:
            T.set_gemm_impl(old_gemm)

    return measure


# ---------------------------------------------------------------------------
# Plan resolution (moe_layer entry)
# ---------------------------------------------------------------------------

_LOADED_CACHES: Dict[str, Tuple[float, PlanCache]] = {}


def load_plan_cache(path: str) -> PlanCache:
    """mtime-memoized cache load; a missing file yields an empty cache (the
    analytical model then supplies plans), and an external rewrite of the
    file is picked up on the next lookup."""
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        mtime = -1.0
    ent = _LOADED_CACHES.get(path)
    if ent is not None and ent[0] == mtime:
        return ent[1]
    pc = PlanCache(path if mtime >= 0 else None)
    pc.path = path
    _LOADED_CACHES[path] = (mtime, pc)
    return pc


def plan_lookup_enabled(mcfg) -> bool:
    if getattr(mcfg, "plan_override", False):
        return False
    return bool(getattr(mcfg, "plan_cache", "")
                or os.environ.get("REPRO_PLAN_CACHE", ""))


def resolve_plan(mcfg, d_model: int, tokens_local: int, ep: int, etp: int,
                 hw: Optional[Hardware] = None) -> Optional[Plan]:
    """Schedule lookup for moe_layer. Returns None when plan resolution is
    disabled (no cache configured, or the explicit-override escape hatch is
    set); otherwise the cached plan for this shape, falling back to the
    analytical model when the cache file or entry is absent. The hardware
    key comes from ``hw`` > ``mcfg.plan_hw`` > $REPRO_PLAN_HW > tpu_v5e."""
    if not plan_lookup_enabled(mcfg):
        return None
    if hw is None:
        name = getattr(mcfg, "plan_hw", "") \
            or os.environ.get("REPRO_PLAN_HW", "")
        if name and name not in HW:
            import warnings
            warnings.warn(f"unknown plan hardware {name!r} (have "
                          f"{sorted(HW)}); using tpu_v5e — tuned plans for "
                          f"{name!r} will never match", stacklevel=2)
        hw = HW.get(name, TPU_V5E)
    path = getattr(mcfg, "plan_cache", "") \
        or os.environ.get("REPRO_PLAN_CACHE", "")
    s = plan_shape(mcfg, d_model, tokens_local, ep, etp)
    cache = load_plan_cache(path)
    plan = cache.get(s, hw)
    if plan is None:
        plan = analytic_plan(s, hw)
        # memoize in the loaded (in-memory) cache only — repeated traces of
        # the same shape must not repeat the candidate search, and a later
        # rewrite of the file invalidates this via the mtime check
        cache.plans[cache.key(s, hw)] = plan
    return plan
