"""Adaptive workload assignment (paper §3.2.2, TPU-native).

The paper balances communication vs computation by moving SMs between
thread-block roles (n_c comm blocks out of 132). On TPU the ICI DMA engines
are disjoint from the MXU, so there is no SM budget to split — the balancing
knob that remains is the PIPELINE GEOMETRY:

* ``n_col_blocks`` — layer-1 N-decomposition granularity (paper Fig. 6 T_N):
  more blocks → earlier first-combine and finer return-traffic interleave,
  but smaller GEMM tiles (alignment floor: blocks of ≥128 columns keep the
  MXU full, the exact analogue of the paper's tile-efficiency constraint).
* ring chunking is fixed by EP (ep-1 hops), and the per-chunk compute is
  M/ep rows — the dispatch-side balance is achieved when per-chunk GEMM time
  ≈ per-hop ICI time, which the cost model reports as ``dispatch_balance``.

Two layers, same as the paper:
1. an ANALYTICAL model (roofline arithmetic from hardware constants) picks a
   starting config — this replaces profiling where no hardware is attached;
2. a PROFILE CACHE stores measured-best configs keyed by
   (M, N, K, E, topk, ep, etp, hw) — the direct analogue of Comet's
   pre-compiled kernel metadata, filled by ``tune()`` when a timing callback
   is available (real TPU runs; benchmarks/ wires the simulator in).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Callable, Dict, Iterable, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float                 # peak dense bf16 FLOP/s per chip
    hbm_bw: float                # bytes/s
    link_bw: float               # bytes/s per interconnect link/direction
    links: int = 1               # usable links per chip for the ring
    gemm_eff: float = 0.7        # sustained fraction of peak for big GEMMs
    small_tile_penalty: float = 0.55   # efficiency when M-tile < 128 rows


TPU_V5E = Hardware("tpu_v5e", flops=197e12, hbm_bw=819e9, link_bw=50e9,
                   links=2)
H100_NVL = Hardware("h100_nvlink", flops=990e12, hbm_bw=3.35e12,
                    link_bw=377e9, links=1, gemm_eff=0.65)
L20_PCIE = Hardware("l20_pcie", flops=119e12, hbm_bw=864e9, link_bw=25e9,
                    links=1, gemm_eff=0.6)

HW = {h.name: h for h in (TPU_V5E, H100_NVL, L20_PCIE)}


# ---------------------------------------------------------------------------
# Analytical cost terms for one MoE layer (per device)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEShape:
    M: int          # tokens on this device's group before dispatch
    N: int          # d_model
    K: int          # d_expert (per-device after ETP split)
    E: int          # global experts
    topk: int
    ep: int
    etp: int
    glu: bool = True
    bytes_per_elt: int = 2


# fixed software/DMA-setup latency per fine-grained transfer: this is what
# makes the optimal decomposition COARSER at small M and FINER at large M
# (the paper's Fig. 8 shift of the optimal division point with M)
HOP_LATENCY_S = 5e-6


def gemm_time(hw: Hardware, rows: int, n: int, k: int, n_mats: int = 1) -> float:
    """Time for rows×k @ k×n (n_mats of them), with small-tile derating."""
    eff = hw.gemm_eff if rows >= 128 else hw.gemm_eff * hw.small_tile_penalty
    return n_mats * 2.0 * rows * n * k / (hw.flops * eff)


def layer_times(hw: Hardware, s: MoEShape) -> Dict[str, float]:
    """Per-chunk / per-hop costs for the comet schedule."""
    rows_per_chunk = s.M * s.topk / s.ep          # expert rows from one source group
    n_l0 = 2 if s.glu else 1                       # gate+up vs up
    t_gemm1 = gemm_time(hw, rows_per_chunk, s.K, s.N, n_l0)
    t_gemm2 = gemm_time(hw, rows_per_chunk, s.N, s.K)
    chunk_bytes = rows_per_chunk * s.N * s.bytes_per_elt
    t_hop = HOP_LATENCY_S + chunk_bytes / (hw.link_bw * hw.links)
    return {
        "t_gemm1": t_gemm1, "t_gemm2": t_gemm2,
        "t_chunk_compute": t_gemm1 + t_gemm2,
        "t_hop": t_hop,
        "dispatch_balance": t_hop / max(t_gemm1 + t_gemm2, 1e-12),
    }


def choose_n_col(hw: Hardware, s: MoEShape, max_blocks: int = 8,
                 align: int = 128) -> int:
    """Pick the layer-1 N-decomposition: the finest column split whose
    per-block GEMM still runs at full tile efficiency (block ≥ align cols)
    and whose per-block return-hop stays ≤ per-block compute (no comm-bound
    tail). Mirrors the paper's observation that the optimal n_c grows with M
    and with communication burden (lower TP / higher bandwidth need)."""
    best = 1
    for n_col in range(1, max_blocks + 1):
        blk = s.N // n_col
        if blk < align or s.N % n_col:
            continue
        rows = s.M * s.topk / s.ep
        t_blk_gemm = gemm_time(hw, rows, blk, s.K)
        t_blk_hop = (HOP_LATENCY_S
                     + rows * blk * s.bytes_per_elt / (hw.link_bw * hw.links))
        if t_blk_hop <= t_blk_gemm * 1.05:
            best = n_col
    return best


# ---------------------------------------------------------------------------
# Profile cache (the paper's pre-compiled kernel metadata analogue)
# ---------------------------------------------------------------------------


class AdaptiveCache:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.table: Dict[str, Dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self.table = json.load(f)

    @staticmethod
    def key(s: MoEShape, hw: Hardware) -> str:
        return f"{hw.name}:M{s.M}:N{s.N}:K{s.K}:E{s.E}:k{s.topk}:ep{s.ep}:etp{s.etp}"

    def get(self, s: MoEShape, hw: Hardware) -> Optional[Dict]:
        return self.table.get(self.key(s, hw))

    def put(self, s: MoEShape, hw: Hardware, cfg: Dict):
        self.table[self.key(s, hw)] = cfg
        if self.path:
            with open(self.path, "w") as f:
                json.dump(self.table, f, indent=1)

    def tune(self, s: MoEShape, hw: Hardware,
             candidates: Iterable[Dict],
             measure: Callable[[Dict], float]) -> Dict:
        """Profile-guided: measure each candidate once, cache the argmin."""
        hit = self.get(s, hw)
        if hit is not None:
            return hit
        best_cfg, best_t = None, math.inf
        for cfg in candidates:
            t = measure(cfg)
            if t < best_t:
                best_cfg, best_t = dict(cfg), t
        best_cfg["measured_s"] = best_t
        self.put(s, hw, best_cfg)
        return best_cfg


def default_candidates(s: MoEShape, max_blocks: int = 8):
    for n_col in range(1, max_blocks + 1):
        if s.N % n_col == 0 and s.N // n_col >= 128:
            yield {"n_col_blocks": n_col}


def resolve_n_col(mcfg, cfg_d_model: int, tokens_local: int,
                  ep: int, etp: int, hw: Hardware = TPU_V5E) -> int:
    """Entry used by moe_layer when mcfg.n_col_blocks == 0 (adaptive)."""
    if mcfg.n_col_blocks:
        return mcfg.n_col_blocks
    s = MoEShape(M=tokens_local, N=cfg_d_model, K=mcfg.d_expert // etp,
                 E=mcfg.num_experts, topk=mcfg.top_k, ep=ep, etp=etp)
    return choose_n_col(hw, s)
