"""Block-schedule IR: whole-graph overlap beyond one MoE layer.

Comet (PAPER.md) overlaps communication with computation INSIDE one MoE
layer; Lancet (PAPERS.md) shows the remaining win is whole-graph — the
dispatch/combine rings still leave link-idle compute bubbles (and
compute-idle link bubbles) that only NON-MoE work from ADJACENT blocks can
fill. This module is the explicit IR that makes those moves legal and
rankable:

* a model forward (and, in training, backward) is lowered to typed
  ``Segment``s — attn / norm / router / dispatch_hop / expert_gemm /
  combine_hop / wgrad_flush / ... — each pinned to a device RESOURCE
  ("compute", or one of the full-duplex link directions "link_in" /
  "link_out") with explicit dependencies;
* ``overlap_order`` is the scheduler: a greedy earliest-start list
  schedule over the dependency DAG that legally hoists the next block's
  attention/norm into the current block's ring bubbles and floats the
  previous layer's wgrad flush (custom-VJP comet ring, PR 3) into the
  backward ring's link windows;
* ``schedule_time`` evaluates any legal order on the three-resource
  machine model. ``layer_barriers=True`` reproduces today's
  layer-at-a-time execution (overlap within a block, a hard barrier at
  every block boundary) — the per-layer-overlap BASELINE the whole-graph
  figures difference against;
* ``exec_order`` applies the same scheduler to the EXECUTED segment list
  (models/blocks.py lowers each layer to ``ExecSeg``-like objects): the
  reordering only permutes segment emission over identical dataflow, so
  scheduled execution is numerically IDENTICAL to the sequential order.

Micro-slicing (Lancet §4): ``attn_{i+1}`` truly depends on ``combine_i``,
so with one slice the forward has no legal cross-layer motion. Slicing the
token dim into ``n_slices`` independent strips creates it: slice 0's
combine frees slice 0's next-block attention while slice 1 still rides the
ring. Slicing is a COST-MODEL degree of freedom here (the ranked schedules
feed the tuner/benchmarks); the executed path keeps full-width segments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Segment taxonomy
# ---------------------------------------------------------------------------

# forward segment kinds
SEGMENT_KINDS = (
    "norm",          # pre-attn / pre-mlp RMSNorm
    "attn",          # attention (qkvo + sdpa), incl. cross-attn
    "ssm",           # mamba mixer (hybrid blocks)
    "ffn",           # dense FFN (non-MoE blocks)
    "shared_ffn",    # MoE shared expert (reads the mid residual only)
    "residual",      # residual add + sharding constraint
    "router",        # top-k gate + dispatch-buffer build
    "dispatch_hop",  # one comet-ring dispatch ppermute      -> link_in
    "expert_gemm",   # one macro-step's fused expert MLP
    "combine_hop",   # one column-block's combine ppermute   -> link_out
    "moe",           # whole MoE layer as ONE segment (executed path)
    # backward-only kinds (training lowering)
    "attn_bwd",      # attention dgrad/wgrad
    "ring_bwd_gemm",  # one backward macro-step's dgrad/wgrad GEMMs
    "ring_bwd_hop",  # dY-in / dX-out reverse ppermute
    "wgrad_flush",   # fp32 dW accumulator flush (floats freely)
)

# which device resource each kind occupies; dispatch and combine ride
# opposite link DIRECTIONS (ICI is full duplex), which is exactly why the
# combine tail of block i can overlap the dispatch head of block i+1
RESOURCE_OF = {
    "norm": "compute", "attn": "compute", "ssm": "compute",
    "ffn": "compute", "shared_ffn": "compute", "residual": "compute",
    "router": "compute", "expert_gemm": "compute",
    "dispatch_hop": "link_in", "combine_hop": "link_out",
    "moe": "link",           # executed path: opaque, serializes on a link
    "attn_bwd": "compute", "ring_bwd_gemm": "compute",
    "ring_bwd_hop": "link_in",   # refined per-direction by the lowering
    "wgrad_flush": "compute",
}

# nominal costs used when ordering EXECUTED segments (no hardware model at
# trace time — only the relative shape matters: rings dominate, norms are
# cheap, so attention hoists into the MoE window)
NOMINAL_COST = {
    "norm": 0.1, "attn": 1.0, "ssm": 1.0, "ffn": 1.0, "shared_ffn": 1.0,
    "residual": 0.05, "router": 0.2, "moe": 4.0,
    "dispatch_hop": 0.5, "expert_gemm": 1.0, "combine_hop": 0.5,
    "attn_bwd": 2.0, "ring_bwd_gemm": 2.0, "ring_bwd_hop": 1.0,
    "wgrad_flush": 0.5,
}


@dataclasses.dataclass(frozen=True)
class Segment:
    """One schedulable unit. ``deps`` are sids of segments that must FINISH
    before this one starts; by construction deps < sid, so every
    ScheduleGraph is a DAG."""
    sid: int
    name: str
    kind: str
    block: int                   # owning block index (layer), -1 = global
    deps: Tuple[int, ...]
    cost_s: float
    resource: str
    slice_id: int = 0


class ScheduleGraph:
    """Append-only segment DAG over the block sequence."""

    def __init__(self):
        self.segments: List[Segment] = []

    def add(self, name: str, kind: str, block: int,
            deps: Iterable[int] = (), cost_s: float = 0.0,
            resource: Optional[str] = None, slice_id: int = 0) -> int:
        if kind not in SEGMENT_KINDS:
            raise ValueError(f"unknown segment kind {kind!r}")
        sid = len(self.segments)
        deps = tuple(sorted(set(int(d) for d in deps)))
        for d in deps:
            if not 0 <= d < sid:
                raise ValueError(
                    f"segment {name!r}: dep {d} must reference an earlier "
                    f"segment (sid {sid})")
        self.segments.append(Segment(
            sid=sid, name=name, kind=kind, block=block, deps=deps,
            cost_s=float(cost_s),
            resource=resource or RESOURCE_OF[kind], slice_id=slice_id))
        return sid

    def __len__(self):
        return len(self.segments)


# ---------------------------------------------------------------------------
# Orders
# ---------------------------------------------------------------------------


def sequential_order(g: ScheduleGraph) -> List[int]:
    """Program order — the layer-at-a-time baseline emission."""
    return list(range(len(g)))


def validate_order(g: ScheduleGraph, order: Sequence[int]) -> List[str]:
    """Legality check: ``order`` must be a permutation of all sids in which
    every segment appears after all of its dependencies. Returns a list of
    violation strings (empty = legal)."""
    errs: List[str] = []
    n = len(g)
    if sorted(order) != list(range(n)):
        errs.append(f"order is not a permutation of 0..{n - 1}")
        return errs
    pos = {sid: i for i, sid in enumerate(order)}
    for seg in g.segments:
        for d in seg.deps:
            if pos[d] >= pos[seg.sid]:
                errs.append(
                    f"{g.segments[d].name} (sid {d}) must precede "
                    f"{seg.name} (sid {seg.sid})")
    return errs


def overlap_order(g: ScheduleGraph) -> List[int]:
    """Greedy earliest-start list schedule.

    Repeatedly picks, among dependency-ready segments, the one that can
    START earliest on its resource given current resource-free times and
    dep finish times (ties broken by (block, sid) so the order is
    deterministic and biased toward program order). This is what hoists
    next-block attention into a ring's compute bubble: while the ring
    occupies link_in/link_out, the compute resource frees early and the
    only ready compute segment is the hoisted one."""
    n = len(g)
    finish: Dict[int, float] = {}
    free: Dict[str, float] = {}
    n_deps = [len(s.deps) for s in g.segments]
    dependents: List[List[int]] = [[] for _ in range(n)]
    for s in g.segments:
        for d in s.deps:
            dependents[d].append(s.sid)
    ready = [s.sid for s in g.segments if not s.deps]
    order: List[int] = []
    while ready:
        best = None
        for sid in ready:
            s = g.segments[sid]
            start = max([free.get(s.resource, 0.0)]
                        + [finish[d] for d in s.deps])
            key = (start, s.block, sid)
            if best is None or key < best[0]:
                best = (key, sid)
        (start, _, _), sid = best
        s = g.segments[sid]
        finish[sid] = start + s.cost_s
        free[s.resource] = finish[sid]
        order.append(sid)
        ready.remove(sid)
        for t in dependents[sid]:
            n_deps[t] -= 1
            if n_deps[t] == 0:
                ready.append(t)
    if len(order) != n:                      # unreachable for a valid DAG
        raise RuntimeError("overlap_order: dependency cycle")
    # greedy list scheduling admits anomalies (an early greedy pick can
    # delay the critical path); program order is always a legal schedule
    # too, so fall back to it when greedy evaluates worse — making
    # "scheduled never slower than sequential emission" an invariant, not
    # a hope
    seq = list(range(n))
    if (schedule_time(g, order)["total"]
            > schedule_time(g, seq)["total"]):
        return seq
    return order


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------


def schedule_time(g: ScheduleGraph, order: Sequence[int],
                  layer_barriers: bool = False) -> Dict[str, float]:
    """Evaluate an emission order on the three-resource machine.

    Segments issue IN ORDER per resource (an in-order queue per engine —
    the XLA/TPU execution model: reordering must happen at emission, the
    hardware won't do it for you); a segment starts at
    max(resource free, deps finish).

    ``layer_barriers=True`` models today's layer-at-a-time execution: when
    the emitted block id changes, all resources sync to the max finish so
    far — overlap lives within one block only. This is the honest
    per-layer-overlap baseline: without it, evaluating the sequential
    order would grant it the same cross-layer overlap the scheduler
    creates, and there would be nothing to difference."""
    errs = validate_order(g, order)
    if errs:
        raise ValueError("illegal order: " + "; ".join(errs[:3]))
    free: Dict[str, float] = {}
    finish: Dict[int, float] = {}
    busy: Dict[str, float] = {}
    cur_block = None
    total = 0.0
    for sid in order:
        s = g.segments[sid]
        if layer_barriers and s.block != cur_block and s.block >= 0:
            if cur_block is not None:
                for r in list(free):
                    free[r] = total
            cur_block = s.block
        start = max([free.get(s.resource, 0.0)]
                    + [finish[d] for d in s.deps])
        finish[sid] = start + s.cost_s
        free[s.resource] = finish[sid]
        busy[s.resource] = busy.get(s.resource, 0.0) + s.cost_s
        total = max(total, finish[sid])
    out = {"total": total}
    for r, b in busy.items():
        out[f"busy_{r}"] = b
        out[f"idle_{r}"] = total - b
    return out


# ---------------------------------------------------------------------------
# Executed path: order ExecSeg-like objects (models/blocks.py)
# ---------------------------------------------------------------------------


def exec_order(segs, mode: str = "overlap"):
    """Order executed segments. ``segs`` are duck-typed objects with
    ``.name`` (unique), ``.kind``, ``.block``, ``.reads`` / ``.writes``
    (value names). Dependencies are derived from dataflow: a segment
    depends on the LAST writer of each value it reads (and on the previous
    writer of any value it overwrites, so no reorder can clobber a live
    value). Returns the segments in the chosen emission order — a pure
    permutation over identical dataflow, hence numerically identical.

    mode: "sequential" keeps program order; "overlap" runs the greedy
    scheduler with nominal costs."""
    if mode not in ("sequential", "overlap"):
        raise ValueError(f"unknown schedule mode {mode!r}")
    if mode == "sequential":
        return list(segs)
    g = ScheduleGraph()
    writer: Dict[str, int] = {}
    readers: Dict[str, List[int]] = {}
    for e in segs:
        deps = set()
        for v in e.reads:
            if v in writer:
                deps.add(writer[v])
        for v in e.writes:
            # WAR + WAW: can't overwrite a value someone still needs
            if v in writer:
                deps.add(writer[v])
            deps.update(readers.get(v, ()))
        sid = g.add(e.name, e.kind, e.block, deps=deps,
                    cost_s=NOMINAL_COST.get(e.kind, 1.0))
        for v in e.reads:
            readers.setdefault(v, []).append(sid)
        for v in e.writes:
            writer[v] = sid
            readers[v] = []
    order = overlap_order(g)
    errs = validate_order(g, order)
    if errs:                                 # defensive: scheduler bug
        raise RuntimeError("exec_order produced an illegal order: "
                           + errs[0])
    segs = list(segs)
    return [segs[i] for i in order]


# ---------------------------------------------------------------------------
# Cost lowering: whole-graph model for the tuner / benchmarks
# ---------------------------------------------------------------------------


def comet_ring_counts(ep: int, ring_group: int, n_col_blocks: int) -> Dict:
    """Segment counts of one comet forward ring (must agree with
    core/transport.py's loop structure): ep//g macro-steps, each consuming
    g source chunks; dispatch moves ep-1 remote chunks; combine returns
    n_col column blocks per source chunk, ep-1 of them remote."""
    g = max(1, ring_group)
    n_steps = max(1, ep // g)
    return {
        "n_steps": n_steps,
        "dispatch_hops": max(0, ep - 1),
        "expert_gemms": n_steps,
        "combine_hops": max(1, n_col_blocks) * max(0, ep - 1),
    }


def lower_model_graph(hw, s, plan, *, d_model: int, n_blocks: int = 2,
                      n_slices: int = 1,
                      training: bool = False) -> ScheduleGraph:
    """Lower ``n_blocks`` identical transformer-MoE blocks under ``plan``
    to a ScheduleGraph with roofline segment costs (core/adaptive.py
    terms). Each block: norm+attn -> router -> comet ring (ring_group-
    aggregated macro-steps on compute, dispatch hops on link_in, combine
    hops on link_out) -> next block. ``n_slices`` micro-slices the token
    dim (Lancet): slices are independent strips, so slice j of block i+1
    can start once slice j of block i combines. ``training=True`` appends
    the reversed-block backward chain with FLOATING wgrad_flush segments
    (no dependents — the scheduler sinks them into link windows).

    Lump terms shared by every order (expert-weight reads, hidden-tensor
    HBM traffic, combine staging) are NOT segments — ``graph_step_time``
    adds them identically to baseline and scheduled totals."""
    from repro.core import adaptive as A       # lazy: avoid import cycles
    from repro.analysis import simulator as SIM

    lt = A.layer_times(hw, s)
    grp = max(1, plan.ring_group)
    n_col = max(1, plan.n_col_blocks)
    cnt = comet_ring_counts(s.ep, grp, n_col)
    n_steps = cnt["n_steps"]
    ns = max(1, n_slices)
    W = s.ep * s.etp
    t_attn = (SIM.attn_time(hw, d_model, max(1, s.M // W), 1) / ns
              + 2e-6)                         # + norm epsilon
    t_router = A.gemm_time(hw, max(1, s.M // ns), s.E, d_model)
    # per-slice ring costs: rows scale 1/ns, hop latency does not
    ss = dataclasses.replace(s, M=max(1, s.M // ns))
    lts = A.layer_times(hw, ss)
    # one macro-step consumes g source chunks; backend differences (fused
    # recompute vs hidden round trip) live in the lump terms, not here
    t_gemm = grp * lts["t_chunk_compute"]
    if plan.impl == "comet_hier":
        # topology-aware ring: macro-step m's dispatch wave sums its g
        # sub-step hops from the per-class profile (inter-node sub-steps
        # first, intra-node tail — core/adaptive.hier_step_order), so the
        # race detector and whole-graph scheduler see the SAME per-step
        # asymmetry the transport executes. The backward ring moves
        # native-width gradients: price its hops with the wire format off.
        hops = A.hop_time_profile(hw, ss, plan)
        hops_n = A.hop_time_profile(
            hw, ss, dataclasses.replace(plan, wire_dtype="fp32"))
        dhop = [sum(hops[m * grp + j] for j in range(grp))
                for m in range(n_steps)]
        bhop = [sum(hops_n[m * grp + j] for j in range(grp))
                for m in range(n_steps)]
    else:
        dhop = [grp * lts["t_hop"]] * n_steps  # g chunks per dispatch wave
        bhop = dhop

    g = ScheduleGraph()
    last_combine: Dict[int, int] = {}         # slice -> sid of final combine
    for i in range(n_blocks):
        for j in range(ns):
            dep = [last_combine[j]] if j in last_combine else []
            a = g.add(f"L{i}.s{j}.attn", "attn", i, deps=dep,
                      cost_s=t_attn, slice_id=j)
            r = g.add(f"L{i}.s{j}.router", "router", i, deps=[a],
                      cost_s=t_router, slice_id=j)
            prev_recv = r
            combine_done = r
            for m in range(n_steps):
                deps = [prev_recv]
                if m > 0:
                    d = g.add(f"L{i}.s{j}.disp{m}", "dispatch_hop", i,
                              deps=[r], cost_s=dhop[m], slice_id=j)
                    deps.append(d)
                e = g.add(f"L{i}.s{j}.gemm{m}", "expert_gemm", i,
                          deps=deps, cost_s=t_gemm, slice_id=j)
                prev_recv = e
                for b in range(n_col):
                    combine_done = g.add(
                        f"L{i}.s{j}.comb{m}.{b}", "combine_hop", i,
                        deps=[e], cost_s=dhop[m] / n_col, slice_id=j)
            last_combine[j] = combine_done
    if training:
        # backward of block i runs MoE-ring-bwd THEN attn_bwd (reverse of
        # the forward's attn -> moe); dY macro-chunks stream on link_in
        # while the dgrad/wgrad GEMMs run, dX returns on link_out — the
        # custom-VJP comet ring's two comm streams (PR 3)
        t_abwd = 2.0 * t_attn
        t_bgemm = grp * (lts["t_bwd_gemm"]
                         + (lts["t_gemm1"]     # in-VMEM hidden recompute
                            if plan.gemm_impl == "pallas_fused" else 0.0))
        # (the bwd recompute is NOT in the lump terms — modeled_plan_time_bwd
        # charges it per chunk the same way, so keep it as segment cost)
        t_flush = A._dw_accum_time(hw, s, n_steps) / (n_steps * ns)
        prev_dx: Dict[int, int] = {}          # slice -> upstream grad sid
        for i in reversed(range(n_blocks)):
            for j in range(ns):
                up = [prev_dx[j]] if j in prev_dx else [last_combine[j]]
                prev_g = None
                dx = up[0]
                for m in range(n_steps):
                    h = g.add(f"L{i}.s{j}.dyhop{m}", "ring_bwd_hop", i,
                              deps=up, cost_s=bhop[m], resource="link_in",
                              slice_id=j)
                    deps = [h] if prev_g is None else [h, prev_g]
                    prev_g = g.add(f"L{i}.s{j}.bgemm{m}", "ring_bwd_gemm",
                                   i, deps=deps, cost_s=t_bgemm, slice_id=j)
                    dx = g.add(f"L{i}.s{j}.dxhop{m}", "ring_bwd_hop", i,
                               deps=[prev_g], cost_s=bhop[m],
                               resource="link_out", slice_id=j)
                    # the flush has NO dependents: it floats into whatever
                    # bubble the scheduler finds (PR 3's deferred dW)
                    g.add(f"L{i}.s{j}.flush{m}", "wgrad_flush", i,
                          deps=[prev_g], cost_s=t_flush, slice_id=j)
                prev_dx[j] = g.add(f"L{i}.s{j}.attn_bwd", "attn_bwd", i,
                                   deps=[dx, prev_g], cost_s=t_abwd,
                                   slice_id=j)
    return g


def graph_step_time(hw, s, plan, *, d_model: int, n_blocks: int = 2,
                    n_slices: int = 1, training: bool = False,
                    scheduled: bool = True) -> Dict[str, float]:
    """Whole-graph modeled time for ``n_blocks`` blocks under ``plan``.

    scheduled=False: sequential emission + per-block barriers (today's
    layer-at-a-time execution; overlap only within one block) and no
    micro-slicing. scheduled=True: the greedy whole-graph order with
    ``n_slices``. Lump HBM terms (expert-weight reads per macro-step,
    hidden-tensor traffic, combine staging; + bwd hidden and nothing else
    — dW flushes are already graph segments) are added identically to
    both, so the difference isolates the scheduling win. Slice
    co-scheduling keeps a macro-step's expert weights resident across
    slices, so weight reads are charged once per macro-step, not per
    slice."""
    from repro.core import adaptive as A

    ns = max(1, n_slices) if scheduled else 1
    g = lower_model_graph(hw, s, plan, d_model=d_model, n_blocks=n_blocks,
                          n_slices=ns, training=training)
    if scheduled:
        order = overlap_order(g)
        t = schedule_time(g, order)
    else:
        t = schedule_time(g, sequential_order(g), layer_barriers=True)
    n_steps = max(1, s.ep // max(1, plan.ring_group))
    lump = n_blocks * (A._weight_read_time(hw, s, n_steps)
                       + A._hidden_traffic_time(hw, s, plan)
                       + A._combine_stage_time(hw, s, plan))
    if training:
        lump += n_blocks * (A._weight_read_time(hw, s, n_steps)
                            + A._bwd_hidden_time(hw, s, plan))
    out = dict(t)
    out["total"] = t["total"] + lump
    out["lump_s"] = lump
    out["n_slices"] = ns
    return out
