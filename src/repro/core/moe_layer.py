"""The MoE block: router → shared-tensor dispatch → transport → combine.

Runs under ``jax.shard_map`` (manual SPMD) when a mesh is active so the
collective schedule is explicit and deterministic — the paper's argument
against stream-level scheduling, and what the roofline parser inspects.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import routing as R
from repro.core import transport as T
from repro.models.common import ParamDecl, is_glu
from repro.parallel.compat import shard_map
from repro.parallel.mesh import AxisCtx


# ---------------------------------------------------------------------------
# Schema: expert weights are stored PRE-SHARDED with leading dim = model-axis
# size W; entry r is exactly what model-rank r owns (experts sliced over ep
# groups, d_expert sliced over etp). This supports any (ep, etp) factorization
# without divisibility constraints between E and the mesh axis.
# ---------------------------------------------------------------------------


def moe_schema(cfg, mcfg, W: int, etp: int) -> Dict:
    d = cfg.d_model
    E_loc = mcfg.num_experts // max(1, W // etp)
    f_loc = mcfg.d_expert // etp
    s: Dict = {
        "router": ParamDecl((d, mcfg.num_experts), ("embed_v", "experts_v")),
    }
    # BigMac descend-ascend (PAPERS.md): shared replicated projections
    # d -> wire before dispatch and wire -> d after combine; the experts
    # then live entirely at wire width, so BOTH rings move wire/d of the
    # bytes. The router keeps the full-width tokens (routing quality).
    wire = getattr(mcfg, "wire_dim", 0)
    d_in = wire or d
    if wire:
        s["w_desc"] = ParamDecl((d, wire), ("embed_v", None))
        s["w_asc"] = ParamDecl((wire, d), (None, "embed_v"))
    ew: Dict[str, ParamDecl] = {}
    if is_glu(cfg.activation):
        ew["w_gate"] = ParamDecl((W, E_loc, d_in, f_loc),
                                 ("expert_shard", None, "embed", None))
    ew["w_up"] = ParamDecl((W, E_loc, d_in, f_loc),
                           ("expert_shard", None, "embed", None))
    ew["w_down"] = ParamDecl((W, E_loc, f_loc, d_in),
                             ("expert_shard", None, None, "embed"))
    s["experts"] = ew
    if mcfg.num_shared_experts:
        from repro.models.common import ffn_schema
        s["shared"] = ffn_schema(cfg, d, mcfg.d_expert * mcfg.num_shared_experts)
    return s


def pack_expert_weights(full: Dict[str, jnp.ndarray], ep: int, etp: int) -> Dict:
    """Convert logical (E, d, f)/(E, f, d) weights into the pre-sharded
    (W, E_loc, ...) storage layout. Used by tests/examples."""
    out = {}
    for name, w in full.items():
        E = w.shape[0]
        E_loc = E // ep
        packed = []
        for g in range(ep):
            for t in range(etp):
                sl = w[g * E_loc:(g + 1) * E_loc]
                if name == "w_down":
                    f_loc = w.shape[1] // etp
                    packed.append(sl[:, t * f_loc:(t + 1) * f_loc, :])
                else:
                    f_loc = w.shape[2] // etp
                    packed.append(sl[:, :, t * f_loc:(t + 1) * f_loc])
        out[name] = jnp.stack(packed)
    return out


# ---------------------------------------------------------------------------
# Local (per-shard) body
# ---------------------------------------------------------------------------


def _moe_body(cfg, mcfg, ctx: AxisCtx, n_col: int, gemm_impl, x, router_w,
              experts, w_desc=None, w_asc=None):
    """x: (B_loc, S_loc, d) local tokens. Returns (y, aux). ``gemm_impl``
    is the resolved GroupGEMM backend, threaded explicitly to every
    transport (no module-global switching). ``w_desc``/``w_asc`` are the
    BigMac descend/ascend projections (replicated): the router sees the
    full-width tokens, everything from dispatch to combine runs at wire
    width, and the ascend restores d_model after the combine."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    Tn = B * S
    E = mcfg.num_experts
    token_axes = ()
    if ctx.active:
        token_axes = tuple(ctx.dp_axes)
        if ctx.seq_shard and S > 1:
            token_axes = token_axes + (ctx.model_axis,)
    idx, wts, aux = R.router(xt, router_w, mcfg, token_axes)
    C = R.capacity(Tn, mcfg.top_k, E, mcfg.capacity_factor)
    ep = ctx.ep if ctx.active else 1
    E_loc = E // ep
    w_local = {k: v[0] for k, v in experts.items()}                 # strip shard dim

    xe = xt if w_desc is None else (xt @ w_desc).astype(xt.dtype)
    dw = xe.shape[-1]                                   # wire (or full) width

    def ascend(y):
        return y if w_asc is None else (y @ w_asc).astype(y.dtype)

    impl = mcfg.impl
    if impl == "coarse" and ctx.active and ctx.world > 1:
        # the coarse schedule re-dispatches per token slice — building the
        # full-batch dispatch here would be pure waste, so it is skipped
        y = _coarse(cfg, mcfg, ctx, xe, idx, wts, E, C, w_local, gemm_impl)
        return ascend(y).reshape(B, S, d), aux

    buf, info = R.build_dispatch(xe, idx, E, C)                     # (E, C, dw)
    if impl == "bcast" or (impl != "dense" and S == 1 and not ctx.seq_shard):
        out = T.transport_bcast(ctx, buf, w_local, cfg.activation, gemm_impl)
        y = R.combine(out.reshape(E * C, dw), info, wts, E_loc=E, C=C,
                      rot=None, ep=1)
    else:
        send = buf.reshape(ep, E_loc, C, dw)
        if impl in ("comet", "comet_hier") and mcfg.fused_combine:
            # streaming layer-1 consumer: combine each column block as it
            # arrives so the weighted reduction overlaps remaining blocks'
            # compute + return traffic (plan knob ``fused_combine``)
            if impl == "comet_hier":
                # hier returns blocks already in destination order (rot=None)
                blocks, rot = T.transport_comet_hier(
                    ctx, send, w_local, cfg.activation, n_col_blocks=n_col,
                    ring_group=mcfg.ring_group,
                    intra_group=mcfg.intra_group,
                    wire_dtype=mcfg.wire_dtype, gemm_impl=gemm_impl)
            else:
                blocks, rot = T.transport_comet_blocks(
                    ctx, send, w_local, cfg.activation, n_col_blocks=n_col,
                    ring_group=mcfg.ring_group, gemm_impl=gemm_impl)
            parts = [R.combine(b.reshape(ep * E_loc * C, b.shape[-1]), info,
                               wts, E_loc, C, rot, ep) for b in blocks]
            y = parts[0] if len(parts) == 1 else \
                jnp.concatenate(parts, axis=-1)
        else:
            if impl == "comet_hier":
                blocks, rot = T.transport_comet_hier(
                    ctx, send, w_local, cfg.activation, n_col_blocks=n_col,
                    ring_group=mcfg.ring_group,
                    intra_group=mcfg.intra_group,
                    wire_dtype=mcfg.wire_dtype, gemm_impl=gemm_impl)
                out = blocks[0] if len(blocks) == 1 else \
                    jnp.concatenate(blocks, axis=-1)
            elif impl == "comet":
                out, rot = T.transport_comet(ctx, send, w_local,
                                             cfg.activation,
                                             n_col_blocks=n_col,
                                             ring_group=mcfg.ring_group,
                                             gemm_impl=gemm_impl)
            else:                                                    # naive / dense
                out, rot = T.transport_naive(ctx, send, w_local,
                                             cfg.activation, gemm_impl)
            y = R.combine(out.reshape(ep * E_loc * C, dw), info, wts, E_loc,
                          C, rot, ep)

    y = ascend(y).reshape(B, S, d)
    # aux already pmean'd over token axes inside the router
    return y, aux


def _coarse(cfg, mcfg, ctx, xt, idx, wts, E, C, w_local, gemm_impl=None):
    """FasterMoE-style: n token slices, each a full (a2a → MLP → a2a) round.

    ``C`` is the full-batch capacity from the outer routing pass; it is
    reused when no slice-local re-routing happens (n == 1 — the slice IS the
    batch), with an equivalence assertion that the slice-local computation
    would have agreed. Only n > 1 recomputes a per-slice capacity."""
    n = max(1, mcfg.coarse_chunks)
    Tn, d = xt.shape
    while Tn % n:
        n -= 1
    Ts = Tn // n
    if n == 1:
        Cs = C
        # drift guard, not a runtime check: fires if the slicing arithmetic
        # above ever makes Ts != Tn (or capacity grows new inputs) while
        # this arm still reuses the outer C
        assert R.capacity(Ts, mcfg.top_k, E, mcfg.capacity_factor) == C, \
            "slice-local capacity must equal the outer routing pass's"
    else:
        Cs = R.capacity(Ts, mcfg.top_k, E, mcfg.capacity_factor)
    ep = ctx.ep
    E_loc = E // ep
    outs = []
    for i in range(n):
        xs = xt[i * Ts:(i + 1) * Ts]
        ids = idx[i * Ts:(i + 1) * Ts]
        ws = wts[i * Ts:(i + 1) * Ts]
        buf, info = R.build_dispatch(xs, ids, E, Cs)
        send = buf.reshape(ep, E_loc, Cs, d)
        out, _ = T.transport_naive(ctx, send, w_local, cfg.activation,
                                   gemm_impl)
        outs.append(R.combine(out.reshape(ep * E_loc * Cs, d), info, ws,
                              E_loc, Cs, None, ep))
    return jnp.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def resolve_token_sharding(ctx: AxisCtx, B: int, S: int):
    """(seq_sharded, dp_axes) for a (B, S) input — the ONE place the body's
    token sharding is decided. Sequence sharding needs S divisible by the
    model axis; a batch indivisible by dp is REPLICATED over dp (e.g.
    long-context decode with B=1) instead of sharded."""
    if not ctx.active:
        return False, ()
    seq_sharded = ctx.seq_shard and S > 1 and S % ctx.model_size == 0
    dp_axes = (ctx.dp_axes
               if ctx.dp_size > 1 and B % ctx.dp_size == 0 else ())
    return seq_sharded, dp_axes


def local_token_count(ctx: AxisCtx, B: int, S: int) -> int:
    """Tokens per model-axis group — the M of the plan-shape key, derived
    from ``resolve_token_sharding`` so the key always matches the sharding
    the body actually runs under. tools/tune.py keys its measured plans
    with this too."""
    seq_sharded, dp_axes = resolve_token_sharding(ctx, B, S)
    dp = ctx.dp_size if dp_axes else 1
    ms = ctx.model_size if seq_sharded else 1
    return max(1, B * S // (dp * ms))


def moe_ffn(cfg, mcfg, params, x, ctx: AxisCtx,
            n_col: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) global (under pjit) or local (no mesh). Returns (y, aux).

    Schedule resolution: when ``mcfg.plan_cache`` (or $REPRO_PLAN_CACHE) is
    set and ``mcfg.plan_override`` is not, the transport/ring_group/n_col/
    gemm backend all come from the tuned plan cache for this shape (missing
    cache → analytical model). Otherwise the explicit config knobs apply;
    n_col == 0 → adaptive workload assignment picks the layer-1 column
    split. The plan's gemm backend rides ``mcfg.gemm_impl`` into the body —
    an explicit argument end to end, never a module global."""
    from repro.core import adaptive as A
    from repro.core import transport as T
    B, S = x.shape[0], x.shape[1]
    # the sharding the body will actually run under — resolved once by
    # resolve_token_sharding, used for both the plan key (via
    # local_token_count) and the shard_map specs below
    seq_sharded, dp_axes = resolve_token_sharding(ctx, B, S)
    toks_local = local_token_count(ctx, B, S)
    if A.plan_lookup_enabled(mcfg):
        plan = A.resolve_plan(mcfg, cfg.d_model, toks_local, ctx.ep, ctx.etp)
        if plan is not None:
            mcfg = plan.apply(mcfg)
            n_col = plan.n_col_blocks
    if n_col == 0:
        n_col = A.resolve_n_col(mcfg, cfg.d_model, toks_local,
                                ctx.ep, ctx.etp)
    gemm_impl = T._impl(mcfg.gemm_impl)
    router_w = params["router"]
    experts = {k: v for k, v in params["experts"].items()}
    # BigMac descend/ascend projections ride along replicated (like the
    # router weight) when the schema declared them
    w_desc, w_asc = params.get("w_desc"), params.get("w_asc")

    if not ctx.active:
        return _moe_body(cfg, mcfg, AxisCtx(), n_col, gemm_impl, x,
                         router_w, experts, w_desc=w_desc, w_asc=w_asc)

    x_spec = P(dp_axes or None,
               ctx.model_axis if seq_sharded else None, None)
    body_ctx = dataclasses.replace(ctx, seq_shard=seq_sharded,
                                   dp_axes=dp_axes)

    expert_specs = {k: P(ctx.model_axis, None, None, None) for k in experts}
    if w_desc is None:
        def body(x_l, rw, ew):
            return _moe_body(cfg, mcfg, body_ctx, n_col, gemm_impl, x_l,
                             rw, ew)

        f = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(x_spec, P(None, None), expert_specs),
            out_specs=(x_spec, P()),
            check_vma=False)
        return f(x, router_w, experts)

    def body_w(x_l, rw, ew, wd, wa):
        return _moe_body(cfg, mcfg, body_ctx, n_col, gemm_impl, x_l, rw,
                         ew, w_desc=wd, w_asc=wa)

    f = shard_map(
        body_w, mesh=ctx.mesh,
        in_specs=(x_spec, P(None, None), expert_specs, P(None, None),
                  P(None, None)),
        out_specs=(x_spec, P()),
        check_vma=False)
    return f(x, router_w, experts, w_desc, w_asc)
