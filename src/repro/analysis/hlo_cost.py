"""Static cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — a scanned
L-layer transformer is undercounted ~L×, which poisons any roofline derived
from it. This module re-derives per-device FLOPs / HBM bytes / ICI bytes by
walking the HLO text with correct call-graph multiplicities:

* ``while`` bodies are multiplied by their trip count (parsed from
  ``backend_config={"known_trip_count":{"n":...}}``, falling back to the
  comparison constant in the condition computation);
* ``fusion`` call sites contribute the *called computation's FLOPs* but only
  the call-site operand/result bytes (fusion internals live in registers /
  VMEM, not HBM — this is also more faithful to a roofline than XLA's
  per-op "bytes accessed");
* collectives contribute ring-model ICI bytes: all-reduce 2(n-1)/n·B,
  all-gather (n-1)·B_shard, reduce-scatter (n-1)/n·B, all-to-all (n-1)/n·B,
  collective-permute 1 hop·B — counted at ``-start`` for async pairs and
  multiplied by enclosing while trip counts (collectives inside the layer
  scan are the common case).

Shapes in a post-SPMD module are shard-local, so every total is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-$]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_NAME = re.compile(r"[\w\-]+\Z")
_OPERAND_NAME = re.compile(r"%([\w.\-$]+)")
_TRIP_BC = re.compile(r'known_trip_count[":{]+n["\s:]+(\d+)')
_CONST_INT = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_SET = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CALLS = re.compile(r"calls=%?([\w.\-$]+)")
_COND = re.compile(r"condition=%?([\w.\-$]+)")
_BODY = re.compile(r"body=%?([\w.\-$]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIM_LABELS = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "domain",
    "opt-barrier", "add-dependency",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
}
_ELEMENTWISE_FLOP = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "atan2",
    "cosine", "sine", "erf", "exponential-minus-one", "log-plus-one",
    "clamp", "remainder", "logistic", "cbrt", "tan", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
    "stochastic-convert", "reduce-precision", "map", "popcnt", "clz",
}


def shape_numel_bytes(type_str: str) -> Tuple[int, int]:
    """(numel, bytes) summed over every dtype[dims] token (tuples sum)."""
    numel = nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str
    numel: int
    nbytes: int


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mxu_flops: float = 0.0            # dot/convolution only
    bytes: float = 0.0
    ici_bytes: float = 0.0
    coll_per_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.mxu_flops += other.mxu_flops * mult
        self.bytes += other.bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        for k, v in other.coll_per_op.items():
            self.coll_per_op[k] = self.coll_per_op.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: List[Instr] = []
        self.by_name: Dict[str, Instr] = {}
        self.text_lines: List[str] = []

    def add(self, ins: Instr):
        self.instrs.append(ins)
        self.by_name[ins.name] = ins


def _scan_paren(s: str, start: int) -> int:
    """Index just past the paren-group opening at s[start] == '('."""
    depth, i = 0, start
    while i < len(s):
        c = s[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
        i += 1
    return len(s)


def parse_instr_line(line: str) -> Optional[Instr]:
    """Parse ``[ROOT] %name = TYPE op(operands), attrs``. TYPE may be a huge
    tuple containing ``/*index=N*/`` comments — regexes over it are unsafe,
    so this uses paren-depth scanning."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):                       # tuple type
        end = _scan_paren(rest, 0)
        type_str = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    p = rest.find("(")
    if p <= 0:
        return None
    op = rest[:p]
    if not _OP_NAME.match(op):
        return None
    end = _scan_paren(rest, p)
    operand_str = rest[p + 1:end - 1]
    attrs = rest[end:]
    numel, nbytes = shape_numel_bytes(type_str)
    ops = _OPERAND_NAME.findall(operand_str)
    return Instr(name, type_str, op, ops, attrs, numel, nbytes)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.text_lines.append(line)
        ins = parse_instr_line(line)
        if ins is not None:
            cur.add(ins)
    return comps, entry


def _group_size(attrs: str, default: int = 2) -> int:
    m = _GROUPS_IOTA.search(attrs)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_SET.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(while_attrs: str, cond: Optional[Computation]) -> int:
    m = _TRIP_BC.search(while_attrs)
    if m:
        return int(m.group(1))
    if cond is not None:
        best = 1
        for line in cond.text_lines:
            for c in _CONST_INT.findall(line):
                best = max(best, int(c))
        return best
    return 1


class HLOCostModel:
    """Evaluates per-device cost of the entry computation with correct
    while/fusion/conditional multiplicities."""

    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # -- per-instruction local helpers ------------------------------------
    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        total = 0.0
        for o in ins.operands:
            d = comp.by_name.get(o)
            if d is not None:
                total += d.nbytes
        return total

    def _operand_shape(self, comp: Computation, ins: Instr, i: int):
        if i < len(ins.operands):
            d = comp.by_name.get(ins.operands[i])
            if d is not None:
                dims_m = _SHAPE_RE.search(d.type_str)
                if dims_m:
                    dims = ([int(x) for x in dims_m.group(2).split(",")]
                            if dims_m.group(2) else [])
                    return dims, d.nbytes
        return None, 0

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        lhs_dims, _ = self._operand_shape(comp, ins, 0)
        contract = 1
        m = _CONTRACT.search(ins.attrs)
        if lhs_dims is not None and m and m.group(1):
            for ax in m.group(1).split(","):
                ax = int(ax)
                if ax < len(lhs_dims):
                    contract *= lhs_dims[ax]
        elif lhs_dims:
            contract = lhs_dims[-1]
        return 2.0 * ins.numel * contract

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        rhs_dims, _ = self._operand_shape(comp, ins, 1)
        if rhs_dims is None:
            return 2.0 * ins.numel
        m = _DIM_LABELS.search(ins.attrs)
        out_ch = 1
        if m:
            rhs_labels = m.group(2)
            o_pos = rhs_labels.find("o")
            if 0 <= o_pos < len(rhs_dims):
                out_ch = rhs_dims[o_pos]
        kernel_numel = 1
        for d in rhs_dims:
            kernel_numel *= d
        return 2.0 * ins.numel * kernel_numel / max(out_ch, 1)

    def _collective(self, cost: Cost, comp: Computation, ins: Instr):
        op = ins.op[:-6] if ins.op.endswith("-start") else ins.op
        ob = self._operand_bytes(comp, ins)
        if ob == 0:
            ob = ins.nbytes
        n = _group_size(ins.attrs)
        if op == "all-reduce":
            traffic = 2.0 * (n - 1) / n * ob
        elif op == "all-gather":
            traffic = (n - 1) * ob          # operand is the local shard
        elif op == "reduce-scatter":
            traffic = (n - 1) / n * ob
        elif op in ("all-to-all", "ragged-all-to-all"):
            traffic = (n - 1) / n * ob
        elif op == "collective-broadcast":
            traffic = float(ob)
        else:                                # collective-permute: one hop
            traffic = float(ob)
        cost.ici_bytes += traffic
        cost.coll_per_op[op] = cost.coll_per_op.get(op, 0.0) + traffic
        cost.coll_counts[op] = cost.coll_counts.get(op, 0.0) + 1
        cost.bytes += ob + ins.nbytes        # collectives also touch HBM

    # -- per-computation cost ----------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        """Cost of one computation.

        Ops whose metadata op_name contains ``__fusable__`` contribute FLOPs
        but NO bytes: the model tags regions (via jax.named_scope) that run as
        a single fused Pallas kernel on the real TPU target (e.g. flash
        attention keeps its score tensors in VMEM), so their intermediate HBM
        traffic is a CPU-lowering artifact. The kernel's true boundary bytes
        are added back analytically by roofline.analyze.
        """
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        self._memo[name] = cost              # cycles cannot occur in HLO
        if comp is None:
            return cost
        for ins in comp.instrs:
            op = ins.op
            if op in _FREE_OPS:
                continue
            if "__fusable__" in ins.attrs and op not in (
                    "while", "conditional", "call"):
                base_f = op[:-6] if op.endswith("-start") else op
                if base_f in _COLLECTIVES and not op.endswith("-done"):
                    # partitioner-inserted collectives move to the kernel
                    # boundary on real TPU but still cross ICI: count the
                    # traffic, skip only the HBM bytes
                    hbm = Cost()
                    self._collective(hbm, comp, ins)
                    cost.ici_bytes += hbm.ici_bytes
                    for k, v in hbm.coll_per_op.items():
                        cost.coll_per_op[k] = cost.coll_per_op.get(k, 0) + v
                    for k, v in hbm.coll_counts.items():
                        cost.coll_counts[k] = cost.coll_counts.get(k, 0) + v
                    continue
                if op == "fusion":
                    m = _CALLS.search(ins.attrs)
                    if m:
                        sub = self.comp_cost(m.group(1))
                        cost.flops += sub.flops
                        cost.mxu_flops += sub.mxu_flops
                elif op == "dot":
                    f = self._dot_flops(comp, ins)
                    cost.flops += f
                    cost.mxu_flops += f
                elif op in _ELEMENTWISE_FLOP:
                    cost.flops += float(ins.numel)
                continue
            if op == "while":
                cond_m = _COND.search(ins.attrs)
                body_m = _BODY.search(ins.attrs)
                sub = Cost()
                if body_m:
                    sub.add(self.comp_cost(body_m.group(1)))
                cond = self.comps.get(cond_m.group(1)) if cond_m else None
                if cond_m:
                    sub.add(self.comp_cost(cond_m.group(1)))
                trip = _trip_count(ins.attrs, cond)
                cost.add(sub, mult=trip)
                continue
            if op == "conditional":
                m = _BRANCHES.search(ins.attrs)
                if m:
                    branches = _OPERAND_NAME.findall(m.group(1))
                    subs = [self.comp_cost(b) for b in branches]
                    if subs:
                        best = max(subs, key=lambda c: (c.flops, c.bytes))
                        cost.add(best)
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            if op == "fusion":
                m = _CALLS.search(ins.attrs)
                if m:
                    sub = self.comp_cost(m.group(1))
                    cost.flops += sub.flops          # FLOPs from internals
                    cost.mxu_flops += sub.mxu_flops
                    cost.ici_bytes += sub.ici_bytes  # (none in practice)
                if "dynamic_update_slice" in ins.attrs or \
                        "dynamic-update-slice" in ins.attrs:
                    # in-place update fusion (KV-cache insert): only the
                    # update operand moves, not the aliased buffer
                    obs = [comp.by_name[o].nbytes for o in ins.operands
                           if o in comp.by_name]
                    if obs:
                        cost.bytes += 2.0 * (sum(obs) - max(obs))
                        continue
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            if op == "call" or op.startswith("async"):
                m = _CALLS.search(ins.attrs) or _OPERAND_NAME.search(ins.attrs)
                if m:
                    cost.add(self.comp_cost(m.group(1)))
                continue
            if op.endswith("-done"):
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                self._collective(cost, comp, ins)
                continue
            if op == "dot":
                f = self._dot_flops(comp, ins)
                cost.flops += f
                cost.mxu_flops += f
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            if op == "convolution":
                f = self._conv_flops(comp, ins)
                cost.flops += f
                cost.mxu_flops += f
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            if op in ("dynamic-update-slice",):
                # in-place: touches only the update operand's bytes (r+w)
                _, ub = self._operand_shape(comp, ins, 1)
                cost.bytes += 2.0 * ub
                continue
            if op in ("dynamic-slice", "slice"):
                cost.bytes += 2.0 * ins.nbytes
                continue
            if op == "gather":
                cost.bytes += 2.0 * ins.nbytes
                continue
            if op == "scatter":
                _, ub = self._operand_shape(comp, ins, 2)
                cost.bytes += 2.0 * ub + ins.nbytes
                cost.flops += ins.numel
                continue
            if op in ("reduce", "reduce-window"):
                in_dims, ib = self._operand_shape(comp, ins, 0)
                n_in = 1
                for d in (in_dims or []):
                    n_in *= d
                cost.flops += float(n_in)
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            if op in _ELEMENTWISE_FLOP:
                cost.flops += float(ins.numel)
                cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
                continue
            # copy, transpose, broadcast, pad, concatenate, sort, rng,
            # custom-call, iota, ...: pure data movement (or unknown)
            cost.bytes += self._operand_bytes(comp, ins) + ins.nbytes
        return cost

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        # reset memo so repeated calls stay correct
        self._memo = {}
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HLOCostModel(hlo_text).entry_cost()
