"""Discrete-event overlap simulator for one MoE layer (and the e2e model).

Reproduces the paper's evaluation (Figures 1a, 8–14) without GPUs: each
mechanism is a task graph over two device resources (compute engine, link)
plus a host launch thread; the event loop resolves start times from resource
availability and data dependencies. Chunk granularity matches each
mechanism's real schedule:

  megatron_cutlass / megatron_te — serial: a2a → GroupGEMM → a2a; no overlap.
  fastermoe   — pipeline degree 2 (the paper's description of [8]); EP only.
  tutel       — n-chunk 2D-hierarchical a2a pipeline; per-chunk kernels mean
                host scheduling overhead scales with chunks AND experts.
  comet       — the paper: EP source-rank chunks (chunk 0 = local, zero recv
                latency), fused per-chunk MLP, layer-1 N-decomposed into
                n_col blocks whose return traffic starts after the first
                block completes; single fused kernel ⇒ one host launch.
                On GPU hardware, thread-block specialization donates nc/n_sm
                of compute throughput to communication (adaptive); on TPU the
                ICI DMA engines are disjoint so compute is NOT derated — the
                hardware-adaptation note in DESIGN.md.

Host-overhead and efficiency constants are calibrated once against the
paper's Fig. 10/11 operating point (Mixtral 8×7B shapes, EP=8, H100) and then
validated — not re-fit — against the paper's other claims (e2e 1.71×, layer
1.28–2.37×, hiding 86.5%/68.6%/29.2%, L20 1.19–1.46×); see
benchmarks/ + tests/test_simulator.py for the asserted bands.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.adaptive import (H100_CROSSNODE, H100_NVL, L20_PCIE,
                                 TPU_V5E, Hardware, MoEShape)

# host-side launch overhead per kernel (CUDA launch + python dispatch); the
# paper attributes FasterMoE/Tutel's small-M losses to this
HOST_LAUNCH_S = 22e-6

# effective fraction of peak link bandwidth achieved by bulk all-to-all with
# per-peer messages in the 1-8 MB range (NCCL on NVLink is far from peak at
# MoE dispatch sizes — this is what makes comm 47% of Fig. 1a despite
# 377 GB/s links). Calibrated once at the Fig. 10/11 operating point.
A2A_EFF = {"h100_nvlink": 0.12, "l20_pcie": 0.45, "tpu_v5e": 0.55,
           "h100_crossnode": 0.3}


def link_rate(hw: Hardware) -> float:
    return hw.link_bw * hw.links * A2A_EFF.get(hw.name, 0.5)


def link_rate_class(hw: Hardware, cls: str) -> float:
    """Effective rate of one link class of an asymmetric topology (same
    per-preset a2a efficiency; the class picks the raw bandwidth). Falls
    back to the flat link_bw where the descriptor leaves a class unset."""
    if cls == "intra":
        bw = hw.intra_bw or hw.link_bw
    else:
        bw = hw.inter_bw or hw.link_bw
    return bw * hw.links * A2A_EFF.get(hw.name, 0.5)


@dataclasses.dataclass
class Timeline:
    """Three-resource event timeline (compute, link, host)."""
    core: float = 0.0
    link: float = 0.0
    host: float = 0.0
    launches: int = 0

    def launch(self, n: int = 1) -> float:
        """Host issues n kernels; returns the time the last is issued."""
        self.host += n * HOST_LAUNCH_S
        self.launches += n
        return self.host

    def compute(self, dur: float, ready: float = 0.0) -> float:
        start = max(self.core, ready)
        self.core = start + dur
        return self.core

    def comm(self, dur: float, ready: float = 0.0) -> float:
        start = max(self.link, ready)
        self.link = start + dur
        return self.link


# ---------------------------------------------------------------------------
# Per-device work for one MoE layer (uniform routing unless imbalance > 0)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerWork:
    rows: float          # expert rows this device computes (M*topk/EP)
    flops_l0: float      # layer-0 GEMM flops (gate+up)
    flops_l1: float      # layer-1 GEMM flops (down)
    disp_bytes: float    # dispatch bytes crossing this device's link
    comb_bytes: float    # combine bytes
    small_rows: float    # rows per expert (tile-efficiency check)


def layer_work(s: MoEShape, imbalance_std: float = 0.0) -> LayerWork:
    W = s.ep * s.etp
    n_mats = 2 if s.glu else 1
    rows = s.M * s.topk / s.ep
    hot = 1.0 + min(2.0, imbalance_std * s.E)      # hottest-rank scaling
    rows *= hot
    k_loc = s.K / s.etp
    flops_l0 = 2.0 * rows * s.N * k_loc * n_mats
    flops_l1 = 2.0 * rows * k_loc * s.N
    remote = (s.ep - 1) / s.ep if s.ep > 1 else 0.0
    disp = s.M / W * s.topk * s.N * s.bytes_per_elt * remote * s.etp * hot
    comb = disp
    if s.etp > 1:
        # ETP adds the partial-output all-reduce over the TP group
        comb += 2.0 * (s.etp - 1) / s.etp * rows * s.N * s.bytes_per_elt \
            / s.etp
    return LayerWork(rows, flops_l0, flops_l1, disp, comb,
                     rows / max(1, s.E / s.ep))


def _eff(hw: Hardware, rows_per_expert: float, k_loc: float = 1e9,
         fragmented: bool = True) -> float:
    """GEMM efficiency: small M-tiles derate everyone; a TP-fragmented K
    (baselines switch weights per small GEMM — paper Fig. 12) derates the
    baselines, while comet's rescheduled GroupGEMM keeps the MXU/tensor-core
    utilization (fragmented=False)."""
    eff = hw.gemm_eff if rows_per_expert >= 128 else \
        hw.gemm_eff * hw.small_tile_penalty
    if fragmented and k_loc < 4096:
        eff *= 0.75
    return eff


def _chunk_rate(hw: Hardware, n_chunks: int) -> float:
    """Chunked a2a sends k× smaller per-peer messages; effective bandwidth
    degrades with chunk count (NCCL latency-bound regime)."""
    return link_rate(hw) / (1.0 + 0.15 * (n_chunks - 1))


# ---------------------------------------------------------------------------
# Mechanisms
# ---------------------------------------------------------------------------


def sim_megatron(hw: Hardware, s: MoEShape, imb: float = 0.0,
                 te: bool = False) -> Dict:
    """Serial, no overlap. TE variant has extra framework call overhead."""
    w = layer_work(s, imb)
    tl = Timeline()
    eff = _eff(hw, w.small_rows, s.K / s.etp)
    # router + permute/indexing kernels
    r = tl.launch(8 + (6 if te else 0))
    t = tl.comm(w.disp_bytes / link_rate(hw), ready=r)
    r = tl.launch(3)
    t = tl.compute(w.flops_l0 / (hw.flops * eff), ready=max(t, r))
    r = tl.launch(2)
    t = tl.compute(w.flops_l1 / (hw.flops * eff), ready=max(t, r))
    r = tl.launch(2)
    t = tl.comm(w.comb_bytes / link_rate(hw), ready=max(t, r))
    r = tl.launch(3)                               # un-permute + topk reduce
    end = max(t, r)
    return {"total": end, "comm": (w.disp_bytes + w.comb_bytes) /
            link_rate(hw), "overlapped": 0.0, "tl": tl}


def sim_pipeline(hw: Hardware, s: MoEShape, n_chunks: int, imb: float = 0.0,
                 launches_per_chunk: int = 8,
                 extra_local_compute: float = 0.0) -> Dict:
    """Coarse-grained k-chunk pipeline (FasterMoE k=2, Tutel k=n): chunked
    a2a and expert compute overlap across chunks; partitioned experts run at
    reduced tile efficiency; each chunk re-launches its kernel set."""
    w = layer_work(s, imb)
    tl = Timeline()
    eff = _eff(hw, w.small_rows / n_chunks, s.K / s.etp)
    # comm kernels on a second stream contend for SMs with the GEMMs
    eff *= 0.9
    rate = _chunk_rate(hw, n_chunks)
    comm_total = 0.0
    recv_done: List[float] = []
    for i in range(n_chunks):
        r = tl.launch(launches_per_chunk // 2)
        d = w.disp_bytes / n_chunks / rate
        recv_done.append(tl.comm(d, ready=r))
        comm_total += d
    mlp_done: List[float] = []
    for i in range(n_chunks):
        r = tl.launch(launches_per_chunk // 2)
        f = (w.flops_l0 + w.flops_l1) / n_chunks / (hw.flops * eff)
        f += extra_local_compute / n_chunks
        mlp_done.append(tl.compute(f, ready=max(recv_done[i], r)))
    end = 0.0
    for i in range(n_chunks):
        d = w.comb_bytes / n_chunks / rate
        end = tl.comm(d, ready=mlp_done[i])
        comm_total += d
    serial_comm = comm_total
    comp_time = (w.flops_l0 + w.flops_l1) / (hw.flops * eff) \
        + extra_local_compute
    # comm hidden = what a fully-serial schedule would add vs what we see
    overlapped = max(0.0, comp_time + serial_comm - end)
    return {"total": end, "comm": serial_comm,
            "overlapped": min(serial_comm, overlapped), "tl": tl}


def sim_fastermoe(hw: Hardware, s: MoEShape, imb: float = 0.0) -> Dict:
    if s.etp > 1:
        raise ValueError("FasterMoE supports expert parallelism only")
    # local indexing extends computation (paper Fig. 11 note)
    w = layer_work(s, imb)
    extra = 0.15 * (w.flops_l0 + w.flops_l1) / (hw.flops * hw.gemm_eff)
    return sim_pipeline(hw, s, n_chunks=2, imb=imb,
                        launches_per_chunk=10 + s.E // 4,
                        extra_local_compute=extra)


def sim_tutel(hw: Hardware, s: MoEShape, imb: float = 0.0) -> Dict:
    # optimized 2D a2a burdens local compute (paper Fig. 11 note)
    w = layer_work(s, imb)
    extra = 0.08 * (w.flops_l0 + w.flops_l1) / (hw.flops * hw.gemm_eff)
    return sim_pipeline(hw, s, n_chunks=4, imb=imb,
                        launches_per_chunk=8 + s.E // 8,
                        extra_local_compute=extra)


def sim_comet(hw: Hardware, s: MoEShape, imb: float = 0.0,
              n_col: int = 0, tpu: bool = False,
              nc_frac: Optional[float] = None) -> Dict:
    """Fine-grained: EP source-rank chunks, local chunk first, fused per-chunk
    MLP, N-decomposed layer-1 with early block return; one fused kernel."""
    w = layer_work(s, imb)
    tl = Timeline()
    ep = max(1, s.ep)
    if n_col <= 0:
        from repro.core.adaptive import choose_n_col
        n_col = choose_n_col(hw, s)
    # GPU: thread-block specialization splits SMs between comm and compute;
    # the adaptive division point balances per-chunk comm and compute.
    if tpu:
        comp_scale, link_scale = 1.0, 1.0
    else:
        if nc_frac is None:
            t_comm = (w.disp_bytes + w.comb_bytes) / link_rate(hw)
            t_comp = (w.flops_l0 + w.flops_l1) / (hw.flops * hw.gemm_eff)
            # donate enough SMs that comm keeps pace, floor/cap for sanity
            nc_frac = min(0.5, max(0.05, t_comm / max(t_comm + t_comp, 1e-12)))
        # GEMM throughput is sublinear in SM count (memory-bound tails), so
        # donating nc_frac of SMs costs ~half of it in GEMM time (Fig. 8's
        # flat region around the optimum)
        comp_scale = 1.0 - 0.5 * nc_frac
        link_scale = 1.0
    # unpartitioned experts + rescheduled GroupGEMM: no fragmentation derate
    eff = _eff(hw, w.small_rows, fragmented=False) * comp_scale
    r = tl.launch(1)                                    # ONE fused kernel
    comm_total = 0.0

    # dispatch: chunk 0 is local; chunks 1..ep-1 stream over the link
    recv_done = [r]
    for i in range(1, ep):
        d = w.disp_bytes / max(1, ep - 1) / (link_rate(hw) * link_scale)
        recv_done.append(tl.comm(d, ready=r))
        comm_total += d
    end = r
    for i in range(ep):
        f0 = w.flops_l0 / ep / (hw.flops * eff)
        t0 = tl.compute(f0, ready=recv_done[i])
        # layer-1 in n_col column blocks; each block returns as produced
        for b in range(n_col):
            f1 = w.flops_l1 / ep / n_col / (hw.flops * eff)
            tb = tl.compute(f1)
            d = w.comb_bytes / ep / n_col / (link_rate(hw) * link_scale)
            end = tl.comm(d, ready=tb)
            comm_total += d
    end = max(end, tl.core)
    comp_time = (w.flops_l0 + w.flops_l1) / (hw.flops * eff)
    overlapped = max(0.0, comp_time + comm_total - end)
    return {"total": end, "comm": comm_total,
            "overlapped": min(comm_total, overlapped), "tl": tl,
            "n_col": n_col}


def sim_comet_hier(hw: Hardware, s: MoEShape, plan, imb: float = 0.0,
                   n_col: int = 0, tpu: bool = False) -> Dict:
    """comet's fine-grained schedule on the two-level ring: each sub-step's
    dispatch/combine hop is priced at its link class (intra vs inter, the
    inter steps front-loaded — core/adaptive.hier_step_order), and the
    wire format shrinks the bytes of both directions. Compute is identical
    to sim_comet: the hierarchy only re-routes traffic."""
    from repro.core import adaptive as A
    w = layer_work(s, imb)
    tl = Timeline()
    ep = max(1, s.ep)
    if n_col <= 0:
        from repro.core.adaptive import choose_n_col
        n_col = choose_n_col(hw, s)
    if tpu:
        comp_scale = 1.0
    else:
        t_comm = (w.disp_bytes + w.comb_bytes) / link_rate(hw)
        t_comp = (w.flops_l0 + w.flops_l1) / (hw.flops * hw.gemm_eff)
        nc_frac = min(0.5, max(0.05, t_comm / max(t_comm + t_comp, 1e-12)))
        comp_scale = 1.0 - 0.5 * nc_frac
    eff = _eff(hw, w.small_rows, fragmented=False) * comp_scale
    classes = A.hier_step_classes(ep, plan.intra_group)
    wire_scale = (A.wire_bytes_per_elt(s, plan.wire_dtype)
                  / s.bytes_per_elt)
    r = tl.launch(1)
    comm_total = 0.0
    recv_done = [r]
    for i in range(1, ep):
        d = (w.disp_bytes * wire_scale / max(1, ep - 1)
             / link_rate_class(hw, classes[i]))
        recv_done.append(tl.comm(d, ready=r))
        comm_total += d
    end = r
    for i in range(ep):
        f0 = w.flops_l0 / ep / (hw.flops * eff)
        tl.compute(f0, ready=recv_done[i])
        for b in range(n_col):
            f1 = w.flops_l1 / ep / n_col / (hw.flops * eff)
            tb = tl.compute(f1)
            if classes[i] == "local":
                continue                      # local chunk: no return hop
            d = (w.comb_bytes * wire_scale / ep / n_col
                 / link_rate_class(hw, classes[i]))
            end = tl.comm(d, ready=tb)
            comm_total += d
    end = max(end, tl.core)
    comp_time = (w.flops_l0 + w.flops_l1) / (hw.flops * eff)
    overlapped = max(0.0, comp_time + comm_total - end)
    return {"total": end, "comm": comm_total,
            "overlapped": min(comm_total, overlapped), "tl": tl,
            "n_col": n_col}


MECHANISMS = {
    "megatron_cutlass": lambda hw, s, imb=0.0: sim_megatron(hw, s, imb),
    "megatron_te": lambda hw, s, imb=0.0: sim_megatron(hw, s, imb, te=True),
    "fastermoe": sim_fastermoe,
    "tutel": sim_tutel,
    "comet": sim_comet,
}


# ---------------------------------------------------------------------------
# e2e model: attention part identical across mechanisms (paper Fig. 9 hatch)
# ---------------------------------------------------------------------------


def attn_time(hw: Hardware, d_model: int, tokens_per_dev: int, tp: int,
              bytes_per_elt: int = 2) -> float:
    """Per-layer non-MoE time: qkvo projections + sdpa + 2 TP all-reduces."""
    f_proj = 2.0 * tokens_per_dev * d_model * d_model * 4 / tp
    f_sdpa = 2.0 * 2.0 * tokens_per_dev * tokens_per_dev * d_model / tp
    t_comp = (f_proj + f_sdpa * 0.25) / (hw.flops * hw.gemm_eff)
    ar = 2 * 2.0 * tokens_per_dev * d_model * bytes_per_elt / \
        link_rate(hw) * (tp - 1) / max(tp, 1)
    return t_comp + (ar if tp > 1 else 0.0)


def sim_e2e(hw: Hardware, mech: str, s: MoEShape, d_model: int,
            n_layers: int, tp_nonmoe: int, imb: float = 0.0,
            tpu: bool = False) -> float:
    W = s.ep * s.etp
    tokens_dev = s.M // W
    ta = attn_time(hw, d_model, tokens_dev * (W // tp_nonmoe), tp_nonmoe)
    fn = MECHANISMS[mech]
    tm = (fn(hw, s, imb, tpu=tpu) if mech == "comet" else fn(hw, s, imb))
    return n_layers * (ta + tm["total"])


def sim_e2e_graph(hw: Hardware, s: MoEShape, plan, d_model: int,
                  n_layers: int, n_slices: int = 2, training: bool = False,
                  scheduled: bool = True) -> float:
    """Whole-graph e2e: ``n_layers`` blocks through the block-schedule IR
    (core/schedule.py) under a comet ``plan``. ``scheduled=False`` is the
    layer-at-a-time per-layer-overlap baseline (same segments, per-block
    barriers, no micro-slicing) — the pair is the PR 6 differencing figure.
    Modeled on a two-block window and scaled: the schedule is periodic, so
    per-block steady-state time is what an L-layer stack repeats."""
    from repro.core import schedule as SCH   # lazy: avoids an import cycle
    t = SCH.graph_step_time(hw, s, plan, d_model=d_model, n_blocks=2,
                            n_slices=n_slices, training=training,
                            scheduled=scheduled)
    return n_layers * t["total"] / 2.0
