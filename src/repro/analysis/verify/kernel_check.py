"""Pass 2 — Pallas kernel resource checker.

A ``pallas_call`` is a contract: the grid × BlockSpecs must (a) fit the
per-core VMEM budget (each grid step holds every operand block plus the
scratch, and the pipeline double-buffers the HBM↔VMEM operand blocks),
(b) only ever index inside the backing arrays, (c) cover every output
tile, and (d) accumulate reduced dtypes in fp32. Mosaic enforces none of
this at Python time and interpret mode only at runtime for the shapes a
test happens to pick — this pass checks the contract statically.

The checker works on :class:`KernelModel` — an analytical mirror of a
kernel's ``pallas_call`` (grid, BlockSpecs with their index maps, scratch
shapes, accumulation dtype). ``builtin_kernel_models`` mirrors the six
repo kernels (fused_mlp fwd/dgrad/wgrad, grouped_gemm, rmsnorm,
topk_combine, ssd, flash_attention) at paper-scale shapes; the mutation
harness corrupts these models and requires every corruption to be
caught.

``fused_mlp_vmem_bytes`` / ``plan_vmem_ok`` are the same footprint math
specialized to the plan knobs — ``core/adaptive.candidate_plans`` calls
``plan_vmem_ok`` so a col_slice/n_major tiling that cannot fit VMEM is
rejected statically, before any measurement.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple

from repro.analysis.verify.diagnostics import Diagnostic

_PASS = "kernel"

DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4,
               "int8": 1, "int32": 4}
_REDUCED = ("bfloat16", "float16", "int8")

# the Pallas grid pipeline keeps the current AND next operand block in
# VMEM (double buffering); scratch is single-buffered and persists
PIPELINE_BUFFERS = 2


def _d(rule: str, loc: str, msg: str, hint: str = "",
       severity: str = "error") -> Diagnostic:
    return Diagnostic(_PASS, rule, severity, loc, msg, hint)


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One operand's blocking: the array it tiles, the block shape, and
    the grid-index -> block-index map (BlockSpec semantics: the map
    returns BLOCK indices, scaled by the block shape)."""
    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    dtype: str = "bfloat16"
    is_output: bool = False


@dataclasses.dataclass(frozen=True)
class KernelModel:
    name: str
    grid: Tuple[int, ...]
    blocks: Tuple[BlockUse, ...]
    scratch: Tuple[Tuple[Tuple[int, ...], str], ...] = ()
    accum_dtype: str = "float32"   # where partial products accumulate


def block_bytes(shape: Sequence[int], dtype: str) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * DTYPE_BYTES[dtype]


def vmem_footprint(model: KernelModel) -> int:
    """Bytes of VMEM one grid step pins: double-buffered operand blocks
    plus single-buffered scratch."""
    io = sum(block_bytes(b.block_shape, b.dtype) for b in model.blocks)
    sc = sum(block_bytes(shape, dt) for shape, dt in model.scratch)
    return PIPELINE_BUFFERS * io + sc


def check_vmem(model: KernelModel, vmem_bytes: int) -> List[Diagnostic]:
    used = vmem_footprint(model)
    if used > vmem_bytes:
        return [_d("vmem-overflow", f"kernel:{model.name}",
                   f"VMEM footprint {used / 2**20:.1f} MiB exceeds the "
                   f"{vmem_bytes / 2**20:.1f} MiB budget "
                   f"(double-buffered operand blocks + scratch)",
                   hint="shrink the block sizes (bf/bn) or raise "
                        "n_col_blocks so each call tiles fewer columns")]
    return []


def check_index_maps(model: KernelModel,
                     max_points: int = 262144) -> List[Diagnostic]:
    """Evaluate every index map over the full grid: block offsets must
    start inside the backing array, and the output maps must visit every
    output tile at least once."""
    diags: List[Diagnostic] = []
    npoints = 1
    for d in model.grid:
        npoints *= int(d)
    if npoints > max_points:
        return [_d("grid-too-large", f"kernel:{model.name}",
                   f"grid has {npoints} points > {max_points}; "
                   "index maps unchecked", severity="warning",
                   hint="model a reduced shape with the same structure")]
    seen = {b.name: set() for b in model.blocks if b.is_output}
    for idx in itertools.product(*(range(d) for d in model.grid)):
        for b in model.blocks:
            bi = tuple(int(x) for x in b.index_map(*idx))
            if len(bi) != len(b.block_shape):
                diags.append(_d(
                    "index-map-arity", f"kernel:{model.name}:{b.name}",
                    f"index map returned {len(bi)} indices for a "
                    f"{len(b.block_shape)}-d block"))
                return diags
            for d, (i, bs, dim) in enumerate(
                    zip(bi, b.block_shape, b.array_shape)):
                if i < 0 or i * bs >= dim:
                    diags.append(_d(
                        "index-out-of-bounds",
                        f"kernel:{model.name}:{b.name}",
                        f"grid point {idx}: block index {bi} puts dim "
                        f"{d} at offset {i * bs} outside array "
                        f"{tuple(b.array_shape)}",
                        hint="index maps return BLOCK indices; check "
                             "the grid-axis ordering"))
                    if len(diags) > 8:
                        return diags
            if b.is_output:
                seen[b.name].add(bi)
    for b in model.blocks:
        if not b.is_output:
            continue
        need = itertools.product(*(
            range(-(-dim // bs))
            for dim, bs in zip(b.array_shape, b.block_shape)))
        missing = [t for t in need if t not in seen[b.name]]
        if missing:
            diags.append(_d(
                "uncovered-output-tile", f"kernel:{model.name}:{b.name}",
                f"{len(missing)} output tile(s) never written "
                f"(first: {missing[0]}): those regions return garbage",
                hint="the grid must enumerate every output block index"))
    return diags


def check_accum_dtypes(model: KernelModel) -> List[Diagnostic]:
    reduced_in = [b.name for b in model.blocks
                  if not b.is_output and b.dtype in _REDUCED]
    if reduced_in and model.accum_dtype != "float32":
        return [_d("accum-dtype", f"kernel:{model.name}",
                   f"inputs {reduced_in} are {_REDUCED}-class but the "
                   f"accumulator is {model.accum_dtype}",
                   hint="accumulate in a float32 VMEM scratch / "
                        "preferred_element_type=float32")]
    return []


def check_model(model: KernelModel, vmem_bytes: int) -> List[Diagnostic]:
    return (check_vmem(model, vmem_bytes)
            + check_index_maps(model)
            + check_accum_dtypes(model))


# ---------------------------------------------------------------------------
# Analytical mirrors of the repo's kernels
# ---------------------------------------------------------------------------


def fused_mlp_model(E=8, R=256, d=4096, f=14336, N=None, *, bm=128, bf=512,
                    bn=0, order="expert_major", glu=True,
                    dtype="bfloat16") -> KernelModel:
    """Mirror of kernels/fused_mlp.fused_mlp's grid/specs. ``N`` defaults
    to ``d`` (full-width w_down); ``bn == 0`` means one full-width tile —
    a comet col_slice call passes ``N = d/n_col`` with ``bn = 0``."""
    N = d if N is None else N
    bm, bf = min(bm, R), min(bf, f)
    bn = N if bn <= 0 else min(bn, N)
    mt, nt, ft = R // bm, N // bn, f // bf
    if order == "expert_major":
        grid = (E, mt, nt, ft)
        ix = lambda e, m, n, fi: (e, m, 0)
        iw1 = lambda e, m, n, fi: (e, 0, fi)
        iwd = lambda e, m, n, fi: (e, fi, n)
        io = lambda e, m, n, fi: (e, m, n)
    else:                                    # n_major
        grid = (nt, E, mt, ft)
        ix = lambda n, e, m, fi: (e, m, 0)
        iw1 = lambda n, e, m, fi: (e, 0, fi)
        iwd = lambda n, e, m, fi: (e, fi, n)
        io = lambda n, e, m, fi: (e, m, n)
    blocks = [BlockUse("x", (E, R, d), (1, bm, d), ix, dtype)]
    if glu:
        blocks.append(BlockUse("w_gate", (E, d, f), (1, d, bf), iw1, dtype))
    blocks.append(BlockUse("w_up", (E, d, f), (1, d, bf), iw1, dtype))
    blocks.append(BlockUse("w_down", (E, f, N), (1, bf, bn), iwd, dtype))
    blocks.append(BlockUse("out", (E, R, N), (1, bm, bn), io, dtype,
                           is_output=True))
    return KernelModel(f"fused_mlp[{order}]", grid, tuple(blocks),
                       (((bm, bn), "float32"),))


def fused_mlp_dgrad_model(E=8, R=256, d=4096, f=14336, *, bm=128, bf=512,
                          glu=True, dtype="bfloat16") -> KernelModel:
    mt, ft = R // min(bm, R), f // min(bf, f)
    bm, bf = min(bm, R), min(bf, f)
    grid = (E, mt, ft)
    ix = lambda e, m, fi: (e, m, 0)
    iw1 = lambda e, m, fi: (e, 0, fi)
    iwd = lambda e, m, fi: (e, fi, 0)
    blocks = [BlockUse("x", (E, R, d), (1, bm, d), ix, dtype)]
    if glu:
        blocks.append(BlockUse("w_gate", (E, d, f), (1, d, bf), iw1, dtype))
    blocks.append(BlockUse("w_up", (E, d, f), (1, d, bf), iw1, dtype))
    blocks.append(BlockUse("w_down", (E, f, d), (1, bf, d), iwd, dtype))
    blocks.append(BlockUse("dy", (E, R, d), (1, bm, d), ix, dtype))
    blocks.append(BlockUse("dx", (E, R, d), (1, bm, d), ix, dtype,
                           is_output=True))
    return KernelModel("fused_mlp_dgrad", grid, tuple(blocks),
                       (((bm, d), "float32"),))


def grouped_gemm_model(E=8, M=256, N=4096, K=512, *, bm=128, bn=128,
                       bk=512, order="expert_major",
                       dtype="bfloat16") -> KernelModel:
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    mt, nt, kt = M // bm, N // bn, K // bk
    if order == "expert_major":
        grid = (E, mt, nt, kt)
        lhs = lambda e, m, n, k: (e, m, k)
        rhs = lambda e, m, n, k: (e, k, n)
        out = lambda e, m, n, k: (e, m, n)
    else:
        grid = (nt, E, mt, kt)
        lhs = lambda n, e, m, k: (e, m, k)
        rhs = lambda n, e, m, k: (e, k, n)
        out = lambda n, e, m, k: (e, m, n)
    return KernelModel(
        f"grouped_gemm[{order}]", grid,
        (BlockUse("lhs", (E, M, K), (1, bm, bk), lhs, dtype),
         BlockUse("rhs", (E, K, N), (1, bk, bn), rhs, dtype),
         BlockUse("out", (E, M, N), (1, bm, bn), out, dtype,
                  is_output=True)),
        (((bm, bn), "float32"),))


def rmsnorm_model(T=4096, d=4096, *, bt=256,
                  dtype="bfloat16") -> KernelModel:
    return KernelModel(
        "rmsnorm", (T // bt,),
        (BlockUse("x", (T, d), (bt, d), lambda i: (i, 0), dtype),
         BlockUse("scale", (d,), (d,), lambda i: (0,), dtype),
         BlockUse("out", (T, d), (bt, d), lambda i: (i, 0), dtype,
                  is_output=True)),
        accum_dtype="float32")   # fp32 row statistics in-body


def topk_combine_model(T=4096, k=2, d=4096, *, bt=256,
                       dtype="bfloat16") -> KernelModel:
    return KernelModel(
        "topk_combine", (T // bt,),
        (BlockUse("rows", (T, k, d), (bt, k, d), lambda i: (i, 0, 0),
                  dtype),
         BlockUse("weights", (T, k), (bt, k), lambda i: (i, 0), "float32"),
         BlockUse("out", (T, d), (bt, d), lambda i: (i, 0), dtype,
                  is_output=True)),
        accum_dtype="float32")   # fp32 einsum in-body


def ssd_model(B=4, nh=24, NC=16, Q=256, hd=64, ds=128,
              dtype="float32") -> KernelModel:
    return KernelModel(
        "ssd", (B * nh, NC),
        (BlockUse("x", (B * nh, NC * Q, hd), (1, Q, hd),
                  lambda bh, c: (bh, c, 0), dtype),
         BlockUse("dt", (B * nh, NC * Q, 1), (1, Q, 1),
                  lambda bh, c: (bh, c, 0), "float32"),
         BlockUse("A", (B * nh, 1), (1, 1), lambda bh, c: (bh, 0),
                  "float32"),
         BlockUse("Bm", (B * nh, NC * Q, ds), (1, Q, ds),
                  lambda bh, c: (bh, c, 0), dtype),
         BlockUse("Cm", (B * nh, NC * Q, ds), (1, Q, ds),
                  lambda bh, c: (bh, c, 0), dtype),
         BlockUse("D", (B * nh, 1), (1, 1), lambda bh, c: (bh, 0),
                  "float32"),
         BlockUse("out", (B * nh, NC * Q, hd), (1, Q, hd),
                  lambda bh, c: (bh, c, 0), dtype, is_output=True)),
        (((ds, hd), "float32"),))


def flash_attention_model(B=2, Hq=32, Hkv=8, Sq=2048, Sk=2048, hd=128,
                          *, bq=128, bk=128,
                          dtype="bfloat16") -> KernelModel:
    rep = Hq // Hkv
    nq, nk = Sq // bq, Sk // bk

    def kv_map(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // rep, ki, 0)

    qmap = lambda bh, qi, ki: (bh, qi, 0)
    return KernelModel(
        "flash_attention", (B * Hq, nq, nk),
        (BlockUse("q", (B * Hq, Sq, hd), (1, bq, hd), qmap, dtype),
         BlockUse("k", (B * Hkv, Sk, hd), (1, bk, hd), kv_map, dtype),
         BlockUse("v", (B * Hkv, Sk, hd), (1, bk, hd), kv_map, dtype),
         BlockUse("out", (B * Hq, Sq, hd), (1, bq, hd), qmap, dtype,
                  is_output=True)),
        (((bq, 1), "float32"), ((bq, 1), "float32"),
         ((bq, hd), "float32")))


def builtin_kernel_models() -> List[KernelModel]:
    """All six kernels at paper-scale shapes, both traversal orders where
    the kernel has them."""
    return [
        fused_mlp_model(order="expert_major"),
        fused_mlp_model(order="n_major", N=1024, R=1024),  # comet col_slice
        fused_mlp_dgrad_model(),
        grouped_gemm_model(order="expert_major"),
        grouped_gemm_model(order="n_major"),
        rmsnorm_model(),
        topk_combine_model(),                   # mixtral-style k=2, full d
        topk_combine_model(k=8, d=1024),        # qwen3-style k=8, col block
        ssd_model(),
        flash_attention_model(),
    ]


def check_builtin_kernels(vmem_bytes: Optional[int] = None
                          ) -> List[Diagnostic]:
    if vmem_bytes is None:
        from repro.core.adaptive import TPU_V5E
        vmem_bytes = TPU_V5E.vmem_bytes
    diags: List[Diagnostic] = []
    for model in builtin_kernel_models():
        diags.extend(check_model(model, vmem_bytes))
    return diags


# ---------------------------------------------------------------------------
# Plan-knob VMEM gate (core/adaptive.candidate_plans hook)
# ---------------------------------------------------------------------------


def fused_mlp_vmem_bytes(N: int, K: int, n_col: int, *, glu: bool = True,
                         bm: int = 128, bf: int = 512,
                         bytes_per_elt: int = 2) -> int:
    """VMEM footprint of one comet col-sliced fused_mlp call under a plan:
    the call tiles ``N/n_col`` output columns full-width (``bn=0``).
    Duck-typed on ints only so core/adaptive can import it cycle-free."""
    bn = max(1, N // max(1, n_col))
    bfe = min(bf, K)
    n_l0 = 2 if glu else 1
    io = (bm * N                       # x block (1, bm, d)
          + n_l0 * N * bfe             # w_gate/w_up blocks (1, d, bf)
          + bfe * bn                   # w_down block (1, bf, bn)
          + bm * bn) * bytes_per_elt   # out block (1, bm, bn)
    return PIPELINE_BUFFERS * io + bm * bn * 4   # + fp32 scratch


def plan_vmem_ok(s, plan, hw) -> bool:
    """Whether ``plan``'s implied kernel tiling fits ``hw.vmem_bytes``.
    Non-Pallas backends stream through XLA and are never rejected."""
    budget = getattr(hw, "vmem_bytes", 0)
    if not budget or plan.gemm_impl != "pallas_fused":
        return True
    n_col = (max(1, plan.n_col_blocks)
             if plan.impl in ("comet", "comet_hier") else 1)
    return fused_mlp_vmem_bytes(
        s.N, s.K, n_col, glu=s.glu,
        bytes_per_elt=s.bytes_per_elt) <= budget


def check_candidate_plans(shapes=None, hw=None) -> List[Diagnostic]:
    """Property check: ``candidate_plans`` must never emit a tiling that
    overflows the hardware's VMEM budget."""
    from repro.core import adaptive as A
    hw = hw or A.TPU_V5E
    if shapes is None:
        shapes = [
            A.MoEShape(M=8192, N=4096, K=14336, E=8, topk=2, ep=8, etp=1),
            A.MoEShape(M=8192, N=2048, K=1408, E=64, topk=4, ep=8, etp=1),
            A.MoEShape(M=4096, N=16384, K=4096, E=16, topk=2, ep=8, etp=1),
        ]
    diags: List[Diagnostic] = []
    for s in shapes:
        for p in A.candidate_plans(s, include_graph=True, hw=hw):
            if not plan_vmem_ok(s, p, hw):
                diags.append(_d(
                    "vmem-overflow", f"plan:N{s.N}:K{s.K}",
                    f"candidate_plans emitted {p.impl}/"
                    f"{p.gemm_impl} n_col={p.n_col_blocks} whose tiling "
                    f"needs more than {hw.vmem_bytes / 2**20:.0f} MiB",
                    hint="candidate_plans must filter through "
                         "plan_vmem_ok"))
    return diags


# ---------------------------------------------------------------------------
# Legalization fixed-point
# ---------------------------------------------------------------------------


def check_legalize_fixed_point(d_models=(1536, 2048, 4096, 7168, 18432),
                               eps=(1, 2, 4, 8, 16),
                               max_knob: int = 12) -> List[Diagnostic]:
    """legalize ∘ legalize == legalize over the knob grid: a legalized
    plan must be a fixed point, or the tuner's persisted knobs and the
    transport's executed knobs could disagree (PR 3's silent
    re-legalization bug, made impossible)."""
    from repro.core import adaptive as A
    diags: List[Diagnostic] = []
    for d_model in d_models:
        for ep in eps:
            for n_col in range(1, max_knob + 1):
                for rg in range(1, max_knob + 1):
                    p1 = A.legalize_plan(
                        A.Plan("comet", rg, n_col, "xla"), d_model, ep)
                    p2 = A.legalize_plan(p1, d_model, ep)
                    if p2 != p1:
                        diags.append(_d(
                            "legalize-not-fixed-point",
                            f"plan:d{d_model}:ep{ep}",
                            f"legalize({n_col},{rg}) -> "
                            f"({p1.n_col_blocks},{p1.ring_group}) -> "
                            f"({p2.n_col_blocks},{p2.ring_group}); "
                            "legalization must be idempotent"))
                    if (p1.n_col_blocks < 1 or d_model % p1.n_col_blocks
                            or p1.ring_group < 1
                            or max(1, ep) % p1.ring_group):
                        diags.append(_d(
                            "illegal-knob", f"plan:d{d_model}:ep{ep}",
                            f"legalized knobs ({p1.n_col_blocks},"
                            f"{p1.ring_group}) do not divide "
                            f"(d_model={d_model}, ep={ep})"))
    return diags
