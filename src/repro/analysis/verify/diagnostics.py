"""Shared diagnostic core for the verify passes.

A :class:`Diagnostic` is one finding: which pass produced it, how bad it
is, where it points (``file:line`` for source findings, ``graph:segment``
for IR findings), what is wrong and how to fix it. :class:`Report`
aggregates findings across passes and renders them as text or JSON (the
CI job consumes the JSON form).

Suppression: a source line may carry ``# verify: ignore[rule] -- why``.
The justification after ``--`` is REQUIRED — an ignore without one does
not suppress anything and is itself reported (rule ``bad-ignore``), so
every suppression in the tree documents its reason.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Tuple

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding."""
    passname: str                 # schedule | kernel | conventions
    rule: str                     # stable kebab-case rule id
    severity: str                 # error | warning
    location: str                 # file:line or graph:segment-name
    message: str                  # what is wrong
    hint: str = ""                # how to fix it

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        tail = f"  [fix: {self.hint}]" if self.hint else ""
        return (f"{self.location}: {self.severity}: "
                f"{self.passname}/{self.rule}: {self.message}{tail}")


class Report:
    """Ordered collection of diagnostics with text/JSON rendering."""

    def __init__(self, diags: Iterable[Diagnostic] = ()):
        self.diags: List[Diagnostic] = list(diags)

    def extend(self, diags: Iterable[Diagnostic]) -> "Report":
        self.diags.extend(diags)
        return self

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diags if d.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def text(self) -> str:
        if not self.diags:
            return "verify: clean (0 diagnostics)"
        lines = [str(d) for d in self.diags]
        ne = len(self.errors)
        lines.append(f"verify: {len(self.diags)} diagnostic"
                     f"{'s' if len(self.diags) != 1 else ''} "
                     f"({ne} error{'s' if ne != 1 else ''})")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "diagnostics": [d.to_json() for d in self.diags],
            "errors": len(self.errors),
            "ok": self.ok,
        }, indent=1)


# ---------------------------------------------------------------------------
# Ignore comments
# ---------------------------------------------------------------------------

_IGNORE_RE = re.compile(
    r"#\s*verify:\s*ignore\[([a-z0-9*-]+)\]\s*(?:--\s*(\S.*))?")


def parse_ignores(source: str) -> Tuple[Dict[int, Tuple[str, str]],
                                        List[Tuple[int, str]]]:
    """Scan ``source`` for ``# verify: ignore[rule] -- why`` comments.

    Returns ``(ignores, bad)``: ``ignores`` maps 1-based line number to
    ``(rule, justification)`` for well-formed suppressions (rule ``*``
    suppresses every rule on that line); ``bad`` lists ``(line, rule)``
    for ignores MISSING the justification — those suppress nothing and
    the linter reports them.
    """
    ignores: Dict[int, Tuple[str, str]] = {}
    bad: List[Tuple[int, str]] = []
    for i, line in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        rule, why = m.group(1), (m.group(2) or "").strip()
        if why:
            ignores[i] = (rule, why)
        else:
            bad.append((i, rule))
    return ignores, bad


def suppressed(ignores: Dict[int, Tuple[str, str]], line: int,
               rule: str) -> bool:
    ent = ignores.get(line)
    return ent is not None and ent[0] in ("*", rule)


def apply_ignores(diags: List[Diagnostic], path: str, source: str,
                  passname: str) -> List[Diagnostic]:
    """Filter ``diags`` (all pointing into ``path``) through the source's
    ignore comments, appending a ``bad-ignore`` diagnostic for every
    justification-less ignore."""
    ignores, bad = parse_ignores(source)
    out = []
    for d in diags:
        line = _line_of(d.location)
        if line is not None and suppressed(ignores, line, d.rule):
            continue
        out.append(d)
    for line, rule in bad:
        out.append(Diagnostic(
            passname, "bad-ignore", "error", f"{path}:{line}",
            f"ignore[{rule}] without a justification suppresses nothing",
            hint="write `# verify: ignore[rule] -- <why this is safe>`"))
    return out


def _line_of(location: str) -> Optional[int]:
    _, _, tail = location.rpartition(":")
    return int(tail) if tail.isdigit() else None
