"""comet-verify: the repo's static-analysis layer (PR 8).

Three passes over the things tests cannot enumerate:

* ``schedule_check`` — the schedule-IR race detector. Re-derives
  RAW/WAR/WAW hazards, ring send/recv pairing and wgrad-flush legality
  from scratch (never trusting the deps the scheduler was handed) and
  checks any proposed emission order against them.
* ``kernel_check`` — the Pallas resource checker. Computes the VMEM
  footprint each kernel's BlockSpecs imply, evaluates index maps over
  the full grid (out-of-bounds offsets, uncovered output tiles) and
  lints accumulation dtypes (bf16 inputs must accumulate in fp32).
* ``conventions`` — the AST convention linter enforcing the ROADMAP's
  durable rules: mesh entry points only via ``parallel/compat.py``, no
  mutable module globals on the hot path, no bare asserts in serving
  code, knob legalization only through the shared helpers.

All passes speak :class:`Diagnostic` and are driven by ``tools/verify.py``.
"""
from repro.analysis.verify.diagnostics import (Diagnostic, Report,
                                               parse_ignores)

__all__ = ["Diagnostic", "Report", "parse_ignores"]
