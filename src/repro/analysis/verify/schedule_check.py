"""Pass 1 — schedule-IR race detector.

The block-schedule IR (core/schedule.py) is only safe because every legal
emission order is a pure permutation over identical dataflow. This pass
re-establishes that claim INDEPENDENTLY: it never trusts the deps the
scheduler was handed (a lowering bug would poison both the order and the
check), but re-derives the hazard relation from first principles and then
checks any proposed order against it.

Two IR flavours, two derivations:

* **Executed segments** (``ExecSeg``, models/blocks.py) declare their
  dataflow as ``reads`` / ``writes`` value-name sets. The hazard relation
  (RAW / WAR / WAW) is recomputed here from those sets alone — a bug in
  ``exec_order``'s last-writer/reader bookkeeping cannot hide, because
  this module keeps its own.
* **Cost-IR segments** (``Segment``, ``lower_model_graph``) carry no
  read/write sets, but their names encode the comet-ring structure
  (``L{i}.s{j}.disp{m}`` / ``gemm{m}`` / ``comb{m}.{b}`` / ...). The
  checker re-derives the ring's precedence rules from that structure:
  recv-before-dependent-compute (every ``link_in`` hop lands before the
  GEMM that consumes it), send-after-produce, per-ring FIFO on each link
  direction (a ring's messages cannot overtake each other on one wire —
  the deadlock-freedom condition), completeness of every ring step, and
  floating ``wgrad_flush`` legality (after its producing GEMM, nothing
  ever depends on it).

``check_model_archs`` runs the standalone check over ``lower_model_graph``
outputs for every registered MoE arch; ``models/lm.forward_scheduled``
calls ``assert_exec_order_safe`` on every scheduled trace (debug
assertion, ``REPRO_VERIFY_SCHEDULE=0`` opts out).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.verify.diagnostics import Diagnostic

_PASS = "schedule"


def _d(rule: str, loc: str, msg: str, hint: str = "",
       severity: str = "error") -> Diagnostic:
    return Diagnostic(_PASS, rule, severity, loc, msg, hint)


# ---------------------------------------------------------------------------
# Executed path: hazards from reads/writes
# ---------------------------------------------------------------------------


def derive_exec_hazards(segs: Sequence) -> List[Tuple[int, int, str, str]]:
    """Re-derive every RAW/WAR/WAW hazard edge from the segments' declared
    ``reads``/``writes`` (program order = list order). Returns
    ``(before, after, kind, value)`` index pairs: ``before`` must be
    emitted before ``after`` in ANY legal order."""
    edges: List[Tuple[int, int, str, str]] = []
    last_writer: Dict[str, int] = {}
    readers_since: Dict[str, List[int]] = {}
    for i, s in enumerate(segs):
        for v in s.reads:
            if v in last_writer:
                edges.append((last_writer[v], i, "RAW", v))
        for v in s.writes:
            if v in last_writer:
                edges.append((last_writer[v], i, "WAW", v))
            for r in readers_since.get(v, ()):
                if r != i:
                    edges.append((r, i, "WAR", v))
        for v in s.reads:
            readers_since.setdefault(v, []).append(i)
        for v in s.writes:
            last_writer[v] = i
            readers_since[v] = []
    return edges


def check_exec_order(program: Sequence, ordered: Sequence) -> List[Diagnostic]:
    """Check a proposed emission order of executed segments against the
    independently re-derived hazard relation. ``program`` is the segment
    list in program order, ``ordered`` the order to be emitted; segments
    are matched by their unique ``.name``."""
    diags: List[Diagnostic] = []
    names = [s.name for s in program]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        return [_d("duplicate-name", "exec:program",
                   f"segment names not unique: {dup[:3]}",
                   hint="namespace executed values/segments per block")]
    pos = {s.name: i for i, s in enumerate(ordered)}
    missing = [n for n in names if n not in pos]
    extra = [getattr(s, "name", "?") for s in ordered
             if getattr(s, "name", None) not in set(names)]
    if missing or extra or len(ordered) != len(program):
        diags.append(_d(
            "not-a-permutation", "exec:order",
            f"order is not a permutation of the program "
            f"(missing {missing[:3]}, extra {extra[:3]}, "
            f"{len(ordered)} vs {len(program)} segments)",
            hint="every program segment must be emitted exactly once"))
        return diags
    idx = {i: s.name for i, s in enumerate(program)}
    for before, after, kind, value in derive_exec_hazards(program):
        if pos[idx[before]] >= pos[idx[after]]:
            diags.append(_d(
                f"{kind.lower()}-hazard", f"exec:{idx[after]}",
                f"{kind} hazard on {value!r}: {idx[before]!r} must be "
                f"emitted before {idx[after]!r}, order has it after",
                hint="the scheduler may only permute within the hazard "
                     "partial order"))
    return diags


def assert_exec_order_safe(program: Sequence, ordered: Sequence):
    """Debug assertion used by models/lm.forward_scheduled: raise if the
    scheduler emitted a hazard-violating order."""
    diags = check_exec_order(program, ordered)
    if diags:
        raise RuntimeError(
            "scheduled emission violates re-derived dataflow hazards:\n"
            + "\n".join(str(d) for d in diags[:5]))


# ---------------------------------------------------------------------------
# Cost IR: structural re-derivation of the comet-ring rules
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(
    r"^L(?P<block>\d+)\.s(?P<slice>\d+)\."
    r"(?P<op>attn_bwd|attn|router|disp|gemm|comb|dyhop|bgemm|dxhop|flush)"
    r"(?P<m>\d+)?(?:\.(?P<b>\d+))?$")

# resource each structural op must occupy (deadlock-freedom starts with
# hops being on the link direction their peer expects)
_OP_RESOURCE = {
    "attn": "compute", "router": "compute", "gemm": "compute",
    "bgemm": "compute", "attn_bwd": "compute", "flush": "compute",
    "disp": "link_in", "dyhop": "link_in",
    "comb": "link_out", "dxhop": "link_out",
}


def _parse(name: str) -> Optional[Dict]:
    m = _NAME_RE.match(name)
    if not m:
        return None
    g = m.groupdict()
    return {"block": int(g["block"]), "slice": int(g["slice"]),
            "op": g["op"],
            "m": int(g["m"]) if g["m"] is not None else None,
            "b": int(g["b"]) if g["b"] is not None else None}


def check_graph_order(g, order: Sequence[int],
                      expect: Optional[Dict] = None) -> List[Diagnostic]:
    """Check a proposed order of a ``lower_model_graph`` ScheduleGraph.

    Everything is re-derived from segment NAMES and kinds — the declared
    ``deps`` are never consulted, so a lowering that dropped an edge and a
    scheduler that exploited the hole are both caught. ``expect`` may pin
    the ring geometry: ``{"n_steps": int, "n_col": int}`` (otherwise both
    are inferred from the observed indices, which still catches interior
    holes and ordering bugs, just not a uniformly truncated ring).
    """
    diags: List[Diagnostic] = []
    n = len(g.segments)
    if sorted(order) != list(range(n)):
        return [_d("not-a-permutation", "graph:order",
                   f"order is not a permutation of 0..{n - 1}")]
    pos = {sid: i for i, sid in enumerate(order)}

    # group parsed segments by (block, slice)
    rings: Dict[Tuple[int, int], Dict] = {}
    for s in g.segments:
        p = _parse(s.name)
        if p is None:
            diags.append(_d("unknown-segment", f"graph:{s.name}",
                            "segment name does not match the lowering's "
                            "naming scheme; structural checks skipped",
                            severity="warning"))
            continue
        want = _OP_RESOURCE[p["op"]]
        if s.resource != want:
            diags.append(_d(
                "wrong-resource", f"graph:{s.name}",
                f"{p['op']} segment on resource {s.resource!r}, expected "
                f"{want!r}",
                hint="dispatch/dY ride link_in, combine/dX ride link_out; "
                     "a hop on the wrong direction deadlocks its peer"))
        ring = rings.setdefault((p["block"], p["slice"]), {})
        ring.setdefault(p["op"], {})[(p["m"], p["b"])] = s.sid

    def before(a: int, b: int, rule: str, why: str, hint: str = ""):
        if pos[a] >= pos[b]:
            diags.append(_d(rule,
                            f"graph:{g.segments[b].name}",
                            f"{g.segments[a].name} must precede "
                            f"{g.segments[b].name}: {why}", hint))

    for (blk, sl), ring in sorted(rings.items()):
        loc = f"graph:L{blk}.s{sl}"
        gemms = ring.get("gemm", {})
        disps = ring.get("disp", {})
        combs = ring.get("comb", {})
        if not gemms:
            continue
        n_steps = (expect["n_steps"] if expect
                   else max(m for m, _ in gemms) + 1)
        n_col = (expect["n_col"] if expect
                 else (max(b for _, b in combs) + 1 if combs else 1))
        # ring completeness: every step's recv / compute / sends exist
        for m in range(n_steps):
            if (m, None) not in gemms:
                diags.append(_d("missing-segment", loc,
                                f"ring step {m} has no expert_gemm",
                                hint="lowering dropped a macro-step"))
            if m > 0 and (m, None) not in disps:
                diags.append(_d(
                    "missing-segment", loc,
                    f"ring step {m} has no dispatch hop: its GEMM would "
                    f"consume a chunk that never arrives",
                    hint="every remote macro-step needs its link_in recv"))
            for b in range(n_col):
                if (m, b) not in combs:
                    diags.append(_d(
                        "missing-segment", loc,
                        f"ring step {m} column block {b} has no combine "
                        f"hop: that output tile is never returned"))
        if "attn" in ring and "router" in ring and (0, None) in gemms:
            a = ring["attn"][(None, None)]
            r = ring["router"][(None, None)]
            before(a, r, "raw-hazard", "router reads attention output")
            before(r, gemms[(0, None)], "raw-hazard",
                   "the first macro-step consumes the local dispatch "
                   "buffer the router built")
        for m in range(n_steps):
            if (m, None) not in gemms:
                continue
            e = gemms[(m, None)]
            if m > 0 and (m, None) in disps:
                before(disps[(m, None)], e, "recv-before-compute",
                       f"GEMM {m} consumes the chunk dispatch hop {m} "
                       f"delivers",
                       hint="a compute issued before its recv deadlocks "
                            "the in-order queues")
            if m > 0 and (m - 1, None) in gemms:
                before(gemms[(m - 1, None)], e, "ring-order",
                       "macro-steps share the compute resource in ring "
                       "order")
            for b in range(n_col):
                if (m, b) in combs:
                    before(e, combs[(m, b)], "send-after-produce",
                           f"combine {m}.{b} returns a column block GEMM "
                           f"{m} produces")
        # the TRUE cross-layer dependency: attn of block i+1 (slice j)
        # waits for the LAST combine of block i in the same slice
        prev_ring = rings.get((blk - 1, sl))
        if prev_ring and "attn" in ring:
            a = ring["attn"][(None, None)]
            for (m, b), sid in prev_ring.get("comb", {}).items():
                before(sid, a, "raw-hazard",
                       f"block {blk} attention reads block {blk - 1}'s "
                       f"combined output (slice {sl})")
        # per-link FIFO: one ring's messages cannot overtake on one wire
        for opname, hops in (("disp", disps), ("comb", combs)):
            def step_span(mm):
                ps = [pos[sid] for (m, b), sid in hops.items() if m == mm]
                return (min(ps), max(ps)) if ps else None
            spans = [(mm, step_span(mm)) for mm in range(n_steps)]
            prev = None
            for mm, span in spans:
                if span is None:
                    continue
                if prev is not None and span[0] <= prev[1][1]:
                    diags.append(_d(
                        "link-fifo", loc,
                        f"{opname} hops of step {mm} emitted before step "
                        f"{prev[0]} finished its sends: ring messages "
                        f"would overtake on one wire",
                        hint="FIFO per (ring, direction) is the deadlock-"
                             "freedom condition"))
                prev = (mm, span)
        # backward chain (training lowerings)
        dyh = ring.get("dyhop", {})
        bgs = ring.get("bgemm", {})
        dxh = ring.get("dxhop", {})
        fls = ring.get("flush", {})
        for m in range(max((m for m, _ in bgs), default=-1) + 1):
            if (m, None) not in bgs:
                diags.append(_d("missing-segment", loc,
                                f"backward step {m} has no ring_bwd_gemm"))
                continue
            bg = bgs[(m, None)]
            if (m, None) in dyh:
                before(dyh[(m, None)], bg, "recv-before-compute",
                       f"bgemm {m} consumes the dY chunk dyhop {m} "
                       f"delivers")
            else:
                diags.append(_d("missing-segment", loc,
                                f"backward step {m} has no dY hop"))
            if (m, None) in dxh:
                before(bg, dxh[(m, None)], "send-after-produce",
                       f"dxhop {m} returns the dX chunk bgemm {m} "
                       f"produces")
            else:
                diags.append(_d("missing-segment", loc,
                                f"backward step {m} has no dX hop"))
            if (m, None) in fls:
                before(bg, fls[(m, None)], "flush-before-producer",
                       f"wgrad flush {m} drains the fp32 accumulator "
                       f"bgemm {m} fills")
            if m > 0 and (m - 1, None) in bgs:
                before(bgs[(m - 1, None)], bg, "ring-order",
                       "backward macro-steps run in ring order")

    # floating wgrad_flush legality: NOTHING may depend on a flush — the
    # whole point is that the scheduler can sink it into any later bubble
    flush_sids = {s.sid for s in g.segments if s.kind == "wgrad_flush"}
    if flush_sids:
        for s in g.segments:
            bad = flush_sids.intersection(s.deps)
            if bad:
                diags.append(_d(
                    "flush-has-dependent", f"graph:{s.name}",
                    f"{s.name} depends on wgrad_flush sid(s) "
                    f"{sorted(bad)}: flushes must float freely",
                    hint="read the dW accumulator via the optimizer "
                         "step, not a graph edge"))
    return diags


def check_lowered(hw, s, plan, *, d_model: int, n_blocks: int = 2,
                  n_slices: int = 1, training: bool = False
                  ) -> List[Diagnostic]:
    """Lower one model graph, schedule it, and run the structural check
    with the ring geometry pinned from (s, plan)."""
    from repro.core.schedule import (comet_ring_counts, lower_model_graph,
                                     overlap_order)
    g = lower_model_graph(hw, s, plan, d_model=d_model, n_blocks=n_blocks,
                          n_slices=n_slices, training=training)
    cnt = comet_ring_counts(s.ep, max(1, plan.ring_group),
                            max(1, plan.n_col_blocks))
    expect = {"n_steps": cnt["n_steps"],
              "n_col": max(1, plan.n_col_blocks)}
    return check_graph_order(g, overlap_order(g), expect=expect)


def check_model_archs(hw=None, tokens: int = 4096) -> List[Diagnostic]:
    """Standalone pass: lower + schedule + check every registered MoE arch
    (fwd and fwd+bwd, sliced and unsliced). Dense/SSM archs have no comet
    ring to lower and are skipped."""
    from repro.configs.base import get_config, list_archs
    from repro.core import adaptive as A

    hw = hw or A.TPU_V5E
    diags: List[Diagnostic] = []
    for name in list_archs():
        cfg = get_config(name)
        if cfg.moe is None:
            continue
        ep = min(8, cfg.moe.num_experts)
        s = A.plan_shape(cfg.moe, cfg.d_model, tokens, ep, 1)
        plans = [A.legalize_plan(
            A.Plan("comet", ring_group=2, n_col_blocks=4,
                   gemm_impl="pallas_fused", fused_combine=True),
            s.N, s.ep)]
        # the hierarchical ring lowers to the same segment graph with
        # per-class hop costs — sweep it on the asymmetric preset so the
        # race detector covers comet_hier's schedules too
        plans.append(A.legalize_plan(
            A.Plan("comet_hier", ring_group=2, n_col_blocks=4,
                   gemm_impl="pallas_fused", fused_combine=True,
                   intra_group=4, wire_dtype="bf16"),
            s.N, s.ep))
        hw_for = {"comet_hier": A.H100_CROSSNODE}
        for plan in plans:
            for training in (False, True):
                for ns in (1, 2):
                    for d in check_lowered(hw_for.get(plan.impl, hw), s,
                                           plan, d_model=cfg.d_model,
                                           n_blocks=2, n_slices=ns,
                                           training=training):
                        diags.append(Diagnostic(
                            d.passname, d.rule, d.severity,
                            f"{name}[{plan.impl},ns={ns},"
                            f"bwd={int(training)}]:{d.location}",
                            d.message, d.hint))
    return diags
