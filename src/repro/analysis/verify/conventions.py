"""Pass 3 — hot-path convention linter (AST-based).

Enforces the ROADMAP's durable conventions, the ones that decay silently
because nothing crashes when they're broken:

* ``mesh-entry`` — mesh/SPMD entry points (``shard_map``, ``use_mesh``,
  ``set_mesh``, ``make_mesh``, the ``Mesh(...)`` constructor) may only be
  touched in ``parallel/compat.py``; everything else routes through the
  compat shims so a JAX version bump is a one-file change. Importing the
  ``Mesh`` *type* for annotations is fine — constructing or activating
  one is not.
* ``mutable-global`` — no module-level mutable accumulators (``{}``,
  ``[]``, ``dict()``, …) and no ``global`` statements in the hot-path
  packages (``core/``, ``kernels/``, ``models/``, ``serving/``): they
  leak state across jit traces and tests. Use ``functools.lru_cache`` or
  pass state explicitly. Non-empty literal tables are constants and
  allowed.
* ``serving-assert`` — no ``assert`` in ``serving/``: the serving loop is
  run with ``python -O`` in some deployments and an assert-guarded
  invariant silently vanishes. Raise a real exception.
* ``knob-legalize`` — no inline ``% n_col`` / ``% ring_group``
  divisibility math outside ``core/adaptive.py``; plan knobs round-trip
  through ``legalize_n_col`` / ``legalize_ring_group`` / ``legalize_plan``
  so every consumer agrees on the clamping rules.

Suppression: ``# verify: ignore[rule] -- why`` on the offending line
(the justification is mandatory; see ``diagnostics.apply_ignores``).
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional

from repro.analysis.verify.diagnostics import Diagnostic, apply_ignores

_PASS = "conventions"

COMPAT_FILE = "parallel/compat.py"
HOT_DIRS = ("core/", "kernels/", "models/", "serving/")
SERVING_DIRS = ("serving/",)

_MESH_ENTRY_NAMES = {"shard_map", "use_mesh", "set_mesh", "make_mesh"}
_MESH_MODULES = ("jax", "jax.sharding", "jax.experimental",
                 "jax.experimental.shard_map", "jax.experimental.mesh_utils")
_KNOB_FRAGMENTS = ("n_col", "ring_group", "intra_group")
_MUTABLE_CALLS = {"dict", "list", "set", "defaultdict", "OrderedDict",
                  "deque", "Counter"}


def _d(rule: str, path: str, line: int, msg: str,
       hint: str = "") -> Diagnostic:
    return Diagnostic(_PASS, rule, "error", f"{path}:{line}", msg, hint)


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.sharding.use_mesh' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_empty_mutable(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.Set)) and not getattr(
            node, "keys", getattr(node, "elts", None)):
        return True
    if isinstance(node, ast.Call) and not node.args and not node.keywords:
        name = _dotted(node.func) or ""
        return name.split(".")[-1] in _MUTABLE_CALLS
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.diags: List[Diagnostic] = []
        self.is_compat = relpath.endswith(COMPAT_FILE)
        self.is_hot = any(f"/{d}" in f"/{relpath}" for d in HOT_DIRS)
        self.is_serving = any(f"/{d}" in f"/{relpath}"
                              for d in SERVING_DIRS)
        # core/adaptive.py OWNS legalization; analysis/verify/ CHECKS it —
        # both must be allowed to do the divisibility math everyone else
        # delegates
        self.is_adaptive = (relpath.endswith("core/adaptive.py")
                            or "analysis/verify/" in relpath)
        self._depth = 0                      # >0 inside a def/class

    # -- mesh-entry ---------------------------------------------------

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        if not self.is_compat and mod.startswith("jax"):
            if "shard_map" in mod:
                self.diags.append(_d(
                    "mesh-entry", self.relpath, node.lineno,
                    f"import from '{mod}' outside {COMPAT_FILE}",
                    hint="use repro.parallel.compat.shard_map"))
            else:
                for a in node.names:
                    if a.name in _MESH_ENTRY_NAMES:
                        self.diags.append(_d(
                            "mesh-entry", self.relpath, node.lineno,
                            f"'{a.name}' imported from '{mod}' outside "
                            f"{COMPAT_FILE}",
                            hint=f"use repro.parallel.compat.{a.name}"))
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if not self.is_compat:
            name = _dotted(node)
            if name and name.startswith("jax") \
                    and name.split(".")[-1] in _MESH_ENTRY_NAMES:
                self.diags.append(_d(
                    "mesh-entry", self.relpath, node.lineno,
                    f"'{name}' referenced outside {COMPAT_FILE}",
                    hint="route through repro.parallel.compat"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if not self.is_compat:
            name = _dotted(node.func) or ""
            if name.split(".")[-1] == "Mesh":
                self.diags.append(_d(
                    "mesh-entry", self.relpath, node.lineno,
                    f"direct Mesh construction ('{name}(...)') outside "
                    f"{COMPAT_FILE}",
                    hint="use repro.parallel.compat.make_mesh"))
        self.generic_visit(node)

    # -- mutable-global -----------------------------------------------

    def _check_module_assign(self, node, value):
        if self.is_hot and self._depth == 0 and value is not None \
                and _is_empty_mutable(value):
            self.diags.append(_d(
                "mutable-global", self.relpath, node.lineno,
                "module-level mutable accumulator in a hot-path module",
                hint="use functools.lru_cache or thread state through "
                     "call arguments"))

    def visit_Assign(self, node: ast.Assign):
        self._check_module_assign(node, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self._check_module_assign(node, node.value)
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global):
        if self.is_hot:
            self.diags.append(_d(
                "mutable-global", self.relpath, node.lineno,
                f"'global {', '.join(node.names)}' in a hot-path module",
                hint="module globals leak across jit traces; use "
                     "functools.lru_cache or explicit state"))
        self.generic_visit(node)

    # -- serving-assert -----------------------------------------------

    def visit_Assert(self, node: ast.Assert):
        if self.is_serving:
            self.diags.append(_d(
                "serving-assert", self.relpath, node.lineno,
                "bare assert in serving code (stripped under python -O)",
                hint="raise ValueError/RuntimeError so the invariant "
                     "survives optimized runs"))
        self.generic_visit(node)

    # -- knob-legalize ------------------------------------------------

    def visit_BinOp(self, node: ast.BinOp):
        if not self.is_adaptive and isinstance(node.op, ast.Mod):
            for side in (node.left, node.right):
                name = _dotted(side) or ""
                if any(f in name for f in _KNOB_FRAGMENTS):
                    self.diags.append(_d(
                        "knob-legalize", self.relpath, node.lineno,
                        f"inline divisibility math on '{name}' outside "
                        "core/adaptive.py",
                        hint="call legalize_n_col/legalize_ring_group/"
                             "legalize_intra_group/legalize_plan instead"))
                    break
        self.generic_visit(node)

    # -- scope tracking -----------------------------------------------

    def _scoped(self, node):
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped
    visit_ClassDef = _scoped
    visit_Lambda = _scoped


def lint_source(relpath: str, source: str) -> List[Diagnostic]:
    """Lint one module; returns diagnostics surviving the source's
    ``# verify: ignore[...]`` comments (plus ``bad-ignore`` findings)."""
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as e:
        return [_d("syntax-error", relpath, e.lineno or 0,
                   f"cannot parse: {e.msg}")]
    linter = _Linter(relpath)
    linter.visit(tree)
    return apply_ignores(linter.diags, relpath, source, _PASS)


def lint_tree(root: str) -> List[Diagnostic]:
    """Lint every ``.py`` under ``root`` (the repo's ``src/repro``)."""
    diags: List[Diagnostic] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                diags.extend(lint_source(rel, f.read()))
    return diags
