"""Roofline analysis from compiled XLA artifacts (no hardware required).

Three terms per (arch × shape × mesh), all in seconds-per-step per device:

  compute    = HLO_FLOPs / peak_FLOP/s          (cost_analysis, per-device)
  memory     = HLO_bytes / HBM_bw               (cost_analysis, per-device)
  collective = ici_bytes / link_bw              (parsed from compiled HLO)

``cost_analysis()`` does not report collective traffic, so ``ici_bytes`` is
reconstructed by walking the post-SPMD HLO text: every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute contributes its
operand bytes × the ring-traffic factor for its replica-group size. Shapes in
the per-device module are already shard-local, so the sum is per-device
traffic directly.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e per-chip constants (assignment-mandated)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link (conservative, 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<res>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start)?\s*\((?P<operands>[^)]*)\)")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] token in a shape/operand string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # collective-permute / unknown: factor computed separately


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, float]            # op kind -> per-device bytes
    counts: Dict[str, int]
    total_bytes: float

    def dominant(self) -> str:
        if not self.per_op:
            return "none"
        return max(self.per_op, key=self.per_op.get)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    per_op: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        operand_bytes = shape_bytes(m.group("operands"))
        if operand_bytes == 0:
            continue
        n = _group_size(line)
        if op == "all-reduce":
            traffic = 2.0 * (n - 1) / n * operand_bytes
        elif op == "all-gather":
            traffic = (n - 1) * operand_bytes           # operand = local shard
        elif op == "reduce-scatter":
            traffic = (n - 1) / n * operand_bytes
        elif op == "all-to-all":
            traffic = (n - 1) / n * operand_bytes
        else:  # collective-permute: one hop, operand bytes
            traffic = float(operand_bytes)
        per_op[op] = per_op.get(op, 0.0) + traffic
        counts[op] = counts.get(op, 0) + 1
    return CollectiveStats(per_op, counts, sum(per_op.values()))


# ---------------------------------------------------------------------------
# Full report for one compiled step
# ---------------------------------------------------------------------------


def flash_kernel_bytes(cfg, shape) -> float:
    """Analytic GLOBAL HBM boundary traffic of the attention regions tagged
    ``__fusable__flash`` (whose internals the HLO byte count skips — on the
    TPU target they run as the Pallas flash kernel with scores in VMEM).

    Per attention layer, fwd = read q + read k,v + write o; train adds the
    remat re-forward (×1) and the backward (reads q,k,v,o,do; writes
    dq,dk,dv ≈ ×2 fwd), total ×4. Decode reads the full KV cache per token.
    """
    if cfg.attn is None:
        return 0.0
    a = cfg.attn
    dt = 2 if "16" in cfg.compute_dtype else 4
    Bsz, S = shape.global_batch, shape.seq_len

    def layer_io(Tq, Tk):
        return dt * (2 * Tq * a.n_heads * a.head_dim
                     + 2 * Tk * a.n_kv_heads * a.head_dim)

    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "a")
    factor = 4.0 if shape.kind == "train" else 1.0
    if shape.kind == "decode":
        # q/o negligible; read whole cache per token per layer
        per_layer = dt * 2 * Bsz * S * a.n_kv_heads * a.head_dim
        total = n_attn * per_layer
        if cfg.n_enc_layers:
            total += cfg.n_layers * dt * 2 * Bsz * 4096 * a.n_kv_heads * a.head_dim
        return total
    total = n_attn * layer_io(Bsz * S, Bsz * S) * factor
    if cfg.n_enc_layers:                      # whisper: encoder + cross attn
        Sd = max(64, S // 4)
        total = cfg.n_layers * layer_io(Bsz * Sd, Bsz * Sd) * factor     # dec self
        total += cfg.n_layers * layer_io(Bsz * Sd, Bsz * S) * factor    # cross
        total += cfg.n_enc_layers * layer_io(Bsz * S, Bsz * S) * factor  # enc
    return total


def ssd_kernel_bytes(cfg, shape) -> float:
    """Analytic GLOBAL boundary traffic of ``__fusable__ssd`` regions (the
    Pallas SSD kernel: read x, dt, B, C; write y; chunk internals in VMEM).
    Same train ×4 factor (fwd + remat + bwd≈2) as the flash model."""
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    dt = 2 if "16" in cfg.compute_dtype else 4
    Bsz, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return 0.0                      # decode path is the O(1) recurrence
    d_in = s.expand * cfg.d_model
    n_ssm = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "m")
    per_layer = dt * Bsz * S * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
    factor = 4.0 if shape.kind == "train" else 1.0
    return n_ssm * per_layer * factor


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train; 2·N_active·D for inference."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(compiled, n_chips: int, cfg=None, shape=None,
            hlo_text: Optional[str] = None) -> Dict:
    """Roofline terms from the compiled artifact.

    ``cost_analysis()`` counts while-loop (scan) bodies once, so FLOPs/bytes
    are re-derived by the HLO static cost model (analysis/hlo_cost.py) with
    correct trip-count multiplicities; ``cost_analysis`` numbers are kept in
    the report for reference as ``xla_*``.
    """
    from repro.analysis.hlo_cost import analyze_text
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    cost = analyze_text(hlo)
    flops = cost.flops
    bytes_accessed = cost.bytes
    fkb = 0.0
    if cfg is not None and shape is not None:
        fkb = (flash_kernel_bytes(cfg, shape)
               + ssd_kernel_bytes(cfg, shape)) / max(1, n_chips)
        bytes_accessed += fkb
    coll = CollectiveStats(dict(cost.coll_per_op),
                           {k: int(v) for k, v in cost.coll_counts.items()},
                           cost.ici_bytes)

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll.total_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_info[attr] = int(v)

    report = {
        "n_chips": n_chips,
        "hlo_flops_per_device": flops,
        "hlo_mxu_flops_per_device": cost.mxu_flops,
        "hlo_bytes_per_device": bytes_accessed,
        "flash_kernel_bytes_per_device": fkb,
        "xla_flops_per_device": xla_flops,
        "xla_bytes_per_device": xla_bytes,
        "collective_bytes_per_device": coll.total_bytes,
        "collective_per_op": coll.per_op,
        "collective_counts": coll.counts,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_time_s": max(terms.values()),
        "memory_analysis": mem_info,
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        report["model_flops_global"] = mf
        report["model_flops_per_device"] = mf / n_chips
        report["useful_flops_ratio"] = (mf / n_chips) / max(flops, 1.0)
        # roofline fraction: useful model FLOPs per device over peak, relative
        # to the step's bound time — "how close to roofline the step runs"
        report["roofline_fraction"] = (
            (mf / n_chips / PEAK_FLOPS) / max(max(terms.values()), 1e-30))
    return report


def fmt_report(name: str, r: Dict) -> str:
    lines = [f"== {name} ==",
             f"  chips={r['n_chips']} "
             f"FLOPs/dev={r['hlo_flops_per_device']:.3e} "
             f"bytes/dev={r['hlo_bytes_per_device']:.3e} "
             f"ici/dev={r['collective_bytes_per_device']:.3e}",
             f"  t_compute={r['t_compute_s']*1e3:.2f}ms "
             f"t_memory={r['t_memory_s']*1e3:.2f}ms "
             f"t_collective={r['t_collective_s']*1e3:.2f}ms "
             f"-> dominant: {r['dominant']}"]
    if "useful_flops_ratio" in r:
        lines.append(f"  model/HLO flops={r['useful_flops_ratio']:.3f} "
                     f"roofline_fraction={r['roofline_fraction']:.3f}")
    if r.get("collective_per_op"):
        per = ", ".join(f"{k}:{v/1e6:.1f}MB×{r['collective_counts'][k]}"
                        for k, v in sorted(r["collective_per_op"].items()))
        lines.append(f"  collectives: {per}")
    tm = r.get("memory_analysis", {})
    if tm:
        lines.append(
            "  mem/dev: args={:.2f}GB out={:.2f}GB temp={:.2f}GB".format(
                tm.get("argument_size_in_bytes", 0) / 2**30,
                tm.get("output_size_in_bytes", 0) / 2**30,
                tm.get("temp_size_in_bytes", 0) / 2**30))
    return "\n".join(lines)
