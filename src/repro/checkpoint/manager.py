"""Async atomic checkpointing with restore-time resharding.

Layout: ``<dir>/step_<k>/`` holding one ``.npy`` per leaf (path-keyed) plus a
``manifest.json`` (treedef, shapes, dtypes, step, mesh shape). A checkpoint is
*committed* by the atomic rename of ``step_<k>.tmp`` → ``step_<k>``; readers
never observe partial state. Saves run on a background thread (device→host
transfer happens on the caller thread — cheap relative to serialization — and
the file I/O overlaps the next training steps, the standard TPU-fleet
pattern). Restore accepts a different mesh than the one that saved: leaves are
loaded as full host arrays and re-placed via ``jax.device_put`` with the new
sharding (elastic-rescale path).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _fname(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Pytree, wait: bool = False,
             extra: Optional[Dict] = None):
        """Snapshot to host, then write+commit (async unless wait=True).
        ``extra`` is an optional JSON-serializable blob committed inside the
        same atomic rename as the array leaves (the serving engine stores
        its scheduler state here, so scheduler + cache can never be torn)."""
        self.wait()                       # one in-flight save at a time
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        flat = _flatten(state)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]

        def work():
            try:
                self._write(step, host, extra)
            except BaseException as e:    # surfaced on next save()/wait()
                self._error = e

        if self.async_save and not wait:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]],
               extra: Optional[Dict] = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        if extra is not None:
            manifest["extra"] = extra
        for key, arr in host:
            np.save(os.path.join(tmp, _fname(key)), arr)
            manifest["leaves"].append(
                {"key": key, "file": _fname(key),
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # commit point
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_extra(self, step: Optional[int] = None) -> Optional[Dict]:
        """The ``extra`` blob committed with ``save(..., extra=)``, or None."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f).get("extra")

    def restore(self, target: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Tuple[Pytree, int]:
        """target: pytree of arrays or ShapeDtypeStructs giving the structure.
        shardings: optional matching pytree of NamedShardings (resharding onto
        a possibly different mesh). Returns (state, step)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {l["key"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, sh_leaves):
            key = _SEP.join(_path_str(p) for p in path)
            if key not in by_key:
                raise KeyError(f"checkpoint {d} missing leaf {key!r}")
            arr = np.load(os.path.join(d, by_key[key]["file"]))
            rec_dt = np.dtype(jax.numpy.dtype(by_key[key]["dtype"]))
            if arr.dtype.kind == "V" and arr.dtype != rec_dt:
                arr = arr.view(rec_dt)    # np.load drops extension dtypes
            want_dt = np.dtype(jax.numpy.dtype(leaf.dtype))
            if arr.dtype != want_dt:
                arr = arr.astype(want_dt)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"leaf {key}: ckpt shape {arr.shape} != "
                                 f"target {leaf.shape}")
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
