"""AdamW with global-norm clipping and cosine schedule — pure JAX pytrees."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(1, warmup))
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


@dataclass(frozen=True)
class AdamW:
    lr: Callable = cosine_schedule(3e-4, 100, 10000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Pytree) -> Dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: Pytree, state: Dict, params: Pytree
               ) -> Tuple[Pytree, Dict, Dict]:
        count = state["count"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = self.lr(count)
        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * g * g
            mh = m_new / c1
            vh = v_new / c2
            step = mh / (jnp.sqrt(vh) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return p_new.astype(p.dtype), m_new, v_new

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_v = jax.tree_util.tree_leaves(state["v"])
        outs = [upd(p, g, m, v) for p, g, m, v in
                zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_state = {
            "m": jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs]),
            "v": jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs]),
            "count": count,
        }
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
