"""Int8 gradient compression with error feedback (distributed-optimization
substrate for the DP all-reduce at 1000+-node scale).

The DP gradient all-reduce crosses DCN between pods; int8 quantization cuts
that traffic 4× (bf16→int8 is 2×, fp32 accum→int8 is 4×). Error feedback
(residual carried to the next step) keeps SGD/Adam convergence — the standard
1-bit-Adam / Optimus-CC result, cited as [34] in the paper's related work.

Usage inside a jitted step::

    q, scale, new_resid = compress(grad + resid)
    q_sum = lax.psum(q.astype(jnp.int32), "pod")     # int32 accumulate
    grad_hat = dequantize(q_sum, psum(scale)) / n_pods

``compress_pytree``/``decompress_pytree`` wrap whole gradient trees and
``allreduce_compressed`` is the pod-axis reduction. Status: validated at
unit level (error-feedback telescoping identity, quantization bound —
tests/test_optim.py). Wiring into the jitted train step requires computing
per-pod partial gradients under ``shard_map`` over the "pod" axis (so the
partitioner does not insert its own full-precision reduce first); that
integration is documented here and left explicit rather than silently
claimed.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8. Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jnp.ndarray, resid: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q, scale, new_resid). new_resid = (grad+resid) - dequant(q)."""
    g = grad.astype(jnp.float32) + resid
    q, scale = quantize_int8(g)
    new_resid = g - dequantize_int8(q, scale)
    return q, scale, new_resid


def init_residuals(grads: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_pytree(grads: Pytree, resids: Pytree):
    """Returns ({'q','scale'} trees, new resids)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(resids)
    qs, ss, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress_with_feedback(g, r)
        qs.append(q); ss.append(s); rs.append(nr)
    unf = lambda l: jax.tree_util.tree_unflatten(tdef, l)
    return {"q": unf(qs), "scale": unf(ss)}, unf(rs)


def decompress_pytree(packed: Dict) -> Pytree:
    return jax.tree_util.tree_map(dequantize_int8, packed["q"], packed["scale"])


def allreduce_compressed(grads: Pytree, resids: Pytree, axis: str):
    """DP-axis all-reduce of int8-compressed grads with error feedback.
    Quantized payload is summed in int32 (exact), then dequantized with the
    max scale — each participant's contribution is within one quantum."""
    packed, new_resids = compress_pytree(grads, resids)
    n = jax.lax.psum(1, axis)

    def reduce_one(q, s):
        s_max = jax.lax.pmax(s, axis)
        # requantize to the common scale so the int32 sum is coherent
        q_common = jnp.clip(jnp.round(q.astype(jnp.float32) * (s / s_max)),
                            -127, 127).astype(jnp.int32)
        tot = jax.lax.psum(q_common, axis)
        return tot.astype(jnp.float32) * s_max / n

    out = jax.tree_util.tree_map(reduce_one, packed["q"], packed["scale"])
    return out, new_resids
