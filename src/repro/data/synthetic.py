"""Deterministic synthetic data pipeline with background prefetch.

Every batch is a pure function of (seed, step) — restart-safe: resuming from a
checkpoint at step k regenerates exactly the batches k, k+1, … that a failed
run would have seen. Sharded per process via (process_index, process_count).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.specs import WHISPER_DEC_RATIO


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, shape_structs: Dict[str, Any],
                 seed: int = 0, process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.structs = shape_structs
        self.seed = seed
        self.pidx = process_index
        self.pcount = process_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.pidx]))
        out: Dict[str, np.ndarray] = {}
        if "tokens" in self.structs:
            # correlated stream so models actually learn: labels = next token
            shape = tuple(self.structs["tokens"].shape)
            stream = self._markov(rng, shape, self.cfg.vocab_size)
            out["tokens"] = stream
            if "labels" in self.structs:
                lab = np.roll(stream, -1, axis=-1)
                lab[..., -1] = 0
                out["labels"] = lab
        elif "labels" in self.structs:                # vlm: embeds + labels
            shape = tuple(self.structs["labels"].shape)
            out["labels"] = rng.integers(0, self.cfg.vocab_size, size=shape,
                                         dtype=np.int32)
        for name in ("embeds", "frames"):
            if name in self.structs:
                shape = tuple(self.structs[name].shape)
                out[name] = rng.standard_normal(shape).astype(np.float32) * 0.02
        return out

    @staticmethod
    def _markov(rng, shape, vocab):
        """Cheap learnable structure: x[t+1] = (a*x[t] + b + noise) % vocab."""
        x = rng.integers(0, vocab, size=shape[:-1] + (1,), dtype=np.int64)
        seq = [x]
        a, b = 31, 17
        for _ in range(shape[-1] - 1):
            nxt = (a * seq[-1] + b + rng.integers(0, 3, size=x.shape)) % vocab
            seq.append(nxt)
        return np.concatenate(seq, axis=-1).astype(np.int32)


class Prefetcher:
    """Background-thread prefetch: overlaps host batch synthesis with device
    compute (the data-pipeline half of compute/comm overlap)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.source.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
