"""GQA attention: chunked online-softmax (flash-style, pure jnp), decode w/ KV
cache, cross-attention. The chunked path keeps activation memory O(S) so the
32k prefill cells lower without a (S, S) score tensor.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDecl, apply_rope

NEG_INF = -1e30
NULL_PAGE = 0          # paged KV: page id 0 is reserved, never allocated


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def attn_schema(cfg, a, cross: bool = False) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    s = {
        "wq": ParamDecl((d, a.n_heads * a.head_dim), ("embed", "qheads")),
        "wk": ParamDecl((d, a.n_kv_heads * a.head_dim), ("embed", "kvheads")),
        "wv": ParamDecl((d, a.n_kv_heads * a.head_dim), ("embed", "kvheads")),
        "wo": ParamDecl((a.n_heads * a.head_dim, d), ("qheads", "embed")),
    }
    if a.qkv_bias:
        s["bq"] = ParamDecl((a.n_heads * a.head_dim,), ("qheads",), "zeros")
        s["bk"] = ParamDecl((a.n_kv_heads * a.head_dim,), ("kvheads",), "zeros")
        s["bv"] = ParamDecl((a.n_kv_heads * a.head_dim,), ("kvheads",), "zeros")
    return s


def qkv(p, a, x, positions=None, rope: bool = True):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"].astype(q.dtype), k + p["bk"].astype(k.dtype), v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, a.n_heads, a.head_dim)
    k = k.reshape(B, S, a.n_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.n_kv_heads, a.head_dim)
    if rope and positions is not None:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _expand_kv(k, n_heads):
    """(B, S, Hkv, hd) -> (B, S, Hq, hd) by repeat."""
    B, S, Hkv, hd = k.shape
    rep = n_heads // Hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def dense_attention(q, k, v, causal: bool, q_offset: int = 0,
                    kv_mask=None, q_pos=None, kv_pos=None) -> jnp.ndarray:
    """Reference O(S^2) path for short sequences. q: (B,Sq,H,hd), k/v: (B,Sk,Hkv,hd).

    q_pos/kv_pos: optional (B, Sq)/(B, Sk) absolute positions for the causal
    mask — required when q is sequence-sharded (local row i is NOT global
    position i)."""
    B, Sq, H, hd = q.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    Sk = k.shape[1]
    if causal:
        if q_pos is not None:
            kp = kv_pos if kv_pos is not None else \
                jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk))
            mask = kp[:, None, None, :] <= q_pos[:, None, :, None]
            scores = jnp.where(mask, scores, NEG_INF)
        else:
            qi = jnp.arange(Sq) + q_offset
            ki = jnp.arange(Sk)
            scores = jnp.where(ki[None, :] <= qi[:, None], scores, NEG_INF)
    if kv_mask is not None:
        scores = jnp.where(kv_mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q, k, v, causal: bool, q_block: int, kv_block: int,
                      q_offset: int = 0, q_pos=None, kv_pos=None,
                      kv_mask=None) -> jnp.ndarray:
    """Flash-style two-level scan: outer over q blocks, inner over kv blocks
    with running (max, sum, acc). Memory O(q_block * kv_block).
    kv_mask: optional (B, Sk) validity — masked kv columns are excluded
    (pad-token exclusion for mixed-length batched prefill)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    if Sq % q_block or Sk % kv_block:
        return dense_attention(q, k, v, causal, q_offset,
                               kv_mask=kv_mask, q_pos=q_pos, kv_pos=kv_pos)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    nq, nk = Sq // q_block, Sk // kv_block
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq)[None, :] + q_offset, (B, Sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk))

    qb = q.reshape(B, nq, q_block, H, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qb,hd)
    kb = k.reshape(B, nk, kv_block, H, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_block, H, hd).transpose(1, 0, 3, 2, 4)
    qpb = q_pos.reshape(B, nq, q_block).swapaxes(0, 1)               # (nq,B,qb)
    kpb = kv_pos.reshape(B, nk, kv_block).swapaxes(0, 1)             # (nk,B,kb)
    # the pad-mask select is only scanned in when a mask is actually passed
    # — the maskless training/prefill hot path keeps its pre-serving shape
    kmb = (None if kv_mask is None else
           jnp.broadcast_to(kv_mask, (B, Sk))
           .reshape(B, nk, kv_block).swapaxes(0, 1))                 # (nk,B,kb)

    def q_step(_, qi_and_block):
        qpos, qblk = qi_and_block
        qblk = qblk.astype(jnp.float32) * scale
        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), jnp.float32)

        def kv_step(carry, ki_and_block):
            m, l, acc = carry
            if kmb is None:
                kpos, kblk, vblk = ki_and_block
            else:
                kpos, kmask, kblk, vblk = ki_and_block
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk.astype(jnp.float32))
            if causal:
                mask = kpos[:, None, None, :] <= qpos[:, None, :, None]
                s = jnp.where(mask, s, NEG_INF)
            if kmb is not None:
                s = jnp.where(kmask[:, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        xs = (kpb, kb, vb) if kmb is None else (kpb, kmb, kb, vb)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qpb, qb))  # (nq,B,H,qb,hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def attention(q, k, v, causal: bool, q_block: int = 512, kv_block: int = 1024,
              q_offset: int = 0, dense_threshold: int = 1024,
              q_pos=None, kv_pos=None, kv_mask=None) -> jnp.ndarray:
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq * Sk <= dense_threshold * dense_threshold:
        return dense_attention(q, k, v, causal, q_offset, kv_mask=kv_mask,
                               q_pos=q_pos, kv_pos=kv_pos)
    return chunked_attention(q, k, v, causal, q_block, kv_block, q_offset,
                             q_pos=q_pos, kv_pos=kv_pos, kv_mask=kv_mask)


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def _pos_col(pos):
    """Normalize a ()/(B,) position to broadcast against (B, ·, ·, S)."""
    pos = jnp.asarray(pos)
    return pos.reshape((-1, 1, 1, 1)) if pos.ndim else pos


# -- paged (block-table) cache layout ---------------------------------------
# The pool holds fixed-size pages shared by every slot: (n_pages, page, Hkv,
# hd). A block table (B, max_blocks) int32 maps each row's logical block i
# (positions [i*page, (i+1)*page)) to a physical page; entry 0 is the NULL
# page — never allocated, so unmapped blocks gather it (masked by position
# validity) and dead-row writes are steered into it.


def paged_gather(pool, block_table):
    """Materialize the logical per-row cache view from the shared pool.
    pool: (P, page, Hkv, hd); block_table: (B, nb) int32 page ids.
    Returns (B, nb*page, Hkv, hd) — row b's logical positions in order."""
    g = jnp.take(pool, block_table, axis=0)       # (B, nb, page, Hkv, hd)
    B, nb, page, Hkv, hd = g.shape
    return g.reshape(B, nb * page, Hkv, hd)


def paged_update_cache(k_pool, v_pool, k_new, v_new, pos, block_table):
    """Decode write through block tables: insert (B, 1, Hkv, hd) at per-row
    logical position ``pos`` (() or (B,)). Rows whose mapped page is the
    null page (free slots — all-zero table rows) write harmlessly into it.
    Returns the updated pools."""
    P, page, Hkv, hd = k_pool.shape
    B = k_new.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    blk = jnp.clip(pos // page, 0, block_table.shape[1] - 1)
    pid = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    phys = pid * page + pos % page                # null page -> rows [0,page)
    kf = k_pool.reshape(P * page, Hkv, hd)
    vf = v_pool.reshape(P * page, Hkv, hd)
    kf = kf.at[phys].set(k_new[:, 0].astype(kf.dtype))
    vf = vf.at[phys].set(v_new[:, 0].astype(vf.dtype))
    return kf.reshape(P, page, Hkv, hd), vf.reshape(P, page, Hkv, hd)


def paged_chunk_update(k_pool, v_pool, k, v, pos_off, block_table, tok_mask):
    """Prefill-chunk write through block tables: k/v (A, C, Hkv, hd) land at
    logical positions pos_off[a] + [0, C). tok_mask (A, C) marks valid
    tokens — tail pads and inactive admission rows are steered to the null
    page, so one stacked call admits several requests without branching.
    Returns the updated pools."""
    P, page, Hkv, hd = k_pool.shape
    A, C = k.shape[:2]
    pos_off = jnp.broadcast_to(jnp.asarray(pos_off, jnp.int32).reshape(-1),
                               (A,))
    positions = pos_off[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    nb = block_table.shape[1]
    blk = positions // page
    pid = jnp.take_along_axis(block_table, jnp.clip(blk, 0, nb - 1), axis=1)
    pid = jnp.where(tok_mask & (blk < nb), pid, NULL_PAGE)
    phys = (pid * page + positions % page).reshape(A * C)
    kf = k_pool.reshape(P * page, Hkv, hd)
    vf = v_pool.reshape(P * page, Hkv, hd)
    kf = kf.at[phys].set(k.reshape(A * C, Hkv, hd).astype(kf.dtype))
    vf = vf.at[phys].set(v.reshape(A * C, Hkv, hd).astype(vf.dtype))
    return kf.reshape(P, page, Hkv, hd), vf.reshape(P, page, Hkv, hd)


def decode_attention(q, k_cache, v_cache, pos, kv_start=None,
                     block_table=None) -> jnp.ndarray:
    """q: (B, 1, H, hd); caches: (B, S, Hkv, hd); pos: () or (B,) per-row
    current index (continuous batching decodes every slot at its OWN
    position). Attends over cache[kv_start : pos+1] via masking (fixed-size
    cache = production decode; the memory-roofline term reads the full
    cache, as real HW does). kv_start: optional ()/(B,) first valid cache
    index — left-padded rows exclude their pad region exactly.
    block_table: optional (B, nb) int32 — the caches are then shared
    (n_pages, page, Hkv, hd) pools and each row's logical view is gathered
    through its table (unmapped blocks hit the null page, masked by the
    position-validity test exactly like stale contiguous rows)."""
    if block_table is not None:
        k_cache = paged_gather(k_cache, block_table)
        v_cache = paged_gather(v_cache, block_table)
    B, S, Hkv, hd = k_cache.shape
    H = q.shape[2]
    k = _expand_kv(k_cache, H)
    v = _expand_kv(v_cache, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ar = jnp.arange(S)[None, None, None, :]
    valid = ar <= _pos_col(pos)
    if kv_start is not None:
        valid &= ar >= _pos_col(kv_start)
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_partial(q, k_shard, v_shard, pos, kv_offset,
                             kv_start=None):
    """Flash-decode partial over a LOCAL kv shard. q: (B,1,H,hd); shards:
    (B,S_loc,Hkv,hd); pos: () or (B,); kv_offset: absolute position of shard
    row 0. Returns (m, l, acc): running max (B,H,1), sum (B,H,1), acc
    (B,H,1,hd) — merged across shards by the caller (pmax/psum), the
    split-KV scheme."""
    B, S_loc, Hkv, hd = k_shard.shape
    H = q.shape[2]
    k = _expand_kv(k_shard, H)
    v = _expand_kv(v_shard, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ar = (kv_offset + jnp.arange(S_loc))[None, None, None, :]
    valid = ar <= _pos_col(pos)
    if kv_start is not None:
        valid &= ar >= _pos_col(kv_start)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                # (B,H,1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)                           # fully-masked shard
    l = jnp.sum(p, axis=-1)                                # (B,H,1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return m, l, acc


def merge_decode_partials(m, l, acc, axis_name):
    """Combine split-KV partials across the mesh axis: three tiny
    collectives of (B,H,1[,hd]) instead of all-gathering the cache."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    acc_g = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_g / jnp.maximum(l_g[..., None], 1e-30)


def update_cache(k_cache, v_cache, k_new, v_new, pos):
    """Insert (B, 1, Hkv, hd) at position pos — () shared across the batch,
    or (B,) per-row write indices (slot-based decode: every slot is at its
    own sequence position)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        return k_cache, v_cache

    def row(c, n, p):
        return jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0)

    k_cache = jax.vmap(row)(k_cache, k_new.astype(k_cache.dtype), pos)
    v_cache = jax.vmap(row)(v_cache, v_new.astype(v_cache.dtype), pos)
    return k_cache, v_cache
