"""Full model assembly: schema, init, train forward, prefill, decode.

Layers are stacked by *period* (lcm of the hybrid pattern length and the MoE
interleave) and scanned — one period of HLO regardless of depth, which keeps
the 94-layer dry-runs compilable.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention as A
from repro.models import blocks as B
from repro.models import ssm as S
from repro.models.common import (ParamDecl, abstract_from_schema, apply_norm,
                                 chunked_xent, ffn_schema, init_from_schema,
                                 norm_schema, sinusoid_positions,
                                 specs_from_schema)
from repro.parallel.mesh import AxisCtx

Pytree = Any


def period_of(cfg) -> int:
    p = max(1, len(cfg.layer_pattern))
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every_k_layers)
    return p


def _stack(schema: Pytree, n: int) -> Pytree:
    def mk(d: ParamDecl):
        return ParamDecl((n,) + d.shape, ("layers",) + d.logical, d.init, d.scale)
    return jax.tree_util.tree_map(mk, schema,
                                  is_leaf=lambda x: isinstance(x, ParamDecl))


def _enc_layer_schema(cfg) -> Dict:
    return {
        "ln1": norm_schema(cfg, cfg.d_model),
        "attn": A.attn_schema(cfg, cfg.attn),
        "ln2": norm_schema(cfg, cfg.d_model),
        "ffn": ffn_schema(cfg, cfg.d_model, cfg.d_ff),
    }


def model_schema(cfg, ctx: AxisCtx) -> Dict:
    d, V = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {"embed": ParamDecl((V, d), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamDecl((d, V), ("embed", "vocab"))
    s["ln_f"] = norm_schema(cfg, d)
    p = period_of(cfg)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    n_periods = cfg.n_layers // p
    cross = cfg.n_enc_layers > 0
    s["layers"] = [
        _stack(B.layer_schema(cfg, pos, ctx, cross=cross), n_periods)
        for pos in range(p)
    ]
    if cfg.n_enc_layers:
        s["encoder"] = _stack(_enc_layer_schema(cfg), cfg.n_enc_layers)
        s["ln_enc"] = norm_schema(cfg, d)
    return s


def init_params(cfg, key, ctx: AxisCtx = AxisCtx()) -> Pytree:
    return init_from_schema(model_schema(cfg, ctx), key, cfg.param_dtype)


def abstract_params(cfg, ctx: AxisCtx = AxisCtx()) -> Pytree:
    return abstract_from_schema(model_schema(cfg, ctx), cfg.param_dtype)


# ---------------------------------------------------------------------------
# Embedding / io
# ---------------------------------------------------------------------------


def embed_inputs(cfg, params, batch, ctx: AxisCtx):
    if "embeds" in batch:                       # stub modality frontend
        h = batch["embeds"].astype(cfg.compute_dtype)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = h.astype(cfg.compute_dtype)
    if cfg.n_enc_layers:                        # whisper decoder: abs positions
        Spos = h.shape[1]
        h = h + sinusoid_positions(Spos, cfg.d_model).astype(h.dtype)
    return B._csp(h, ctx, ctx.dp_axes, None, None)


def output_head(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encode(cfg, params, frames, ctx: AxisCtx):
    h = frames.astype(cfg.compute_dtype)
    h = h + sinusoid_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(x, p):
        hh = apply_norm(cfg, p["ln1"], x)
        hh, _ = B.attn_apply(cfg, p["attn"], hh, ctx, positions, causal=False,
                             use_rope=False)
        x = x + hh
        hh = apply_norm(cfg, p["ln2"], x)
        from repro.models.common import ffn_apply
        x = x + ffn_apply(cfg, p["ffn"], hh)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(lambda c, p: body(c, p), h, params["encoder"])
    return apply_norm(cfg, params["ln_enc"], h)


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------


def _forward_inputs(cfg, params, batch, ctx: AxisCtx):
    """Shared front of every full-sequence forward: embeddings, pad-aware
    positions, optional encoder output."""
    h = embed_inputs(cfg, params, batch, ctx)
    Bsz, Ssz, _ = h.shape
    mask = batch.get("mask")
    if "positions" in batch:
        positions = batch["positions"]
    elif mask is not None:
        # left-pad aware: position = rank among this row's valid tokens
        positions = jnp.maximum(
            jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1, 0)
    else:
        positions = jnp.broadcast_to(jnp.arange(Ssz)[None, :], (Bsz, Ssz))
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = encode(cfg, params, batch["frames"], ctx)
    return h, positions, mask, enc_out


def forward(cfg, params, batch, ctx: AxisCtx = AxisCtx(),
            return_cache: bool = False):
    """Returns (h_final, aux_loss, cache|None). h_final: (B, S, d).

    batch may carry a ``mask`` (B, S) bool — pad-token validity for
    mixed-length batched prefill. With it, pad keys/values are excluded
    from attention, SSM pad steps become identities, and per-row positions
    are derived from the mask (left-padded rows RoPE from 0 at their first
    real token), so the padded forward is EXACT, not approximate.

    With ``cfg.block_schedule`` set ("sequential" | "overlap") the
    non-cache path runs through the block-schedule IR
    (``forward_scheduled``); prefill (return_cache=True) always keeps the
    scan path."""
    if getattr(cfg, "block_schedule", "") and not return_cache:
        return forward_scheduled(cfg, params, batch, ctx)
    h, positions, mask, enc_out = _forward_inputs(cfg, params, batch, ctx)

    p = period_of(cfg)

    def period_body(carry, layer_params):
        x, aux = carry
        caches = []
        for pos in range(p):
            x, a, ce = B.apply_layer(cfg, pos, layer_params[pos], x, ctx,
                                     positions, enc_out=enc_out,
                                     return_cache=return_cache, mask=mask)
            aux = aux + a
            caches.append(ce)
        out = tuple(caches) if return_cache else None
        return (x, aux), out

    body = period_body
    if cfg.remat == "full" and not return_cache:
        body = jax.checkpoint(period_body)

    (h, aux), caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), tuple(params["layers"]))
    h = apply_norm(cfg, params["ln_f"], h)
    return h, aux, caches


def forward_scheduled(cfg, params, batch, ctx: AxisCtx = AxisCtx()):
    """Block-schedule-IR forward: every layer is lowered to its executed
    segments (models/blocks.py ``block_segments``), the whole-graph segment
    list is ordered by core/schedule.py (``cfg.block_schedule``:
    "sequential" = program order, "overlap" = the greedy earliest-start
    scheduler), and the chosen emission order is interpreted against one
    shared env. Any legal order is a pure permutation over identical
    dataflow, so this is numerically IDENTICAL to the sequential baseline
    — the equivalence the tests assert bitwise.

    Layers are UNROLLED (no scan/remat): the scheduler needs segments of
    DIFFERENT blocks visible in one window, which a scanned period body
    cannot expose. Intended for the paper-shape step benchmarks and
    parity tests, not 94-layer dry-runs."""
    from repro.core.schedule import exec_order

    h, positions, mask, enc_out = _forward_inputs(cfg, params, batch, ctx)
    p = period_of(cfg)
    segs = []
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a, i=i: a[i // p],
                                    params["layers"][i % p])
        segs += B.block_segments(cfg, i % p, lp, ctx, positions,
                                 enc_out=enc_out, return_cache=False,
                                 mask=mask, block=i, x_in=f"x{i}",
                                 x_out=f"x{i + 1}")
    program = segs
    segs = exec_order(segs, cfg.block_schedule)
    if os.environ.get("REPRO_VERIFY_SCHEDULE", "1") != "0":
        # trace-time race detector: re-derive RAW/WAR/WAW hazards from the
        # segments' declared reads/writes (NOT the deps the scheduler
        # used) and refuse any order that violates one. Pure Python over
        # a few hundred segments — costs nothing against the jit trace.
        from repro.analysis.verify.schedule_check import \
            assert_exec_order_safe
        assert_exec_order_safe(program, segs)
    env = B.run_segments(segs, {"x0": h})
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        a = env.get(f"L{i}.aux")
        if a is not None:
            aux = aux + a
    h = apply_norm(cfg, params["ln_f"], env[f"x{cfg.n_layers}"])
    return h, aux, None


def loss_fn(cfg, params, batch, ctx: AxisCtx = AxisCtx()):
    h, aux, _ = forward(cfg, params, batch, ctx)
    loss, cnt = chunked_xent(h, output_head(cfg, params), batch["labels"])
    return loss + aux, {"xent": loss, "aux": aux, "tokens": cnt}


def prefill(cfg, params, batch, ctx: AxisCtx = AxisCtx()):
    """Returns (last-token logits (B, V), cache pytree). Mixed-length
    batches LEFT-pad (prompt ends aligned at index S-1, where the logits
    are read) and pass ``batch["mask"]`` — with the mask the padded forward
    is exact (see ``forward``), without it pad tokens attend."""
    h, _, caches = forward(cfg, params, batch, ctx, return_cache=True)
    logits = h[:, -1].astype(jnp.float32) @ output_head(cfg, params).astype(jnp.float32)
    return logits, caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, seq_len: int, ctx: AxisCtx = AxisCtx(),
               enc_len: int = 0) -> Tuple:
    """Zero cache matching the scan layout: tuple over period positions of
    stacked (n_periods, ...) entries."""
    p = period_of(cfg)
    n_periods = cfg.n_layers // p
    a = cfg.attn
    dt = jnp.dtype(cfg.param_dtype)
    caches = []
    for pos in range(p):
        kind = cfg.layer_kind(pos)
        if kind == "a":
            e = {
                "k": jnp.zeros((n_periods, batch_size, seq_len, a.n_kv_heads,
                                a.head_dim), dt),
                "v": jnp.zeros((n_periods, batch_size, seq_len, a.n_kv_heads,
                                a.head_dim), dt),
            }
            if cfg.n_enc_layers:
                e["xk"] = jnp.zeros((n_periods, batch_size, enc_len,
                                     a.n_kv_heads, a.head_dim), dt)
                e["xv"] = jnp.zeros_like(e["xk"])
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            e = {
                "conv": jnp.zeros((n_periods, batch_size, s.conv_width - 1,
                                   d_in + 2 * s.d_state), dt),
                "state": jnp.zeros((n_periods, batch_size, nh, s.d_state,
                                    s.head_dim), jnp.float32),
            }
        caches.append(e)
    return tuple(caches)


def init_paged_cache(cfg, n_slots: int, n_pages: int, page_size: int,
                     ctx: AxisCtx = AxisCtx()) -> Tuple:
    """Paged decode cache: K/V entries are SHARED page pools (n_periods,
    n_pages, page_size, Hkv, hd) — every slot reads/writes through its
    block table — while SSM conv/state stay dense per-slot (they are O(1)
    per request and carry no per-token history). Page 0 is the null page
    (see serving/paged_cache.py)."""
    assert cfg.n_enc_layers == 0, "paged serving: decoder-only models"
    p = period_of(cfg)
    n_periods = cfg.n_layers // p
    a = cfg.attn
    dt = jnp.dtype(cfg.param_dtype)
    caches = []
    for pos in range(p):
        if cfg.layer_kind(pos) == "a":
            e = {
                "k": jnp.zeros((n_periods, n_pages, page_size, a.n_kv_heads,
                                a.head_dim), dt),
                "v": jnp.zeros((n_periods, n_pages, page_size, a.n_kv_heads,
                                a.head_dim), dt),
            }
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            e = {
                "conv": jnp.zeros((n_periods, n_slots, s.conv_width - 1,
                                   d_in + 2 * s.d_state), dt),
                "state": jnp.zeros((n_periods, n_slots, nh, s.d_state,
                                    s.head_dim), jnp.float32),
            }
        caches.append(e)
    return tuple(caches)


def decode_step(cfg, params, cache, tokens, t_pos, ctx: AxisCtx = AxisCtx(),
                rope_pos=None, kv_start=None, block_tables=None):
    """tokens: (B, 1) int32; t_pos: () int32 shared position, or (B,) int32
    PER-ROW cache write indices (slot-based decode — every in-flight request
    sits at its own sequence position). rope_pos: optional ()/(B,) RoPE
    positions when they differ from the cache index (left-padded rows);
    kv_start: optional ()/(B,) first valid cache index per row.
    block_tables: optional (B, max_blocks) int32 — the cache's K/V entries
    are then shared paged pools (see ``init_paged_cache``) and each row
    resolves its logical positions through its table.
    Returns (logits (B, V), cache)."""
    Bsz = tokens.shape[0]
    t_vec = jnp.broadcast_to(
        jnp.asarray(t_pos, jnp.int32).reshape(-1), (Bsz,))
    rope_vec = None if rope_pos is None else jnp.broadcast_to(
        jnp.asarray(rope_pos, jnp.int32).reshape(-1), (Bsz,))
    start_vec = None if kv_start is None else jnp.broadcast_to(
        jnp.asarray(kv_start, jnp.int32).reshape(-1), (Bsz,))
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.n_enc_layers:
        from repro.models.common import sinusoid_at
        pe = jax.vmap(lambda pp: sinusoid_at(pp, cfg.d_model))(t_vec)
        h = h + pe[:, None, :].astype(h.dtype)
    p = period_of(cfg)
    has_cross = cfg.n_enc_layers > 0

    def period_body(x, inp):
        layer_params, cache_in = inp
        new_caches = []
        for pos in range(p):
            x, nc = B.decode_layer(cfg, pos, layer_params[pos], x, ctx,
                                   cache_in[pos], t_vec, has_cross=has_cross,
                                   rope_pos=rope_vec, kv_start=start_vec,
                                   block_table=block_tables)
            new_caches.append(nc)
        return x, tuple(new_caches)

    h, new_cache = jax.lax.scan(
        period_body, h, (tuple(params["layers"]), cache))
    h = apply_norm(cfg, params["ln_f"], h)
    logits = h[:, 0].astype(jnp.float32) @ output_head(cfg, params).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked prefill (continuous-batching admission path)
# ---------------------------------------------------------------------------


def prefill_chunk(cfg, params, cache, tokens, pos_off, valid_len,
                  ctx: AxisCtx = AxisCtx(), slot=None, block_tables=None):
    """Prompt chunks against per-slot cache regions — one admission row or
    a STACK of them (batched chunk admission: several queued requests run
    their chunk step in one compiled call).

    tokens: (A, C) int32, one chunk per admission row (tail-padded when
    valid_len < C); pos_off: ()/(A,) int32 cache index of each row's first
    token; valid_len: ()/(A,) int32 valid tokens per row (0 = the row's
    prompt already ended in this stacked step — pure identity row); slot:
    optional ()/(A,) int32 — when given, ``cache`` is the FULL decode
    cache and each row runs against its own slot (gathered out, updated,
    scattered back), which is how the serving engine stitches prompts into
    per-slot regions with ONE compiled function for every slot set.
    block_tables: optional (A, max_blocks) int32 — the cache's K/V entries
    are then shared paged pools (``init_paged_cache``) written through
    each row's table (SSM conv/state keep the dense per-slot layout).

    The chunk attends over its row's cache up to its own indices (earlier
    chunks included) with exact causal/pad masking, SSM layers scan on
    from the cached (conv window, SSD state) — reset in-graph where
    pos_off == 0, so a freed slot needs no host-side scrubbing before
    reuse. Returns (logits (A, V) at each row's last VALID position,
    updated cache)."""
    assert cfg.n_enc_layers == 0, "chunked prefill: decoder-only models"
    Bc, C = tokens.shape
    pos_off = jnp.broadcast_to(
        jnp.asarray(pos_off, jnp.int32).reshape(-1), (Bc,))
    valid_len = jnp.broadcast_to(
        jnp.asarray(valid_len, jnp.int32).reshape(-1), (Bc,))
    paged = block_tables is not None
    full = cache
    slots = None
    if slot is not None:
        slots = jnp.broadcast_to(jnp.asarray(slot, jnp.int32).reshape(-1),
                                 (Bc,))
        # gather the admission rows: SSM entries always carry a slot axis;
        # K/V only in the contiguous layout (paged pools are shared)
        cache = tuple(
            {k: (v if paged and k in ("k", "v")
                 else jnp.take(v, slots, axis=1))
             for k, v in e.items()} for e in cache)
    # first chunk of a request: the slot's SSM carry must restart from zero
    # (K/V need no reset — stale indices are causal-masked / overwritten)
    first = pos_off == 0

    def _reset(k, v):
        if k not in ("conv", "state"):
            return v
        f = first.reshape((1, -1) + (1,) * (v.ndim - 2))
        return jnp.where(f, jnp.zeros_like(v), v)

    cache = tuple({k: _reset(k, v) for k, v in e.items()} for e in cache)

    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    q_pos = pos_off[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    mask = jnp.arange(C)[None, :] < valid_len[:, None]
    p = period_of(cfg)

    def period_body(x, inp):
        layer_params, cache_in = inp
        new_caches = []
        for pos in range(p):
            x, nc = B.chunk_layer(cfg, pos, layer_params[pos], x, ctx,
                                  cache_in[pos], pos_off, q_pos, mask,
                                  valid_len, block_table=block_tables)
            new_caches.append(nc)
        return x, tuple(new_caches)

    h, new_cache = jax.lax.scan(
        period_body, h, (tuple(params["layers"]), cache))
    h = apply_norm(cfg, params["ln_f"], h)
    h_last = jax.vmap(
        lambda hr, vl: jax.lax.dynamic_slice_in_dim(
            hr, jnp.maximum(vl - 1, 0), 1, axis=0))(h, valid_len)[:, 0]
    logits = (h_last.astype(jnp.float32)
              @ output_head(cfg, params).astype(jnp.float32))
    if slot is not None:
        # scatter the admission rows back (paged K/V pools are already
        # global — the layers updated them directly)
        out = []
        for e_new, e_full in zip(new_cache, full):
            d = {}
            for k, n in e_new.items():
                if paged and k in ("k", "v"):
                    d[k] = n
                else:
                    d[k] = e_full[k].at[:, slots].set(
                        n.astype(e_full[k].dtype))
            out.append(d)
        new_cache = tuple(out)
    return logits, new_cache
