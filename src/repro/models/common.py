"""Shared model building blocks: schema-driven params, norms, RoPE, FFN, losses.

Parameters are declared through a *schema* (nested dict of ``ParamDecl``) so a
single source of truth yields: real initialization, abstract ShapeDtypeStructs
for the dry-run, and PartitionSpecs for pjit — the three never drift.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


# ---------------------------------------------------------------------------
# Param schema
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                    # normal | zeros | ones | small
    scale: float = 1.0

    def initialize(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


def init_from_schema(schema: Pytree, key, dtype: str) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamDecl))
    keys = jax.random.split(key, len(leaves))
    dt = jnp.dtype(dtype)
    out = []
    for k, decl in zip(keys, leaves):
        # norm scales/biases kept fp32 for stability
        use = jnp.float32 if decl.init in ("ones", "zeros") else dt
        out.append(decl.initialize(k, use))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_from_schema(schema: Pytree, dtype: str) -> Pytree:
    dt = jnp.dtype(dtype)

    def mk(decl: ParamDecl):
        use = jnp.float32 if decl.init in ("ones", "zeros") else dt
        return jax.ShapeDtypeStruct(decl.shape, use)

    return jax.tree_util.tree_map(
        mk, schema, is_leaf=lambda x: isinstance(x, ParamDecl))


def specs_from_schema(schema: Pytree, rules: Dict[str, Optional[Any]]) -> Pytree:
    def mk(decl: ParamDecl):
        axes = tuple(rules.get(l) if l is not None else None for l in decl.logical)
        return P(*axes)

    return jax.tree_util.tree_map(
        mk, schema, is_leaf=lambda x: isinstance(x, ParamDecl))


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    out = (h - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def norm_schema(cfg, d) -> Dict[str, ParamDecl]:
    s = {"scale": ParamDecl((d,), ("embed_v",), "ones")}
    if cfg.norm == "layernorm":
        s["bias"] = ParamDecl((d,), ("embed_v",), "zeros")
    return s


def activate(name: str, gate, up):
    """gate may be None for non-GLU activations."""
    if name == "swiglu":
        return jax.nn.silu(gate) * up
    if name == "geglu":
        return jax.nn.gelu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(up)
    if name == "relu2":
        r = jax.nn.relu(up)
        return r * r
    raise ValueError(name)


def is_glu(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def ffn_schema(cfg, d, hidden) -> Dict[str, ParamDecl]:
    s: Dict[str, ParamDecl] = {}
    if is_glu(cfg.activation):
        s["w_gate"] = ParamDecl((d, hidden), ("embed", "ffn"))
    s["w_up"] = ParamDecl((d, hidden), ("embed", "ffn"))
    s["w_down"] = ParamDecl((hidden, d), ("ffn", "embed"), scale=1.0)
    return s


def ffn_apply(cfg, p, x):
    gate = x @ p["w_gate"] if "w_gate" in p else None
    up = x @ p["w_up"]
    h = activate(cfg.activation, gate, up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(S: int, d: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


def sinusoid_at(pos, d: int):
    """Single-position sinusoid embedding; pos may be traced. Returns (d,)."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((d,), jnp.float32)
    return pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy — never materializes (tokens, vocab)
# ---------------------------------------------------------------------------


def chunked_xent(h, w_out, labels, chunk: int = 1024, logit_dtype=jnp.float32):
    """h: (B, S, d); w_out: (d, V); labels: (B, S) with -1 = ignore.

    Scans over sequence chunks; per chunk the (tokens, V) logits exist only
    transiently. Returns (mean loss over non-ignored, token count).
    """
    B, S, d = h.shape
    V = w_out.shape[1]
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    # checkpoint: without it jax saves each chunk's FULL logits as scan
    # residuals for the backward pass — the exact (tokens, V) blow-up this
    # function exists to avoid. With it, logits are recomputed in bwd.
    @jax.checkpoint
    def one(hc, lc):
        logits = (hc.astype(logit_dtype) @ w_out.astype(logit_dtype))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(logit_dtype)
        return jnp.sum((lse - tgt) * mask), jnp.sum(mask)

    def body(carry, xs):
        hc, lc = xs
        l, c = one(hc, lc)
        return (carry[0] + l, carry[1] + c), None

    hs = h[:, :n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), logit_dtype),) * 2, (hs, ls))
    if rem:
        l, c = one(h[:, n * chunk:], labels[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0), cnt


def logits_for(h, w_out, logit_dtype=jnp.float32):
    return h.astype(logit_dtype) @ w_out.astype(logit_dtype)
