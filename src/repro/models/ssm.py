"""Mamba-2 (SSD, state-space duality) block: chunked dual-form training path +
O(1)-state decode step. Pure JAX; the chunked scan is the TPU-friendly
formulation (dense intra-chunk matmuls feed the MXU, inter-chunk recurrence is
a length-S/Q scan over (nh, hd, d_state) states).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDecl, rms_norm


def ssm_schema(cfg, s) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.d_state
    return {
        "in_proj": ParamDecl((d, 2 * d_in + 2 * s.d_state + nh), ("embed", "ssm_in")),
        "conv_w": ParamDecl((s.conv_width, conv_ch), (None, "ssm_conv")),
        "conv_b": ParamDecl((conv_ch,), ("ssm_conv",), "zeros"),
        "A_log": ParamDecl((nh,), ("ssm_heads",), "ones"),
        "D": ParamDecl((nh,), ("ssm_heads",), "ones"),
        "dt_bias": ParamDecl((nh,), ("ssm_heads",), "zeros"),
        "norm_scale": ParamDecl((d_in,), ("ssm_inner",), "ones"),
        "out_proj": ParamDecl((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg, s, zxbcdt):
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    sizes = [d_in, d_in, s.d_state, s.d_state, nh]
    idx = []
    acc = 0
    for sz in sizes[:-1]:
        acc += sz
        idx.append(acc)
    return jnp.split(zxbcdt, idx, axis=-1)  # z, x, B, C, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (W, C). state: (B, W-1, C) or None.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return y + b.astype(x.dtype), new_state


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, h0=None):
    """SSD dual form. x: (B,S,nh,hd); dt: (B,S,nh); A: (nh) (negative);
    Bm/Cm: (B,S,ds); D: (nh). h0: optional (B,nh,ds,hd) fp32 initial state
    (chunked-prefill continuation; None = zero state).
    Returns y (B,S,nh,hd)."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        Q = S  # fall back to one chunk (small/smoke shapes)
    nchunks = S // Q
    f32 = jnp.float32

    xd = (x * dt[..., None]).astype(f32)              # discretized input
    la = (dt * A[None, None, :]).astype(f32)          # log decay per step (<=0)

    # reshape into chunks
    xc = xd.reshape(Bsz, nchunks, Q, nh, hd)
    lac = la.reshape(Bsz, nchunks, Q, nh)
    Bc = Bm.reshape(Bsz, nchunks, Q, ds).astype(f32)
    Cc = Cm.reshape(Bsz, nchunks, Q, ds).astype(f32)

    cum = jnp.cumsum(lac, axis=2)                     # (B,NC,Q,nh)
    total = cum[:, :, -1]                             # (B,NC,nh)

    # --- intra-chunk (quadratic within chunk) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,NC,Q,Q,nh)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    CB = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)                # (B,NC,Q,Q)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", CB, L, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)        # (B,NC,Q,nh)
    states = jnp.einsum("bnjs,bnjh,bnjhp->bnhsp", Bc,
                        decay_to_end, xc)                     # (B,NC,nh,ds,hd)

    # --- inter-chunk recurrence ---
    def step(h, inp):
        st, tot = inp                                          # (B,nh,ds,hd),(B,nh)
        h_new = h * jnp.exp(tot)[..., None, None] + st
        return h_new, h                                        # emit state BEFORE chunk

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, ds, hd), f32)
    h_final, h_prev = jax.lax.scan(
        step, h0.astype(f32),
        (states.swapaxes(0, 1), total.swapaxes(0, 1)))         # (NC,B,nh,ds,hd)
    h_prev = h_prev.swapaxes(0, 1)                             # (B,NC,nh,ds,hd)

    y_inter = jnp.einsum("bnis,bnih,bnhsp->bnihp", Cc,
                         jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(Bsz, S, nh, hd)
    y = y + (D[None, None, :, None] * x.astype(f32))
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, A, Bm, Cm, D):
    """Naive O(S) sequential recurrence — oracle for tests."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt * A)                                   # (B,nh)
        xd = xt * dtt[..., None]
        h = h * a[..., None, None] + jnp.einsum("bs,bhp->bhsp", bt, xd)
        y = jnp.einsum("bs,bhsp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, nh, ds, hd), f32)
    xs = (x.astype(f32).swapaxes(0, 1), dt.astype(f32).swapaxes(0, 1),
          Bm.astype(f32).swapaxes(0, 1), Cm.astype(f32).swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + D[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype)


def ssm_forward(cfg, s, p, x, cache=None, pos=None, return_cache=False,
                mask=None, valid_len=None):
    """Full Mamba-2 block. x: (B,S,d). cache: None for training/prefill, else
    dict with 'conv' (B,W-1,C) and 'state' (B,nh,ds,hd) — single-token decode
    when S == 1, chunked-prefill CONTINUATION when S > 1 (the chunk scans on
    from the cached conv window and SSD state). return_cache=True on the
    prefill path emits the final state.

    mask: optional (B, S) validity — pad positions become IDENTITY steps
    (conv input zeroed so the causal window sees the same zeros the unpadded
    run's initial state provides; dt zeroed so decay is exp(0)=1 and the
    discretized input is 0), which makes mixed-length batched prefill and
    tail-padded chunks EXACT, not approximate. valid_len: () count of valid
    leading tokens in a continuation chunk — the emitted conv window is
    taken at that offset, so decode resumes from the last REAL token.
    Returns (y, new_cache)."""
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    S_len = x.shape[1]
    chunk_cont = cache is not None and S_len > 1
    zxbcdt = x @ p["in_proj"]
    z, xr, Bm, Cm, dt = _split_proj(cfg, s, zxbcdt)

    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    if mask is not None:
        conv_in = conv_in * mask[..., None].astype(conv_in.dtype)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xr = conv_out[..., :d_in]
    Bm = conv_out[..., d_in:d_in + s.d_state]
    Cm = conv_out[..., d_in + s.d_state:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if mask is not None:
        dt = dt * mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xr.reshape(*xr.shape[:-1], nh, s.head_dim)

    if cache is None or chunk_cont:
        # tagged fusable: kernels/ssd.py is the validated Pallas kernel that
        # keeps the chunk working set (L, CB, states) in VMEM on TPU; the
        # roofline counts its boundary bytes analytically.
        h0 = cache["state"] if chunk_cont else None
        with jax.named_scope("__fusable__ssd"):
            y, h_final = ssd_chunked(xh, dt, A, Bm, Cm,
                                     p["D"].astype(jnp.float32), s.chunk_size,
                                     h0=h0)
        new_cache = None
        if return_cache or chunk_cont:
            W = s.conv_width
            if W > 1:
                if chunk_cont:
                    # conv window after the last VALID token of the chunk:
                    # concat(prev window, chunk inputs) sliced at valid_len
                    # — () shared, or (B,) per-row (batched chunk admission
                    # stacks rows at different fill levels)
                    xp = jnp.concatenate(
                        [conv_state.astype(conv_in.dtype), conv_in], axis=1)
                    off = (jnp.asarray(valid_len, jnp.int32)
                           if valid_len is not None else jnp.int32(S_len))
                    if off.ndim:
                        conv_entry = jax.vmap(
                            lambda xr, o: jax.lax.dynamic_slice_in_dim(
                                xr, o, W - 1, axis=0))(xp, off)
                        conv_entry = conv_entry.astype(x.dtype)
                    else:
                        conv_entry = jax.lax.dynamic_slice_in_dim(
                            xp, off, W - 1, axis=1).astype(x.dtype)
                else:
                    conv_entry = conv_in[:, -(W - 1):].astype(x.dtype)
            else:
                conv_entry = jnp.zeros((x.shape[0], 0, conv_in.shape[-1]),
                                       x.dtype)
            new_cache = {"conv": conv_entry, "state": h_final}
    else:
        # single-step recurrence: S == 1
        h = cache["state"]                                    # (B,nh,ds,hd) fp32
        a = jnp.exp(dt[:, 0] * A)                             # (B,nh)
        xd = (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)
        h = h * a[..., None, None] + jnp.einsum("bs,bhp->bhsp",
                                                Bm[:, 0].astype(jnp.float32), xd)
        y = jnp.einsum("bs,bhsp->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "state": h}

    y = y.reshape(*x.shape[:-1], d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return (y @ p["out_proj"]).astype(x.dtype), new_cache


def init_ssm_cache(cfg, s, batch: int, dtype):
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
    }
