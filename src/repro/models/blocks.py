"""Layer blocks: (attention | mamba) + (dense FFN | MoE), schema + apply,
for train / prefill / decode modes."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.moe_layer import moe_ffn, moe_schema
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.common import (apply_norm, ffn_apply, ffn_schema,
                                 norm_schema)
from repro.parallel.compat import shard_map
from repro.parallel.mesh import AxisCtx


def _csp(x, ctx: AxisCtx, *axes):
    """Sharding-constraint helper; no-op without a mesh."""
    if not ctx.active:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*axes)))


# ---------------------------------------------------------------------------
# Schema for one layer position
# ---------------------------------------------------------------------------


def layer_schema(cfg, pos: int, ctx: AxisCtx, cross: bool = False) -> Dict:
    kind = cfg.layer_kind(pos)
    s: Dict[str, Any] = {"ln1": norm_schema(cfg, cfg.d_model)}
    if kind == "a":
        s["attn"] = A.attn_schema(cfg, cfg.attn)
        if cross:
            s["ln_x"] = norm_schema(cfg, cfg.d_model)
            s["xattn"] = A.attn_schema(cfg, cfg.attn, cross=True)
    else:
        s["ssm"] = S.ssm_schema(cfg, cfg.ssm)
    has_mlp = cfg.d_ff > 0 or cfg.is_moe_layer(pos)
    if has_mlp:
        s["ln2"] = norm_schema(cfg, cfg.d_model)
        if cfg.is_moe_layer(pos):
            W = ctx.model_size if ctx.active else 1
            s["moe"] = moe_schema(cfg, cfg.moe, W, ctx.etp)
        else:
            s["ffn"] = ffn_schema(cfg, cfg.d_model, cfg.d_ff)
    return s


# ---------------------------------------------------------------------------
# Apply — training / prefill
# ---------------------------------------------------------------------------


def attn_case(ctx: AxisCtx, a, Sq: int) -> str:
    """How attention shards over the model axis. Explicit (not left to the
    SPMD partitioner) because an indivisible head count otherwise makes XLA
    reshard INSIDE the chunked-attention scan loops — one collective per
    (q-block × kv-block) iteration, observed as ~1 TB/device of all-reduce
    on qwen2-0.5b (14 heads on a 16-way axis).

      heads  — Hq and Hkv both divide the axis: classic TP head sharding.
      qheads — only Hq divides: q sharded over heads, K/V replicated once
               per layer (GQA KV is small; Megatron-style).
      seq    — heads don't divide: sequence-parallel attention; K/V
               all-gathered once per layer, q/output stay seq-sharded.
      none   — nothing divides (tiny smoke shapes): replicate.
    """
    m = ctx.model_size
    if not ctx.active or m == 1:
        return "none"
    if a.n_heads % m == 0 and a.n_kv_heads % m == 0:
        return "heads"
    if a.n_heads % m == 0:
        return "qheads"
    if Sq % m == 0 and Sq > 1:
        return "seq"
    return "none"


def _attn_core(a, causal, use_rope, q_sharded, kv_sharded, mx,
               q4, k4, v4, qp, kp):
    """Local (per-shard) attention body. q4: (B, Sq_l, H_l, hd);
    k4/v4: (B, Sk, Hkv_l, hd); qp/kp: absolute positions (B, Sq_l)/(B, Sk).
    Runs under shard_map so fwd AND bwd are collective-free inside."""
    if use_rope:
        q4 = A.apply_rope(q4, qp, a.rope_theta)
        k4 = A.apply_rope(k4, kp, a.rope_theta)
    k_cache, v_cache = k4, v4                    # post-rope, pre-expansion
    H_l, Hkv_l = q4.shape[2], k4.shape[2]
    rep = a.n_heads // a.n_kv_heads
    if mx:
        r = jax.lax.axis_index(mx)
        head_base = r * H_l if q_sharded else 0
        kv_base = r * Hkv_l if kv_sharded else 0
    else:
        head_base = kv_base = 0
    # global q head -> local kv head (works for every sharding case)
    kv_map = (head_base + jnp.arange(H_l)) // rep - kv_base
    ke = jnp.take(k4, kv_map, axis=2)
    ve = jnp.take(v4, kv_map, axis=2)
    with jax.named_scope("__fusable__flash"):
        o = A.attention(q4, ke, ve, causal=causal, q_block=a.q_block,
                        kv_block=a.kv_block, q_pos=qp, kv_pos=kp)
    return o, k_cache, v_cache


def attn_apply(cfg, p, x, ctx: AxisCtx, positions, causal: bool,
               use_rope: bool = True, kv_x=None, return_kv: bool = False):
    a = cfg.attn
    src = x if kv_x is None else kv_x
    B, Sq, _ = x.shape
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    Sk = src.shape[1]
    q = q.reshape(B, Sq, a.n_heads, a.head_dim)
    k = k.reshape(B, Sk, a.n_kv_heads, a.head_dim)
    v = v.reshape(B, Sk, a.n_kv_heads, a.head_dim)
    if positions is None:
        positions = jnp.arange(Sq)[None, :]
    positions = jnp.broadcast_to(positions, (B, Sq))
    kv_positions = (positions if kv_x is None else
                    jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk)))

    Hq_real, Hkv_real = a.n_heads, a.n_kv_heads
    m = ctx.model_size
    padded = (a.pad_heads and ctx.active and m > 1
              and (a.n_heads % m or a.n_kv_heads % m))
    if padded:
        # pad KV heads up to the axis, keep the real group ratio for q
        rep = a.n_heads // a.n_kv_heads
        Hkv_p = -(-a.n_kv_heads // m) * m
        Hq_p = Hkv_p * rep
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hq_p - a.n_heads), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Hkv_p - a.n_kv_heads), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Hkv_p - a.n_kv_heads), (0, 0)))
        # dummy heads: zero K/V ⇒ uniform softmax over zero values ⇒ zero
        # output, and real q head h keeps kv head h//rep < Hkv_real
        a = dataclasses.replace(a, n_heads=Hq_p, n_kv_heads=Hkv_p)

    case = attn_case(ctx, a, Sq)
    mx = ctx.model_axis
    if case == "none" or not ctx.active:
        o, kc, vc = _attn_core(a, causal, use_rope, False, False,
                               None, q, k, v, positions, kv_positions)
    else:
        dp = ctx.dp_axes if B % max(1, ctx.dp_size) == 0 else None
        q_sharded = case in ("heads", "qheads")
        kv_sharded = case == "heads"
        q_spec = (P(dp, None, mx, None) if q_sharded
                  else P(dp, mx, None, None))
        kv_spec = (P(dp, None, mx, None) if kv_sharded
                   else P(dp, None, None, None))
        qp_spec = P(dp, None) if q_sharded else P(dp, mx)
        body = partial(_attn_core, a, causal, use_rope, q_sharded,
                       kv_sharded, mx)
        o, kc, vc = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, qp_spec, P(dp, None)),
            out_specs=(q_spec, kv_spec, kv_spec),
            check_vma=False)(q, k, v, positions, kv_positions)
    if padded:
        # drop dummy-head outputs / cache entries (exact: they are zero)
        o = o[:, :, :Hq_real]
        kc = kc[:, :, :Hkv_real]
        vc = vc[:, :, :Hkv_real]
    o = o.reshape(B, Sq, Hq_real * a.head_dim)
    if ctx.active and case not in ("none",):
        if case == "seq":
            o = _csp(o, ctx, ctx.dp_axes, mx, None)
        else:
            o = _csp(o, ctx, ctx.dp_axes, None, mx)
    out = o @ p["wo"]
    if return_kv:
        return out, (kc, vc)
    return out, None


def apply_layer(cfg, pos: int, p, x, ctx: AxisCtx, positions,
                enc_out=None, return_cache: bool = False):
    """Training / prefill path. Returns (x, aux_loss, cache_entry)."""
    kind = cfg.layer_kind(pos)
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "a":
        is_causal = cfg.attn.causal
        use_rope = cfg.attn.rope_theta > 0
        h, kv = attn_apply(cfg, p["attn"], h, ctx, positions, is_causal,
                           use_rope, return_kv=return_cache)
        if return_cache:
            cache_entry = {"k": kv[0], "v": kv[1]}
        x = x + h.astype(x.dtype)
        if enc_out is not None:
            hx = apply_norm(cfg, p["ln_x"], x)
            hx, xkv = attn_apply(cfg, p["xattn"], hx, ctx, positions,
                                 causal=False, use_rope=False, kv_x=enc_out,
                                 return_kv=return_cache)
            if return_cache:
                cache_entry["xk"], cache_entry["xv"] = xkv
            x = x + hx.astype(x.dtype)
    else:
        h, ssm_cache = S.ssm_forward(cfg, cfg.ssm, p["ssm"], h,
                                     return_cache=return_cache)
        if return_cache:
            cache_entry = ssm_cache
        x = x + h.astype(x.dtype)

    if "ln2" in p:
        h = apply_norm(cfg, p["ln2"], x)
        if "moe" in p:
            h = _csp(h, ctx, ctx.dp_axes,
                     ctx.model_axis if ctx.seq_shard and h.shape[1] > 1 else None,
                     None)
            h, aux = moe_ffn(cfg, cfg.moe, p["moe"], h, ctx,
                             n_col=cfg.moe.n_col_blocks)
            if "shared" in p["moe"]:
                h = h + ffn_apply(cfg, p["moe"]["shared"],
                                  apply_norm(cfg, p["ln2"], x))
        else:
            h = ffn_apply(cfg, p["ffn"], h)
        x = x + h.astype(x.dtype)
    sp = (cfg.sp_residual and ctx.active
          and x.shape[1] % max(1, ctx.model_size) == 0 and x.shape[1] > 1)
    x = _csp(x, ctx, ctx.dp_axes, ctx.model_axis if sp else None, None)
    return x, aux, cache_entry


# ---------------------------------------------------------------------------
# Apply — single-token decode with caches
# ---------------------------------------------------------------------------


def sharded_decode_attention(ctx: AxisCtx, a, q, k_cache, v_cache, t_pos):
    """Decode attention without gathering the cache.

    * Hkv divides the model axis → kv-group sharding: q reshaped
      (B,1,Hkv,rep,hd) and sharded with its kv head; zero collectives.
    * else S divides → split-KV flash decode: each rank reduces its cache
      shard to (m, l, acc) partials, merged by pmax + two psums of
      (B,H,1[,hd]) — ~kB per layer instead of all-gathering GBs of cache.
    * else → plain replicated decode.
    """
    B, S, Hkv, hd = k_cache.shape
    m = ctx.model_size
    if not ctx.active or m == 1:
        return A.decode_attention(q, k_cache, v_cache, t_pos)
    mx = ctx.model_axis
    dp = ctx.dp_axes if ctx.dp_size > 1 and B % ctx.dp_size == 0 else None
    H = q.shape[2]
    rep = H // Hkv
    if Hkv % m == 0:
        qg = q.reshape(B, 1, Hkv, rep, hd)

        def body(qk, kc, vc):
            qk = qk.reshape(B, 1, -1, hd)           # (B,1,Hkv_l*rep,hd)
            return A.decode_attention(qk, kc, vc, t_pos)

        o = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dp, None, mx, None, None),
                      P(dp, None, mx, None), P(dp, None, mx, None)),
            out_specs=P(dp, None, mx, None),
            check_vma=False)(qg, k_cache, v_cache)
        return o.reshape(B, 1, H, hd)
    if S % m == 0:
        S_loc = S // m

        def body(qf, kc, vc):
            off = jax.lax.axis_index(mx) * S_loc
            mm, ll, acc = A.decode_attention_partial(qf, kc, vc, t_pos, off)
            out = A.merge_decode_partials(mm, ll, acc, mx)   # (B,H,1,hd)
            return out.transpose(0, 2, 1, 3).astype(qf.dtype)

        return shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dp, None, None, None),
                      P(dp, mx, None, None), P(dp, mx, None, None)),
            out_specs=P(dp, None, None, None),
            check_vma=False)(q, k_cache, v_cache)
    return A.decode_attention(q, k_cache, v_cache, t_pos)


def decode_layer(cfg, pos: int, p, x, ctx: AxisCtx, cache, t_pos,
                 has_cross: bool = False):
    """x: (B, 1, d); cache: layer cache dict; t_pos: () int32 position.
    Returns (x, new_cache)."""
    kind = cfg.layer_kind(pos)
    a = cfg.attn
    new_cache = dict(cache) if cache is not None else None
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "a":
        B = x.shape[0]
        q = h @ p["attn"]["wq"]
        k = h @ p["attn"]["wk"]
        v = h @ p["attn"]["wv"]
        if "bq" in p["attn"]:
            q = q + p["attn"]["bq"].astype(q.dtype)
            k = k + p["attn"]["bk"].astype(k.dtype)
            v = v + p["attn"]["bv"].astype(v.dtype)
        q = q.reshape(B, 1, a.n_heads, a.head_dim)
        k = k.reshape(B, 1, a.n_kv_heads, a.head_dim)
        v = v.reshape(B, 1, a.n_kv_heads, a.head_dim)
        if a.rope_theta > 0:
            pos_arr = jnp.full((B, 1), t_pos, jnp.int32)
            q = A.apply_rope(q, pos_arr, a.rope_theta)
            k = A.apply_rope(k, pos_arr, a.rope_theta)
        kc, vc = A.update_cache(cache["k"], cache["v"], k, v, t_pos)
        new_cache["k"], new_cache["v"] = kc, vc
        o = sharded_decode_attention(ctx, a, q, kc, vc, t_pos)
        o = o.reshape(B, 1, a.n_heads * a.head_dim)
        h = o @ p["attn"]["wo"]
        x = x + h
        if has_cross:
            hx = apply_norm(cfg, p["ln_x"], x)
            qx = (hx @ p["xattn"]["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
            ox = A.dense_attention(qx, cache["xk"], cache["xv"], causal=False)
            hx = ox.reshape(B, 1, a.n_heads * a.head_dim) @ p["xattn"]["wo"]
            x = x + hx
    else:
        h, ssm_new = S.ssm_forward(cfg, cfg.ssm, p["ssm"], h, cache=cache)
        new_cache = ssm_new
        x = x + h

    if "ln2" in p:
        h = apply_norm(cfg, p["ln2"], x)
        if "moe" in p:
            h, _ = moe_ffn(cfg, cfg.moe, p["moe"], h, ctx)
            if "shared" in p["moe"]:
                h = h + ffn_apply(cfg, p["moe"]["shared"],
                                  apply_norm(cfg, p["ln2"], x))
        else:
            h = ffn_apply(cfg, p["ffn"], h)
        x = x + h
    return x, new_cache
