"""Layer blocks: (attention | mamba) + (dense FFN | MoE), schema + apply,
for train / prefill / decode modes."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.moe_layer import moe_ffn, moe_schema
from repro.models import attention as A
from repro.models import ssm as S
from repro.models.common import (apply_norm, ffn_apply, ffn_schema,
                                 norm_schema)
from repro.parallel.compat import shard_map
from repro.parallel.mesh import AxisCtx


def _csp(x, ctx: AxisCtx, *axes):
    """Sharding-constraint helper; no-op without a mesh."""
    if not ctx.active:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*axes)))


# ---------------------------------------------------------------------------
# Schema for one layer position
# ---------------------------------------------------------------------------


def layer_schema(cfg, pos: int, ctx: AxisCtx, cross: bool = False) -> Dict:
    kind = cfg.layer_kind(pos)
    s: Dict[str, Any] = {"ln1": norm_schema(cfg, cfg.d_model)}
    if kind == "a":
        s["attn"] = A.attn_schema(cfg, cfg.attn)
        if cross:
            s["ln_x"] = norm_schema(cfg, cfg.d_model)
            s["xattn"] = A.attn_schema(cfg, cfg.attn, cross=True)
    else:
        s["ssm"] = S.ssm_schema(cfg, cfg.ssm)
    has_mlp = cfg.d_ff > 0 or cfg.is_moe_layer(pos)
    if has_mlp:
        s["ln2"] = norm_schema(cfg, cfg.d_model)
        if cfg.is_moe_layer(pos):
            W = ctx.model_size if ctx.active else 1
            s["moe"] = moe_schema(cfg, cfg.moe, W, ctx.etp)
        else:
            s["ffn"] = ffn_schema(cfg, cfg.d_model, cfg.d_ff)
    return s


# ---------------------------------------------------------------------------
# Apply — training / prefill
# ---------------------------------------------------------------------------


def attn_case(ctx: AxisCtx, a, Sq: int) -> str:
    """How attention shards over the model axis. Explicit (not left to the
    SPMD partitioner) because an indivisible head count otherwise makes XLA
    reshard INSIDE the chunked-attention scan loops — one collective per
    (q-block × kv-block) iteration, observed as ~1 TB/device of all-reduce
    on qwen2-0.5b (14 heads on a 16-way axis).

      heads  — Hq and Hkv both divide the axis: classic TP head sharding.
      qheads — only Hq divides: q sharded over heads, K/V replicated once
               per layer (GQA KV is small; Megatron-style).
      seq    — heads don't divide: sequence-parallel attention; K/V
               all-gathered once per layer, q/output stay seq-sharded.
      none   — nothing divides (tiny smoke shapes): replicate.
    """
    m = ctx.model_size
    if not ctx.active or m == 1:
        return "none"
    if a.n_heads % m == 0 and a.n_kv_heads % m == 0:
        return "heads"
    if a.n_heads % m == 0:
        return "qheads"
    if Sq % m == 0 and Sq > 1:
        return "seq"
    return "none"


def _attn_core(a, causal, use_rope, q_sharded, kv_sharded, mx,
               q4, k4, v4, qp, kp, kvm):
    """Local (per-shard) attention body. q4: (B, Sq_l, H_l, hd);
    k4/v4: (B, Sk, Hkv_l, hd); qp/kp: absolute positions (B, Sq_l)/(B, Sk);
    kvm: (B, Sk) kv validity (pad mask) or None.
    Runs under shard_map so fwd AND bwd are collective-free inside."""
    if use_rope:
        q4 = A.apply_rope(q4, qp, a.rope_theta)
        k4 = A.apply_rope(k4, kp, a.rope_theta)
    k_cache, v_cache = k4, v4                    # post-rope, pre-expansion
    H_l, Hkv_l = q4.shape[2], k4.shape[2]
    rep = a.n_heads // a.n_kv_heads
    if mx:
        r = jax.lax.axis_index(mx)
        head_base = r * H_l if q_sharded else 0
        kv_base = r * Hkv_l if kv_sharded else 0
    else:
        head_base = kv_base = 0
    # global q head -> local kv head (works for every sharding case)
    kv_map = (head_base + jnp.arange(H_l)) // rep - kv_base
    ke = jnp.take(k4, kv_map, axis=2)
    ve = jnp.take(v4, kv_map, axis=2)
    with jax.named_scope("__fusable__flash"):
        o = A.attention(q4, ke, ve, causal=causal, q_block=a.q_block,
                        kv_block=a.kv_block, q_pos=qp, kv_pos=kp,
                        kv_mask=kvm)
    return o, k_cache, v_cache


def attn_apply(cfg, p, x, ctx: AxisCtx, positions, causal: bool,
               use_rope: bool = True, kv_x=None, return_kv: bool = False,
               kv_mask=None):
    a = cfg.attn
    src = x if kv_x is None else kv_x
    B, Sq, _ = x.shape
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    Sk = src.shape[1]
    q = q.reshape(B, Sq, a.n_heads, a.head_dim)
    k = k.reshape(B, Sk, a.n_kv_heads, a.head_dim)
    v = v.reshape(B, Sk, a.n_kv_heads, a.head_dim)
    if positions is None:
        positions = jnp.arange(Sq)[None, :]
    positions = jnp.broadcast_to(positions, (B, Sq))
    kv_positions = (positions if kv_x is None else
                    jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk)))

    Hq_real, Hkv_real = a.n_heads, a.n_kv_heads
    m = ctx.model_size
    padded = (a.pad_heads and ctx.active and m > 1
              and (a.n_heads % m or a.n_kv_heads % m))
    if padded:
        # pad KV heads up to the axis, keep the real group ratio for q
        rep = a.n_heads // a.n_kv_heads
        Hkv_p = -(-a.n_kv_heads // m) * m
        Hq_p = Hkv_p * rep
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hq_p - a.n_heads), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Hkv_p - a.n_kv_heads), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Hkv_p - a.n_kv_heads), (0, 0)))
        # dummy heads: zero K/V ⇒ uniform softmax over zero values ⇒ zero
        # output, and real q head h keeps kv head h//rep < Hkv_real
        a = dataclasses.replace(a, n_heads=Hq_p, n_kv_heads=Hkv_p)

    if kv_mask is not None:
        kv_mask = jnp.broadcast_to(kv_mask, (B, Sk))
    case = attn_case(ctx, a, Sq)
    mx = ctx.model_axis
    if case == "none" or not ctx.active:
        o, kc, vc = _attn_core(a, causal, use_rope, False, False,
                               None, q, k, v, positions, kv_positions,
                               kv_mask)
    else:
        dp = ctx.dp_axes if B % max(1, ctx.dp_size) == 0 else None
        q_sharded = case in ("heads", "qheads")
        kv_sharded = case == "heads"
        q_spec = (P(dp, None, mx, None) if q_sharded
                  else P(dp, mx, None, None))
        kv_spec = (P(dp, None, mx, None) if kv_sharded
                   else P(dp, None, None, None))
        qp_spec = P(dp, None) if q_sharded else P(dp, mx)
        body = partial(_attn_core, a, causal, use_rope, q_sharded,
                       kv_sharded, mx)
        if kv_mask is None:
            body_in = (lambda qq, kk, vv, qp, kp:
                       body(qq, kk, vv, qp, kp, None))
            specs = (q_spec, kv_spec, kv_spec, qp_spec, P(dp, None))
            args = (q, k, v, positions, kv_positions)
        else:
            body_in = body
            specs = (q_spec, kv_spec, kv_spec, qp_spec, P(dp, None),
                     P(dp, None))
            args = (q, k, v, positions, kv_positions, kv_mask)
        o, kc, vc = shard_map(
            body_in, mesh=ctx.mesh,
            in_specs=specs,
            out_specs=(q_spec, kv_spec, kv_spec),
            check_vma=False)(*args)
    if padded:
        # drop dummy-head outputs / cache entries (exact: they are zero)
        o = o[:, :, :Hq_real]
        kc = kc[:, :, :Hkv_real]
        vc = vc[:, :, :Hkv_real]
    o = o.reshape(B, Sq, Hq_real * a.head_dim)
    if ctx.active and case not in ("none",):
        if case == "seq":
            o = _csp(o, ctx, ctx.dp_axes, mx, None)
        else:
            o = _csp(o, ctx, ctx.dp_axes, None, mx)
    out = o @ p["wo"]
    if return_kv:
        return out, (kc, vc)
    return out, None


@dataclasses.dataclass(frozen=True)
class ExecSeg:
    """One EXECUTED segment of a block: a closure over an env dict of named
    values, with its dataflow declared (``reads`` / ``writes``) so
    core/schedule.py can derive dependencies and legally reorder emission.
    Reordering only permutes which segment is traced first over identical
    expressions, so any legal order is numerically identical."""
    name: str
    kind: str
    block: int
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    fn: Any                     # Callable[[Dict[str, Any]], None]


def block_segments(cfg, pos: int, p, ctx: AxisCtx, positions, enc_out=None,
                   return_cache: bool = False, mask=None, block: int = 0,
                   x_in: str = "x", x_out: str = "x_out"):
    """Lower one layer to its executed segment list. The residual stream
    enters as env[``x_in``] and leaves as env[``x_out``]; internal values
    are namespaced ``L{block}.*`` (aux loss at ``L{block}.aux``, cache
    entry at ``L{block}.cache``). The segment bodies are the EXACT
    expressions of the historical monolithic apply_layer — the lowering
    only names the intermediate values so the scheduler can see, e.g., that
    the MoE shared expert reads the mid residual and is independent of the
    dispatch/combine ring."""
    kind = cfg.layer_kind(pos)
    pr = f"L{block}."
    segs = []
    cross = kind == "a" and enc_out is not None
    xm0 = pr + ("xm0" if cross else "xm")
    xm = xm0

    if kind == "a":
        def f_attn(env):
            h = apply_norm(cfg, p["ln1"], env[x_in])
            h, kv = attn_apply(cfg, p["attn"], h, ctx, positions,
                               cfg.attn.causal, cfg.attn.rope_theta > 0,
                               return_kv=return_cache, kv_mask=mask)
            env[pr + "h0"] = h
            if return_cache:
                env[pr + "cache"] = {"k": kv[0], "v": kv[1]}

        segs.append(ExecSeg(pr + "attn", "attn", block, (x_in,),
                            (pr + "h0",) + ((pr + "cache",)
                                            if return_cache else ()),
                            f_attn))
    else:
        def f_ssm(env):
            h = apply_norm(cfg, p["ln1"], env[x_in])
            h, ssm_cache = S.ssm_forward(cfg, cfg.ssm, p["ssm"], h,
                                         return_cache=return_cache,
                                         mask=mask)
            env[pr + "h0"] = h
            if return_cache:
                env[pr + "cache"] = ssm_cache

        segs.append(ExecSeg(pr + "ssm", "ssm", block, (x_in,),
                            (pr + "h0",) + ((pr + "cache",)
                                            if return_cache else ()),
                            f_ssm))

    def f_res1(env):
        x = env[x_in]
        env[xm0] = x + env[pr + "h0"].astype(x.dtype)

    segs.append(ExecSeg(pr + "res1", "residual", block,
                        (x_in, pr + "h0"), (xm0,), f_res1))

    if cross:
        def f_xattn(env):
            hx = apply_norm(cfg, p["ln_x"], env[pr + "xm0"])
            hx, xkv = attn_apply(cfg, p["xattn"], hx, ctx, positions,
                                 causal=False, use_rope=False,
                                 kv_x=enc_out, return_kv=return_cache)
            env[pr + "hx"] = hx
            if return_cache:
                env[pr + "cache"]["xk"], env[pr + "cache"]["xv"] = xkv

        segs.append(ExecSeg(
            pr + "xattn", "attn", block,
            (pr + "xm0",) + ((pr + "cache",) if return_cache else ()),
            (pr + "hx",) + ((pr + "cache",) if return_cache else ()),
            f_xattn))
        xm = pr + "xm"

        def f_resx(env):
            x = env[pr + "xm0"]
            env[xm] = x + env[pr + "hx"].astype(x.dtype)

        segs.append(ExecSeg(pr + "resx", "residual", block,
                            (pr + "xm0", pr + "hx"), (xm,), f_resx))

    tail_reads = [xm]
    if "ln2" in p:
        if "moe" in p:
            def f_moe(env):
                h = apply_norm(cfg, p["ln2"], env[xm])
                h = _csp(h, ctx, ctx.dp_axes,
                         ctx.model_axis if ctx.seq_shard and h.shape[1] > 1
                         else None, None)
                h, aux = moe_ffn(cfg, cfg.moe, p["moe"], h, ctx,
                                 n_col=cfg.moe.n_col_blocks)
                env[pr + "h1"] = h
                env[pr + "aux"] = aux

            segs.append(ExecSeg(pr + "moe", "moe", block, (xm,),
                                (pr + "h1", pr + "aux"), f_moe))
            if "shared" in p["moe"]:
                # reads the MID residual only — independent of the ring,
                # the one executed segment the scheduler can hoist into it
                def f_shared(env):
                    env[pr + "hsh"] = ffn_apply(
                        cfg, p["moe"]["shared"],
                        apply_norm(cfg, p["ln2"], env[xm]))

                segs.append(ExecSeg(pr + "shared", "shared_ffn", block,
                                    (xm,), (pr + "hsh",), f_shared))
                tail_reads += [pr + "h1", pr + "hsh"]
            else:
                tail_reads += [pr + "h1"]
        else:
            def f_ffn(env):
                env[pr + "h1"] = ffn_apply(
                    cfg, p["ffn"], apply_norm(cfg, p["ln2"], env[xm]))

            segs.append(ExecSeg(pr + "ffn", "ffn", block, (xm,),
                                (pr + "h1",), f_ffn))
            tail_reads += [pr + "h1"]

    def f_tail(env):
        x = env[xm]
        if pr + "h1" in env:
            h = env[pr + "h1"]
            if pr + "hsh" in env:
                h = h + env[pr + "hsh"]
            x = x + h.astype(x.dtype)
        sp = (cfg.sp_residual and ctx.active
              and x.shape[1] % max(1, ctx.model_size) == 0
              and x.shape[1] > 1)
        x = _csp(x, ctx, ctx.dp_axes, ctx.model_axis if sp else None, None)
        env[x_out] = x

    segs.append(ExecSeg(pr + "res2", "residual", block, tuple(tail_reads),
                        (x_out,), f_tail))
    return segs


def run_segments(segs, env):
    """Execute segments in the given emission order against ``env``."""
    for s in segs:
        s.fn(env)
    return env


def apply_layer(cfg, pos: int, p, x, ctx: AxisCtx, positions,
                enc_out=None, return_cache: bool = False, mask=None):
    """Training / prefill path. Returns (x, aux_loss, cache_entry).
    mask: optional (B, S) validity — pad tokens are excluded from attention
    (kv_mask) and become identity steps in the SSM scan, so mixed-length
    left-padded prefill is exact.

    Implemented as the SEQUENTIAL interpretation of ``block_segments`` —
    the same lowering lm.forward_scheduled reorders across blocks."""
    segs = block_segments(cfg, pos, p, ctx, positions, enc_out=enc_out,
                          return_cache=return_cache, mask=mask, block=pos,
                          x_in="x", x_out="x_out")
    env = run_segments(segs, {"x": x})
    aux = env.get(f"L{pos}.aux")
    if aux is None:
        aux = jnp.zeros((), jnp.float32)
    return env["x_out"], aux, env.get(f"L{pos}.cache")


# ---------------------------------------------------------------------------
# Apply — single-token decode with caches
# ---------------------------------------------------------------------------


def _qkv_proj(a, p_attn, h):
    """Shared QKV projection + bias + head reshape for the cached paths
    (decode_layer / chunk_layer). h: (B, S, d) -> q/k/v (B, S, H*, hd)."""
    B, S, _ = h.shape
    q = h @ p_attn["wq"]
    k = h @ p_attn["wk"]
    v = h @ p_attn["wv"]
    if "bq" in p_attn:
        q = q + p_attn["bq"].astype(q.dtype)
        k = k + p_attn["bk"].astype(k.dtype)
        v = v + p_attn["bv"].astype(v.dtype)
    q = q.reshape(B, S, a.n_heads, a.head_dim)
    k = k.reshape(B, S, a.n_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.n_kv_heads, a.head_dim)
    return q, k, v


def _mlp_tail(cfg, p, x, ctx: AxisCtx):
    """Shared ln2 → (MoE | FFN) → residual tail for the cached paths."""
    if "ln2" not in p:
        return x
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        h, _ = moe_ffn(cfg, cfg.moe, p["moe"], h, ctx)
        if "shared" in p["moe"]:
            h = h + ffn_apply(cfg, p["moe"]["shared"],
                              apply_norm(cfg, p["ln2"], x))
    else:
        h = ffn_apply(cfg, p["ffn"], h)
    return x + h.astype(x.dtype)


def sharded_decode_attention(ctx: AxisCtx, a, q, k_cache, v_cache, t_pos,
                             kv_start=None, block_table=None):
    """Decode attention without gathering the cache. t_pos: () or (B,)
    per-row positions (slot-based decode); kv_start: optional ()/(B,) first
    valid cache index per row (left-padded prefill exclusion).
    block_table: optional (B, nb) int32 — the caches are then shared paged
    pools (n_pages, page, Hkv, hd) and rows read their logical view through
    the table.

    * Hkv divides the model axis → kv-group sharding: q reshaped
      (B,1,Hkv,rep,hd) and sharded with its kv head; zero collectives
      (paged pools shard the SAME way — the Hkv axis — with the block
      table replicated, so the per-shard gather stays local).
    * else S divides → split-KV flash decode: each rank reduces its cache
      shard to (m, l, acc) partials, merged by pmax + two psums of
      (B,H,1[,hd]) — ~kB per layer instead of all-gathering GBs of cache.
    * else → plain replicated decode.
    """
    B = q.shape[0]
    Hkv, hd = k_cache.shape[-2], k_cache.shape[-1]
    m = ctx.model_size
    if not ctx.active or m == 1:
        return A.decode_attention(q, k_cache, v_cache, t_pos, kv_start,
                                  block_table)
    S = k_cache.shape[1] if block_table is None else None
    mx = ctx.model_axis
    dp = ctx.dp_axes if ctx.dp_size > 1 and B % ctx.dp_size == 0 else None
    H = q.shape[2]
    rep = H // Hkv
    # per-row positions travel as explicit shard_map operands (sharded with
    # the batch like the tokens), never as closed-over values
    pos_v = jnp.broadcast_to(jnp.asarray(t_pos, jnp.int32).reshape(-1), (B,))
    start_v = (jnp.zeros((B,), jnp.int32) if kv_start is None else
               jnp.broadcast_to(jnp.asarray(kv_start, jnp.int32).reshape(-1),
                                (B,)))
    if Hkv % m == 0:
        qg = q.reshape(B, 1, Hkv, rep, hd)
        if block_table is not None:
            # paged pools shard on the Hkv axis; the block table rides along
            # replicated and each shard gathers its local head slice
            def body_p(qk, kc, vc, pv, sv, bt):
                qk = qk.reshape(qk.shape[0], 1, -1, hd)
                return A.decode_attention(qk, kc, vc, pv, sv, bt)

            o = shard_map(
                body_p, mesh=ctx.mesh,
                in_specs=(P(dp, None, mx, None, None),
                          P(None, None, mx, None), P(None, None, mx, None),
                          P(dp), P(dp), P(dp, None)),
                out_specs=P(dp, None, mx, None),
                check_vma=False)(qg, k_cache, v_cache, pos_v, start_v,
                                 block_table)
            return o.reshape(B, 1, H, hd)

        def body(qk, kc, vc, pv, sv):
            qk = qk.reshape(qk.shape[0], 1, -1, hd)  # (B_l,1,Hkv_l*rep,hd)
            return A.decode_attention(qk, kc, vc, pv, sv)

        o = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dp, None, mx, None, None),
                      P(dp, None, mx, None), P(dp, None, mx, None),
                      P(dp), P(dp)),
            out_specs=P(dp, None, mx, None),
            check_vma=False)(qg, k_cache, v_cache, pos_v, start_v)
        return o.reshape(B, 1, H, hd)
    if block_table is not None:
        # indivisible heads: paged pools stay replicated (split-KV does not
        # map onto the page pool layout — pages are position-interleaved)
        return A.decode_attention(q, k_cache, v_cache, t_pos, kv_start,
                                  block_table)
    if S % m == 0:
        S_loc = S // m

        def body(qf, kc, vc, pv, sv):
            off = jax.lax.axis_index(mx) * S_loc
            mm, ll, acc = A.decode_attention_partial(qf, kc, vc, pv, off, sv)
            out = A.merge_decode_partials(mm, ll, acc, mx)   # (B,H,1,hd)
            return out.transpose(0, 2, 1, 3).astype(qf.dtype)

        return shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(dp, None, None, None),
                      P(dp, mx, None, None), P(dp, mx, None, None),
                      P(dp), P(dp)),
            out_specs=P(dp, None, None, None),
            check_vma=False)(q, k_cache, v_cache, pos_v, start_v)
    return A.decode_attention(q, k_cache, v_cache, t_pos, kv_start)


def decode_layer(cfg, pos: int, p, x, ctx: AxisCtx, cache, t_pos,
                 has_cross: bool = False, rope_pos=None, kv_start=None,
                 block_table=None):
    """x: (B, 1, d); cache: layer cache dict; t_pos: () or (B,) int32 cache
    WRITE index per row. rope_pos: optional ()/(B,) RoPE position when it
    differs from the cache index (left-padded rows: real position = index -
    pad offset); kv_start: optional ()/(B,) first valid cache index.
    block_table: optional (B, nb) int32 — K/V cache entries are then shared
    paged pools and reads/writes go through per-row tables.
    Returns (x, new_cache)."""
    kind = cfg.layer_kind(pos)
    a = cfg.attn
    new_cache = dict(cache) if cache is not None else None
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "a":
        B = x.shape[0]
        q, k, v = _qkv_proj(a, p["attn"], h)
        if a.rope_theta > 0:
            rp = t_pos if rope_pos is None else rope_pos
            pos_arr = jnp.broadcast_to(
                jnp.asarray(rp, jnp.int32).reshape((-1, 1)), (B, 1))
            q = A.apply_rope(q, pos_arr, a.rope_theta)
            k = A.apply_rope(k, pos_arr, a.rope_theta)
        if block_table is not None:
            kc, vc = A.paged_update_cache(cache["k"], cache["v"], k, v,
                                          t_pos, block_table)
        else:
            kc, vc = A.update_cache(cache["k"], cache["v"], k, v, t_pos)
        new_cache["k"], new_cache["v"] = kc, vc
        o = sharded_decode_attention(ctx, a, q, kc, vc, t_pos, kv_start,
                                     block_table)
        o = o.reshape(B, 1, a.n_heads * a.head_dim)
        h = o @ p["attn"]["wo"]
        x = x + h
        if has_cross:
            hx = apply_norm(cfg, p["ln_x"], x)
            qx = (hx @ p["xattn"]["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
            ox = A.dense_attention(qx, cache["xk"], cache["xv"], causal=False)
            hx = ox.reshape(B, 1, a.n_heads * a.head_dim) @ p["xattn"]["wo"]
            x = x + hx
    else:
        h, ssm_new = S.ssm_forward(cfg, cfg.ssm, p["ssm"], h, cache=cache)
        new_cache = ssm_new
        x = x + h

    return _mlp_tail(cfg, p, x, ctx), new_cache


# ---------------------------------------------------------------------------
# Apply — chunked prefill against a per-slot cache region
# ---------------------------------------------------------------------------


def chunk_layer(cfg, pos: int, p, x, ctx: AxisCtx, cache, pos_off, q_pos,
                mask, valid_len, block_table=None):
    """One prompt CHUNK per admission row against its cache region: x
    (A, C, d) rows enter at cache indices [pos_off[a], pos_off[a] + C);
    queries attend over their OWN row's cache up to their own index
    (previous chunks included), so a prompt split into chunks reproduces
    the monolithic prefill exactly — and A > 1 rows admit several queued
    requests in one stacked call.

    pos_off: (A,) first cache index per row; q_pos: (A, C) absolute cache
    indices of the chunk tokens (index == RoPE position — slot prefill is
    right-anchored at 0); mask: (A, C) token validity (final partial
    chunk's tail AND rows whose prompt already ended in this stacked
    step); valid_len: (A,) valid-token counts. Tail-pad K/V land at
    indices > every valid query's position (causal-masked now, overwritten
    by the first decode steps before any query can reach them), and the
    SSM treats pads as identity steps, so the stitch is exact.
    block_table: optional (A, nb) int32 — K/V entries are then shared
    paged pools; pad/inactive tokens write the null page. Returns
    (x, new_cache)."""
    kind = cfg.layer_kind(pos)
    a = cfg.attn
    new_cache = dict(cache) if cache is not None else None
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "a":
        Bc, C, _ = x.shape
        q, k, v = _qkv_proj(a, p["attn"], h)
        if a.rope_theta > 0:
            q = A.apply_rope(q, q_pos, a.rope_theta)
            k = A.apply_rope(k, q_pos, a.rope_theta)
        if block_table is not None:
            kp, vp = A.paged_chunk_update(cache["k"], cache["v"], k, v,
                                          pos_off, block_table, mask)
            new_cache["k"], new_cache["v"] = kp, vp
            kc = A.paged_gather(kp, block_table)   # (A, nb*page, Hkv, hd)
            vc = A.paged_gather(vp, block_table)
        else:
            def row_upd(c, n, off):
                return jax.lax.dynamic_update_slice_in_dim(c, n, off, axis=0)

            kc = jax.vmap(row_upd)(cache["k"], k.astype(cache["k"].dtype),
                                   pos_off)
            vc = jax.vmap(row_upd)(cache["v"], v.astype(cache["v"].dtype),
                                   pos_off)
            new_cache["k"], new_cache["v"] = kc, vc
        S_tot = kc.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(S_tot)[None, :], (Bc, S_tot))
        o = A.attention(q, kc, vc, causal=True, q_block=a.q_block,
                        kv_block=a.kv_block, q_pos=q_pos, kv_pos=kv_pos)
        o = o.reshape(Bc, C, a.n_heads * a.head_dim)
        h = o @ p["attn"]["wo"]
        x = x + h.astype(x.dtype)
    else:
        h, ssm_new = S.ssm_forward(cfg, cfg.ssm, p["ssm"], h, cache=cache,
                                   mask=mask, valid_len=valid_len)
        new_cache = ssm_new
        x = x + h.astype(x.dtype)

    return _mlp_tail(cfg, p, x, ctx), new_cache
