"""Fault-tolerant training loop.

Production behaviours implemented (and unit-tested on CPU):

* **Checkpoint/restart** — periodic async atomic snapshots; on any step
  failure the loop restores the last committed checkpoint and replays from
  there. The synthetic data pipeline is a pure function of (seed, step), so a
  replayed run is bit-identical to an uninterrupted one.
* **Straggler mitigation** — per-step wall-time EWMA; a step slower than
  ``straggler_factor``× the EWMA is logged and counted. On a real fleet the
  monitor's callback triggers the elastic path below (we expose the same
  hook and drive it from tests via fault injection).
* **Elastic re-meshing** — ``reshard_state`` re-places a full training state
  onto a *different* mesh (fewer/more hosts) through host round-trip +
  ``device_put`` with the new NamedShardings; the step function is rebuilt
  for the new mesh and training resumes at the same step counter.
* **Grad-accumulation microbatching** lives in the jitted step
  (launch/train_step.py); the loop only feeds (accum, mb, ...) batches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.train_step import (abstract_state, build_train_step,
                                     state_specs)
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.parallel.mesh import make_mesh

Pytree = Any


class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (ICI/host stragglers)."""

    def __init__(self, factor: float = 2.5, alpha: float = 0.2,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged: List[int] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.factor * self.ewma)
        if is_straggler:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        else:  # don't poison the EWMA with outliers
            self.ewma = dt if self.ewma is None else (
                self.alpha * dt + (1 - self.alpha) * self.ewma)
        return is_straggler


def named_shardings(mesh, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def reshard_state(state: Pytree, new_mesh, new_spec_tree) -> Pytree:
    """Elastic path: move a live state onto a different mesh."""
    host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), state)
    sh = named_shardings(new_mesh, new_spec_tree)
    return jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), host, sh)


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.5
    seed: int = 0
    max_restarts: int = 3
    # non-finite guard: the train step skips the update IN-GRAPH when loss
    # or grad_norm goes NaN/inf (metrics["skipped"]); the trainer counts
    # skips and, after this many CONSECUTIVE ones, escalates to the normal
    # checkpoint/restore path (a persistent NaN means the optimizer state
    # itself is poisoned — replay from the last good snapshot).
    nan_limit: int = 3
    # tuned adaptive-transport plans (core/adaptive.py): every moe_ffn under
    # the jitted train step resolves its schedule — transport, ring_group,
    # n_col, gemm backend, AND the custom-VJP backward ring geometry — from
    # this cache, so tuned fwd+bwd schedules apply to training, not just to
    # the forward-only serving paths.
    plan_cache: str = ""
    plan_hw: str = ""


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                 tcfg: TrainerConfig = TrainerConfig(),
                 optim: Optional[AdamW] = None, fsdp: bool = True,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg
        self.optim = optim or AdamW()
        self.fsdp = fsdp
        self.fault_hook = fault_hook          # tests inject failures here
        self.built = build_train_step(cfg, shape, mesh, self.optim, fsdp=fsdp,
                                      plan_cache=tcfg.plan_cache,
                                      plan_hw=tcfg.plan_hw)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.monitor = StragglerMonitor(tcfg.straggler_factor)
        self.metrics_log: List[Dict[str, float]] = []
        self.nan_skips = 0                    # total skipped updates
        self._consec_nans = 0
        self.data = SyntheticLM(cfg, self.built["batch_structs"],
                                seed=tcfg.seed)

    # ------------------------------------------------------------------ state
    def init_state(self, key=None) -> Pytree:
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        ctx = self.built["ctx"]
        if self.mesh is None:
            params = lm.init_params(self.cfg, key, ctx)
            opt = self.optim.init(params)
            return {"params": params, "opt": opt,
                    "step": jax.numpy.zeros((), jax.numpy.int32)}
        sspecs = self.built["state_specs"]
        sh = named_shardings(self.mesh, sspecs)

        def make():
            params = lm.init_params(self.cfg, key, ctx)
            opt = self.optim.init(params)
            return {"params": params, "opt": opt,
                    "step": jax.numpy.zeros((), jax.numpy.int32)}

        return jax.jit(make, out_shardings=sh)()

    def restore_or_init(self) -> Tuple[Pytree, int]:
        target = abstract_state(self.cfg, self.built["ctx"])
        if self.ckpt.latest_step() is not None:
            sh = (named_shardings(self.mesh, self.built["state_specs"])
                  if self.mesh is not None else None)
            state, step = self.ckpt.restore(target, shardings=sh)
            return state, step
        return self.init_state(), 0

    # ------------------------------------------------------------------- run
    def _device_batch(self, np_batch):
        if self.mesh is None:
            return jax.tree_util.tree_map(jax.numpy.asarray, np_batch)
        sh = named_shardings(self.mesh, self.built["batch_pspecs"])
        return jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), dict(np_batch), dict(sh))

    def run(self, num_steps: int) -> Dict[str, Any]:
        """Train with checkpoint/restart. Returns summary dict."""
        state, start = self.restore_or_init()
        step = start
        restarts = 0
        while step < num_steps:
            try:
                state, step = self._run_span(state, step, num_steps)
            except Exception as e:  # node failure / injected fault
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise
                self.ckpt.wait()
                print(f"[trainer] failure after step {step} "
                      f"({type(e).__name__}: {e}); restoring from "
                      f"step {self.ckpt.latest_step() or 0} "
                      f"(restart {restarts}/{self.tcfg.max_restarts})")
                state, step = self.restore_or_init()
                self._consec_nans = 0
        self.ckpt.save(step, state, wait=True)
        return {"final_step": step, "restarts": restarts,
                "stragglers": list(self.monitor.flagged),
                "nan_skips": self.nan_skips,
                "metrics": self.metrics_log}

    def _apply_fault_hook(self, step, state):
        """Fault hooks come in two arities: ``(step)`` (legacy — raise to
        simulate a node failure) and ``(step, state) -> state`` (may also
        CORRUPT the state to exercise the non-finite guard)."""
        import inspect
        try:
            nparams = len(inspect.signature(self.fault_hook).parameters)
        except (TypeError, ValueError):
            nparams = 1
        if nparams >= 2:
            out = self.fault_hook(step, state)
            return state if out is None else out
        self.fault_hook(step)
        return state

    def _run_span(self, state, step, num_steps):
        jit_step = self.built["jit"]
        while step < num_steps:
            if self.fault_hook is not None:
                state = self._apply_fault_hook(step, state)
            batch = self._device_batch(self.data.batch_at(step))
            t0 = time.perf_counter()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])  # blocks; also surfaces NaN early
            dt = time.perf_counter() - t0
            step += 1
            self.monitor.observe(step, dt)
            skipped = bool(int(metrics.get("skipped", 0))) \
                or not np.isfinite(loss)
            if skipped:
                # the jitted step already refused the update in-graph (see
                # make_train_fn); count it, and escalate to checkpoint
                # replay once the skips stop being transient
                self.nan_skips += 1
                self._consec_nans += 1
                print(f"[trainer] step {step}: non-finite loss/grads — "
                      f"update skipped ({self._consec_nans} consecutive, "
                      f"{self.nan_skips} total)")
                if self._consec_nans > self.tcfg.nan_limit:
                    raise FloatingPointError(
                        f"{self._consec_nans} consecutive non-finite steps "
                        f"at step {step} (nan_limit {self.tcfg.nan_limit})")
            else:
                self._consec_nans = 0
            rec = {"step": step, "loss": loss, "time_s": dt,
                   "skipped": int(skipped),
                   "grad_norm": float(metrics.get("grad_norm", np.nan))}
            self.metrics_log.append(rec)
            if step % self.tcfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if step % self.tcfg.ckpt_every == 0 and self._consec_nans == 0:
                # never checkpoint mid-NaN-streak: the state that produced
                # a non-finite step must not become the restore point
                self.ckpt.save(step, state)
        return state, step

    # ----------------------------------------------------------- elastic path
    def rescale(self, state: Pytree, new_mesh) -> Pytree:
        """Re-mesh a live state (e.g. after losing a slice) and rebuild the
        step function. Returns the re-placed state."""
        self.mesh = new_mesh
        # the new mesh may imply a different (ep, etp) and local-token shape
        # — plan resolution re-keys automatically via the same cache
        self.built = build_train_step(self.cfg, self.shape, new_mesh,
                                      self.optim, fsdp=self.fsdp,
                                      plan_cache=self.tcfg.plan_cache,
                                      plan_hw=self.tcfg.plan_hw)
        if new_mesh is None:
            return jax.tree_util.tree_map(
                lambda x: jax.numpy.asarray(np.asarray(jax.device_get(x))), state)
        return reshard_state(state, new_mesh, self.built["state_specs"])


# ---------------------------------------------------------------------------
# Selftest entry (runs inside the forced-device-count subprocess)
# ---------------------------------------------------------------------------


def smoke_mesh_train(arch: str, n_dev: int, steps: int = 4) -> Tuple[float, float]:
    cfg = get_config(arch)
    mp = min(4, n_dev)
    dp = n_dev // mp
    mesh = make_mesh((dp, mp), ("data", "model"))
    shape = ShapeConfig("smoke", seq_len=64, global_batch=max(4, 2 * dp),
                        kind="train")
    import tempfile
    tcfg = TrainerConfig(ckpt_dir=tempfile.mkdtemp(prefix="repro_st_"),
                         ckpt_every=10_000, log_every=10_000)
    tr = Trainer(cfg, shape, mesh, tcfg)
    out = tr.run(steps)
    losses = [m["loss"] for m in out["metrics"]]
    return losses[0], losses[-1]
