from repro.serving.engine import (GenerateResult, Request,  # noqa: F401
                                  ServeEngine, stitch_prefill_cache)
from repro.serving.paged_cache import (BlockAllocator,  # noqa: F401
                                       PagedCacheConfig, pages_for)
