from repro.serving.engine import GenerateResult, ServeEngine  # noqa: F401
