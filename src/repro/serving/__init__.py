from repro.serving.engine import (GenerateResult, Request,  # noqa: F401
                                  ServeEngine, stitch_prefill_cache)
