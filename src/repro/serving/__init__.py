from repro.serving.engine import (GenerateResult, Request,  # noqa: F401
                                  RejectedRequest, RejectReason,
                                  RequestStatus, ServeEngine,
                                  stitch_prefill_cache)
from repro.serving.faults import (FaultInjector, FaultPlan,  # noqa: F401
                                  InjectedFault)
from repro.serving.paged_cache import (AllocatorError,  # noqa: F401
                                       BlockAllocator, PagedCacheConfig,
                                       pages_for)
