from repro.serving.disagg import (DecodeWorker,  # noqa: F401
                                  PrefillWorker, Router)
from repro.serving.engine import (EngineConfig, GenerateResult,  # noqa: F401
                                  Handoff, RejectedRequest, RejectReason,
                                  Request, RequestSpec, RequestStatus,
                                  ServeEngine, stitch_prefill_cache)
from repro.serving.faults import (FaultInjector, FaultPlan,  # noqa: F401
                                  InjectedFault)
from repro.serving.paged_cache import (AllocatorError,  # noqa: F401
                                       BlockAllocator, PagedCacheConfig,
                                       pages_for)
