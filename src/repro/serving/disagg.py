"""Disaggregated prefill/decode serving: the router/worker topology.

Prefill and decode have OPPOSITE profiles — prefill is a bursty,
compute-bound batch job; decode is a steady, latency-bound stream — and
production MoE serving (MegaScale-MoE, PAPERS.md) runs them on separate
worker pools so neither starves the other. This module builds that
topology out of the engine's worker API:

::

              submit() / generate()          typed admission
                       |                     (RequestSpec -> Request)
                  +---------+
                  | Router  |  bounded queue, shedding, deadlines,
                  +---------+  route hints, crash reconciliation
                   /       \\
        PrefillWorker      DecodeWorker         (N per role)
        role="prefill"      role="decode"
        chunked prefill     slot scheduler + paged KV pool
        :phprefill plans    :phdecode plans
               \\              /
                page-migration handoff
          (Handoff: content pages + SSM carry,
           export_pages -> import_pages)

* A :class:`PrefillWorker` admits queued requests and runs their prompt
  chunks; the moment a prefill finishes (the request's FIRST token is
  produced here — TTFT never waits on decode slot occupancy) the worker
  EXPORTS it as a :class:`~repro.serving.engine.Handoff` and forgets it,
  so its slots and pages turn over at prefill rate, not generation rate.
* The :class:`Router` migrates each handoff into the least-loaded
  :class:`DecodeWorker` (``migrate()`` — fresh pages via
  ``import_pages``, content scattered in, NO re-prefill), applying
  backpressure by simply holding the handoff until a decode pool has
  slot + pages.
* Token streams are BIT-EXACT vs a single ServeEngine: the prefill
  chunks, the migrated cache contents, and the per-row decode are the
  same computations on the same values, only the pool they live in
  changes.

EXACTLY-ONCE across the handoff boundary: every worker shares ONE
emission-watermark dict (the router's), the router holds each Handoff
until its request retires, and each worker keeps its own snapshot/
write-ahead-log recovery. A crashed prefill worker replays its queue and
re-exports — the router drops duplicate handoffs by rid. A crashed
decode worker restores its last snapshot — the router re-migrates any
rid the restore lost (from the held handoff; regeneration is
bit-identical and the shared watermark suppresses re-emission). The
chaos plan's ``crash_workers`` targets one (role, index) at a time
through role-scoped injectors, so this path is testable per worker.

The Router deliberately mirrors the ServeEngine streaming surface
(``submit/step/run/generate/cancel/collect/pending/finished``) — several
policy methods are REUSED from ServeEngine unbound (queue expiry,
shedding, spec coercion, batch generate), duck-typed on the same
attribute contract, so the two front-ends cannot drift apart.
"""
from __future__ import annotations

import os
import time
from collections import deque
from collections.abc import Mapping
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.serving.engine import (EngineConfig, Handoff, RejectReason,
                                  Request, RequestStatus, ServeEngine,
                                  _req_from_json, pages_for)


class PrefillWorker(ServeEngine):
    """Chunked-prefill engine (``role="prefill"``): admits queued
    requests, runs their prompt chunks through :phprefill plans, then
    exports every finished prefill into ``outbox`` as a page-migration
    :class:`Handoff` instead of decoding it. Prefill workers never
    decode, so after each step every live slot IS a finished prefill."""

    def __init__(self, cfg: ModelConfig, **kw):
        super().__init__(cfg, role="prefill", **kw)
        self.outbox: List[Handoff] = []

    def _after_phases(self):
        for slot in range(self.B):
            if self.live[slot] and self.slot_req[slot] is not None:
                self.outbox.append(self.export_handoff(slot))


class DecodeWorker(ServeEngine):
    """Slot-scheduler decode engine (``role="decode"``): requests enter
    ONLY via ``migrate()`` (page import) and run :phdecode plans against
    this worker's own paged pool. ``submit()`` is refused."""

    def __init__(self, cfg: ModelConfig, **kw):
        super().__init__(cfg, role="decode", **kw)


class Router:
    """Typed admission front-end + scheduler of the disaggregated
    topology. One ``step()`` is one tick of the whole fleet: expire →
    dispatch → prefill workers step → drain outboxes → migrate ready
    handoffs → decode workers step → collect finished. Workers step once
    per tick, so their monotonic step counters align with the router's
    and a chaos plan's ``crash_workers`` schedule means the same instant
    on every worker."""

    def __init__(self, cfg: ModelConfig, econfig: EngineConfig,
                 params=None, mesh=None,
                 clock: Optional[Callable[[], float]] = None,
                 on_token: Optional[Callable[[int, int, int], None]] = None,
                 faults="auto"):
        if not econfig.disagg:
            raise ValueError("Router needs an EngineConfig with disagg=True")
        ec = econfig
        self.cfg = cfg
        self.econfig = ec
        self._clock = clock or time.perf_counter
        self.on_token = on_token
        # router-level admission policy (workers get per-request deadlines
        # through the Request records; the bounded queue lives HERE)
        self.max_queue = ec.max_queue
        self.shed_policy = ec.shed_policy
        self.ttft_deadline_s = ec.ttft_deadline_s
        self.deadline_s = ec.deadline_s

        if faults == "auto":
            def injector(role):
                return ec.make_faults(role=role)
        elif faults is None or isinstance(faults, Mapping):
            def injector(role):
                return None if faults is None else faults.get(role)
        else:
            raise ValueError("faults must be 'auto', None, or a mapping "
                             "{(role, idx): FaultInjector}")
        recover = ec.recover
        if recover is None and ec.chaos_rate > 0:
            recover = True

        def subdir(role: str, i: int) -> Optional[str]:
            if ec.snapshot_dir is None:
                return None
            return os.path.join(ec.snapshot_dir, f"{role}{i}")

        common = dict(mesh=mesh, max_seq=ec.max_seq, chunk=ec.chunk,
                      seed=ec.seed, plan_cache=ec.plan_cache,
                      plan_hw=ec.plan_hw, page_size=ec.page_size,
                      admit_k=ec.admit_k, snapshot_every=ec.snapshot_every,
                      max_restarts=ec.max_restarts, recover=recover,
                      clock=clock, on_token=on_token)
        self.prefills: List[PrefillWorker] = []
        for i in range(ec.prefill_workers):
            w = PrefillWorker(cfg, params=params,
                              batch_size=ec.prefill_slots or ec.batch_size,
                              snapshot_dir=subdir("prefill", i),
                              faults=injector(("prefill", i)), **common)
            params = w.params            # init once, share across the fleet
            self.prefills.append(w)
        self.decodes: List[DecodeWorker] = []
        for i in range(ec.decode_workers):
            w = DecodeWorker(cfg, params=params,
                             batch_size=ec.decode_slots or ec.batch_size,
                             n_pages=ec.n_pages,
                             snapshot_dir=subdir("decode", i),
                             faults=injector(("decode", i)), **common)
            params = w.params
            self.decodes.append(w)
        self.params = params
        self.workers: List[ServeEngine] = [*self.prefills, *self.decodes]
        # legalized geometry comes FROM the workers (they divisor-snap
        # chunk/page); admission checks must see what they see
        self.max_seq = self.workers[0].max_seq
        self.page_size = self.workers[0].page_size
        self._pool_cap = min(min(w.n_pages - 1, w.max_blocks)
                             for w in self.workers)
        # ONE emission watermark across the fleet: exactly-once delivery
        # must survive a request moving between workers
        self.emitted: Dict[int, int] = {}
        for w in self.workers:
            w.emitted = self.emitted
        # router scheduler state
        self.queue: deque = deque()
        self.ready: deque = deque()               # rids awaiting migration
        self.handoffs: Dict[int, Handoff] = {}    # held until retire
        self.assigned: Dict[int, Tuple[str, int]] = {}  # rid -> (state, idx)
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self.step_idx = 0
        # accounting
        self.migrations = 0
        self.remigrations = 0          # decode-crash re-migrations
        self.duplicate_handoffs = 0    # prefill-crash replays deduped
        self.pages_moved = 0
        self.shed = 0
        self.expired = 0

    # the Router IS the engine's admission front-end: reuse its policy
    # methods unbound (same attribute contract — queue, clocks, counters),
    # so the two submission surfaces validate and batch identically
    _coerce_spec = ServeEngine._coerce_spec
    _reject = ServeEngine._reject
    _shed_victim = ServeEngine._shed_victim
    _expire_queued = ServeEngine._expire_queued
    run = ServeEngine.run
    collect = ServeEngine.collect
    generate = ServeEngine.generate

    # -- admission ----------------------------------------------------------

    def submit(self, request, max_new: int = 32,
               eos_id: Optional[int] = None,
               ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request with the fleet; returns its id. Validation
        matches ``ServeEngine.submit`` reason-for-reason (same spec
        coercion, same typed :class:`RejectedRequest`); capacity checks
        run against the TIGHTEST worker pool so an admitted request can
        always eventually prefill AND decode."""
        spec = self._coerce_spec(request, max_new, eos_id,
                                 ttft_deadline_s, deadline_s)
        req = Request(self._next_rid, list(spec.prompt), spec.max_new,
                      spec.eos_id, submit_t=self._clock(),
                      ttft_deadline_s=(self.ttft_deadline_s
                                       if spec.ttft_deadline_s is None
                                       else spec.ttft_deadline_s),
                      deadline_s=(self.deadline_s if spec.deadline_s is None
                                  else spec.deadline_s),
                      route_hint=spec.route_hint)
        self._next_rid += 1                    # rids stay unique on reject
        if spec.budget_tokens > self.max_seq:
            self._reject(req, RejectReason.TOO_LONG,
                         f"prompt {len(req.prompt)} + max_new "
                         f"{spec.max_new} exceeds max_seq {self.max_seq}")
        need = pages_for(spec.budget_tokens, self.page_size)
        if need > self._pool_cap:
            self._reject(req, RejectReason.OVER_CAPACITY,
                         f"request needs {need} pages, tightest worker "
                         f"pool holds {self._pool_cap}")
        if self.max_queue and len(self.queue) >= self.max_queue:
            victim = self._shed_victim(req)
            if victim is None:
                self._reject(req, RejectReason.QUEUE_FULL,
                             f"queue at max_queue={self.max_queue}")
            self._drop_queued(victim, RequestStatus.EXPIRED,
                              "shed: queue full")
            self.shed += 1
        req.status = RequestStatus.QUEUED
        self.queue.append(req)
        self.assigned[req.rid] = ("queued", -1)
        return req.rid

    def _drop_queued(self, req: Request, status: RequestStatus, error: str):
        self.queue.remove(req)
        self._finish(req, status, error)

    def _finish(self, req: Request, status: RequestStatus, error: str):
        req.status = status
        req.error = error
        req.done_t = self._clock()
        if req.length < 0:
            req.length = len(req.tokens)
        self.finished[req.rid] = req
        self.handoffs.pop(req.rid, None)
        self.assigned[req.rid] = ("done", -1)

    # -- scheduling ---------------------------------------------------------

    def _capacity(self, w: ServeEngine) -> int:
        free = sum(1 for s in range(w.B)
                   if not w.live[s] and w.slot_req[s] is None)
        return free - len(w.queue)

    def _pick_prefill(self, req: Request) -> Optional[int]:
        """Target prefill worker for the queue head: the route hint wins
        when it can admit (best-effort affinity), else the most-free
        worker that can. None = nobody can this tick (FIFO: wait, don't
        reorder around the head)."""
        budget = len(req.prompt) + req.max_new
        order = list(range(len(self.prefills)))
        hinted = None
        if req.route_hint is not None:
            hinted = req.route_hint % len(self.prefills)
        best, best_cap = None, 0
        for i in order:
            w = self.prefills[i]
            cap = self._capacity(w)
            if cap > 0 and w.alloc.can_admit(budget):
                if i == hinted:
                    return i
                if cap > best_cap:
                    best, best_cap = i, cap
        return best

    def _dispatch(self):
        while self.queue:
            req = self.queue[0]
            widx = self._pick_prefill(req)
            if widx is None:
                break
            self.queue.popleft()
            self.prefills[widx].enqueue(req)
            self.assigned[req.rid] = ("prefill", widx)

    def _drain_outboxes(self):
        for w in self.prefills:
            for h in w.outbox:
                st = self.assigned.get(h.rid, ("", -1))[0]
                if h.rid in self.handoffs or h.rid in self.finished \
                        or st in ("ready", "decode", "done"):
                    # a crash-replayed prefill re-exported a rid that
                    # already crossed the boundary: drop the duplicate
                    self.duplicate_handoffs += 1
                    continue
                self.handoffs[h.rid] = h
                self.ready.append(h.rid)
                self.assigned[h.rid] = ("ready", -1)
            w.outbox.clear()

    def _pick_decode(self, h: Handoff) -> Optional[int]:
        best, best_free = None, -1
        for i, w in enumerate(self.decodes):
            if w.can_import(h):
                free = sum(1 for s in range(w.B)
                           if not w.live[s] and w.slot_req[s] is None)
                if free > best_free:
                    best, best_free = i, free
        return best

    def _migrate_ready(self):
        while self.ready:
            rid = self.ready[0]
            h = self.handoffs[rid]
            widx = self._pick_decode(h)
            if widx is None or not self.decodes[widx].migrate(h):
                break        # backpressure: hold the handoff, stay FIFO
            self.ready.popleft()
            self.assigned[rid] = ("decode", widx)
            self.migrations += 1
            self.pages_moved += h.n_content_pages

    def _expire_ready(self):
        """Total-latency deadlines apply while a handoff waits for decode
        capacity, too — the prefill worker no longer owns the request."""
        now = self._clock()
        for rid in list(self.ready):
            h = self.handoffs[rid]
            d = h.req_json.get("deadline_s")
            if d is not None and now - h.req_json["submit_t"] > d:
                self.ready.remove(rid)
                req = _req_from_json(h.req_json)
                self._finish(req, RequestStatus.EXPIRED,
                             f"deadline {d:.3f}s exceeded awaiting "
                             f"decode capacity")
                self.expired += 1

    # -- worker stepping + crash reconciliation -----------------------------

    def _step_worker(self, role: str, idx: int, w: ServeEngine):
        before = w.recoveries
        w.step()
        if w.recoveries != before:
            # the worker restored a snapshot + replayed its log; patch up
            # whatever the restore cannot know about the rest of the fleet
            if role == "prefill":
                self._reconcile_prefill(w)
            else:
                self._reconcile_decode(idx, w)

    def _reconcile_prefill(self, w: PrefillWorker):
        """A recovered prefill worker replays every logged submission —
        including rids that already crossed the handoff boundary. Purge
        those from its queue (re-prefilling them would only produce
        duplicate handoffs for the dedup to drop)."""
        for r in list(w.queue):
            st = self.assigned.get(r.rid, ("", -1))[0]
            if st in ("ready", "decode", "done"):
                w.queue.remove(r)

    def _reconcile_decode(self, idx: int, w: DecodeWorker):
        """A recovered decode worker holds only what its last snapshot
        saw: any rid migrated to it AFTER that snapshot is gone from the
        restored state. Re-migrate those from the router-held handoffs —
        regeneration from the prefill position is bit-identical, and the
        shared emission watermark suppresses already-delivered tokens."""
        present = {r.rid for r in w.slot_req if r is not None}
        present |= set(w.finished)
        lost = sorted(rid for rid, (st, wi) in self.assigned.items()
                      if st == "decode" and wi == idx
                      and rid not in present)
        for rid in reversed(lost):        # extend left, keep rid order
            self.ready.appendleft(rid)
            self.assigned[rid] = ("ready", -1)
        self.remigrations += len(lost)

    # -- the fleet tick -----------------------------------------------------

    def step(self) -> bool:
        """One tick of the whole topology; returns whether work remains.
        Worker crashes recover inside ``w.step()`` (snapshot restore +
        log replay) and the router reconciles the boundary; an exception
        escaping here means a worker exhausted ``max_restarts`` — every
        in-flight request is then terminally failed before re-raising."""
        self.step_idx += 1
        try:
            self._expire_queued()
            self._expire_ready()
            self._dispatch()
            for i, w in enumerate(self.prefills):
                self._step_worker("prefill", i, w)
            self._drain_outboxes()
            self._migrate_ready()
            for i, w in enumerate(self.decodes):
                self._step_worker("decode", i, w)
            self._collect_finished()
        except Exception as e:
            self._fail_all(e)
            raise
        return self.pending

    def _collect_finished(self):
        for w in self.workers:
            for rid in list(w.finished):
                req = w.finished.pop(rid)
                if rid in self.finished:
                    continue    # duplicate terminal after a recovery race
                # NOT ServeEngine.collect: the emission watermark must
                # outlive worker-side retirement (a restore could replay
                # the tail of a finished stream) — it drops only when the
                # USER collects from the router
                self.finished[rid] = req
                self.handoffs.pop(rid, None)
                self.assigned[rid] = ("done", -1)

    def _fail_all(self, error: Exception):
        msg = f"router failure: {type(error).__name__}: {error}"
        for r in list(self.queue):
            self._drop_queued(r, RequestStatus.FAILED, msg)
        for rid in list(self.ready):
            self.ready.remove(rid)
            self._finish(_req_from_json(self.handoffs[rid].req_json),
                         RequestStatus.FAILED, msg)
        self._collect_finished()     # workers' own _fail_all records

    def cancel(self, rid: int) -> bool:
        """Cancel wherever the request currently lives: router queue,
        awaiting-migration handoff, or inside a worker."""
        for r in self.queue:
            if r.rid == rid:
                self._drop_queued(r, RequestStatus.CANCELLED, "cancelled")
                return True
        if rid in self.ready:
            self.ready.remove(rid)
            self._finish(_req_from_json(self.handoffs[rid].req_json),
                         RequestStatus.CANCELLED, "cancelled")
            return True
        for w in self.workers:
            if w.cancel(rid):
                req = w.finished.pop(rid)
                self._finish(req, RequestStatus.CANCELLED, req.error)
                return True
        return False

    # -- surface parity with ServeEngine ------------------------------------

    @property
    def pending(self) -> bool:
        return bool(self.queue) or bool(self.ready) \
            or any(w.pending or w.outbox for w in self.prefills) \
            or any(w.pending for w in self.decodes)

    @property
    def decode_steps(self) -> int:
        return sum(w.decode_steps for w in self.decodes)

    @property
    def prefill_tokens(self) -> int:
        return sum(w.prefill_tokens for w in self.workers)

    @property
    def decode_tokens(self) -> int:
        return sum(w.decode_tokens for w in self.workers)

    @property
    def failures(self) -> int:
        return sum(w.failures for w in self.workers)

    @property
    def recoveries(self) -> int:
        return sum(w.recoveries for w in self.workers)

    @property
    def quarantined(self) -> int:
        return sum(w.quarantined for w in self.workers)

    def summary(self) -> Dict:
        """Aggregate fleet accounting (the CLI's robustness summary)."""
        def agg(name: str) -> float:
            return sum(getattr(w, name) for w in self.workers)
        return {
            "requests_finished": len(self.finished),
            "migrations": self.migrations,
            "remigrations": self.remigrations,
            "duplicate_handoffs": self.duplicate_handoffs,
            "pages_moved": self.pages_moved,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_s": agg("prefill_s"),
            "decode_s": agg("decode_s"),
            "failures": self.failures,
            "recoveries": self.recoveries,
            "quarantined": self.quarantined,
            "expired": self.expired + int(agg("expired")),
            "shed": self.shed + int(agg("shed")),
            "per_worker": {
                f"prefill{i}": {"admissions": w.admissions,
                                "handoffs_out": w.handoffs_out,
                                "pages_exported": w.pages_exported,
                                "failures": w.failures,
                                "recoveries": w.recoveries}
                for i, w in enumerate(self.prefills)
            } | {
                f"decode{i}": {"migrations_in": w.migrations_in,
                               "pages_imported": w.pages_imported,
                               "decode_steps": w.decode_steps,
                               "failures": w.failures,
                               "recoveries": w.recoveries}
                for i, w in enumerate(self.decodes)
            },
        }
