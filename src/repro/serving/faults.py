"""Deterministic fault injection for the serving engine.

Production MoE serving lives or dies on operating through faults —
device losses, straggling hosts, poisoned activations, memory pressure —
and none of that is testable without a way to INJECT those faults into
the real engine loop on a repeatable schedule. This module provides:

* :class:`FaultPlan` — a seeded, step-indexed schedule of fault events
  (crashes, latency spikes, NaN logit rows, page-pool squeezes).
  ``FaultPlan.poisson`` draws a chaos schedule from independent per-step
  Bernoulli trials, so a whole chaos trace is one integer seed.
* :class:`FaultInjector` — applies a plan through a NARROW hook in
  ``ServeEngine.step()``: ``begin_step`` fires latency/pressure/crash
  events keyed on the engine's monotonic step counter, ``poison_rows``
  marks live decode rows whose logits the engine must treat as
  non-finite. The engine's own quarantine / recovery machinery then
  handles the fault exactly as it would a real one.

The injector is keyed on ``ServeEngine.step_idx``, which is MONOTONIC
across crash recovery (it never rolls back with a snapshot restore), so
an injected crash fires exactly once — replayed steps run fault-free
unless the plan schedules new events for them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Simulated device loss raised from inside ``ServeEngine.step()``."""

    def __init__(self, step: int, msg: str = ""):
        super().__init__(msg or f"injected device loss at step {step}")
        self.step = step


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Step-indexed fault schedule. All step indices refer to the engine's
    monotonic ``step_idx`` (1-based, never rolled back by recovery).

    * ``crash_steps`` — steps whose ``begin_step`` raises InjectedFault.
    * ``latency_s`` — step -> seconds of injected sleep (straggler spike).
    * ``nan_rows`` — step -> how many live decode rows get their logits
      treated as non-finite (per-row quarantine path).
    * ``page_squeeze`` — step -> (n_pages, hold_steps): temporarily claim
      free pages from the engine's allocator (memory-pressure admission
      stall), released ``hold_steps`` later.
    * ``crash_workers`` — step -> (role, index): crash ONE worker of the
      disaggregated topology (e.g. ``("decode", 0)``) at that step. Only
      role-scoped injectors (``FaultInjector(plan, role=...)``) fire
      these, and only the matching worker's injector raises — the router
      hands the same plan to every worker, so a single seed targets a
      single worker role across the whole fleet. Ignored by role-less
      (single-engine) injectors.
    """
    seed: int = 0
    crash_steps: Tuple[int, ...] = ()
    latency_s: Mapping[int, float] = dataclasses.field(default_factory=dict)
    nan_rows: Mapping[int, int] = dataclasses.field(default_factory=dict)
    page_squeeze: Mapping[int, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)
    crash_workers: Mapping[int, Tuple[str, int]] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def poisson(cls, seed: int, horizon: int, crash_rate: float = 0.02,
                nan_rate: float = 0.02, spike_rate: float = 0.05,
                spike_s: float = 0.02, squeeze_rate: float = 0.0,
                squeeze_pages: int = 2, squeeze_hold: int = 4,
                start: int = 2,
                workers: Tuple[Tuple[str, int], ...] = ()) -> "FaultPlan":
        """Chaos schedule: independent per-step Bernoulli draws for each
        fault class over ``[start, horizon)`` — the discrete analogue of a
        Poisson fault process. One seed reproduces the whole trace.

        With ``workers`` (disaggregated topology: a tuple of ``(role,
        index)`` targets), each crash draw hits one uniformly chosen
        worker and lands in ``crash_workers`` instead of ``crash_steps``
        — the whole-engine crash becomes a single-worker loss."""
        rng = np.random.default_rng(seed)
        crash, lat, nan, squeeze, wcrash = [], {}, {}, {}, {}
        for t in range(start, horizon):
            if rng.random() < crash_rate:
                if workers:
                    wcrash[t] = tuple(workers[int(rng.integers(len(workers)))])
                else:
                    crash.append(t)
            if rng.random() < spike_rate:
                lat[t] = spike_s
            if rng.random() < nan_rate:
                nan[t] = 1
            if rng.random() < squeeze_rate:
                squeeze[t] = (squeeze_pages, squeeze_hold)
        return cls(seed=seed, crash_steps=tuple(crash), latency_s=lat,
                   nan_rows=nan, page_squeeze=squeeze, crash_workers=wcrash)

    def summary(self) -> Dict[str, int]:
        return {"crash": len(self.crash_steps),
                "latency": len(self.latency_s),
                "nan": len(self.nan_rows),
                "page_squeeze": len(self.page_squeeze),
                "worker_crash": len(self.crash_workers)}


class FaultInjector:
    """Applies a :class:`FaultPlan` to a live engine through the narrow
    ``begin_step`` / ``poison_rows`` hook pair. Counts everything it
    injects (``counts``) and records an event log for assertions."""

    def __init__(self, plan: FaultPlan,
                 sleep: Callable[[float], None] = time.sleep,
                 role: Optional[Tuple[str, int]] = None):
        self.plan = plan
        self.sleep = sleep
        # role=(name, index) scopes this injector to ONE worker of a
        # disaggregated topology: only the plan's matching crash_workers
        # entries fire here (the router clones one plan across workers)
        self.role = tuple(role) if role is not None else None
        self.counts: Dict[str, int] = {"crash": 0, "latency": 0, "nan": 0,
                                       "page_squeeze": 0}
        self.events: List[Tuple[int, str]] = []
        self._squeezes: Dict[int, int] = {}      # pseudo-slot -> release step

    def begin_step(self, eng):
        """Fire this step's latency / page-pressure / crash events. Called
        first thing in ``ServeEngine.step()``; a raised InjectedFault is
        the simulated device loss the engine's recovery path handles."""
        t = eng.step_idx
        # release expired squeezes first so pressure is bounded
        for key, rel in list(self._squeezes.items()):
            if t >= rel:
                if eng.alloc is not None and eng.alloc.owns(key):
                    eng.alloc.free_slot(key)
                del self._squeezes[key]
        s = self.plan.latency_s.get(t)
        if s:
            self.counts["latency"] += 1
            self.events.append((t, f"latency {s:.3f}s"))
            self.sleep(s)
        sq = self.plan.page_squeeze.get(t)
        if sq and eng.paged:
            n_pages, hold = sq
            n_pages = min(n_pages, eng.alloc.free_pages,
                          eng.alloc.cfg.max_blocks)
            if n_pages > 0:
                key = -1000 - t          # pseudo-slot, never a real slot id
                eng.alloc.allocate(key, n_pages * eng.page_size)
                self._squeezes[key] = t + hold
                self.counts["page_squeeze"] += 1
                self.events.append((t, f"squeeze {n_pages} pages"))
        if self.role is not None:
            tgt = self.plan.crash_workers.get(t)
            if tgt is not None and tuple(tgt) == self.role:
                self.counts["crash"] += 1
                self.events.append((t, f"crash {self.role[0]}{self.role[1]}"))
                raise InjectedFault(
                    t, f"injected {self.role[0]}-worker {self.role[1]} "
                       f"loss at step {t}")
        if t in self.plan.crash_steps:
            self.counts["crash"] += 1
            self.events.append((t, "crash"))
            raise InjectedFault(t)

    def release_all(self, eng):
        """Drop every outstanding page squeeze (e.g. after the engine
        drains before a squeeze's scheduled release step)."""
        for key in list(self._squeezes):
            if eng.alloc is not None and eng.alloc.owns(key):
                eng.alloc.free_slot(key)
            del self._squeezes[key]

    def poison_rows(self, eng) -> List[int]:
        """Live decode rows whose logits the engine must treat as
        non-finite this step (deterministic per (seed, step))."""
        k = self.plan.nan_rows.get(eng.step_idx, 0)
        if not k:
            return []
        live = np.flatnonzero(eng.live)
        if live.size == 0:
            return []
        rng = np.random.default_rng((self.plan.seed, eng.step_idx))
        rows = rng.choice(live, size=min(k, live.size), replace=False)
        self.counts["nan"] += len(rows)
        self.events.append((eng.step_idx, f"nan rows {sorted(rows.tolist())}"))
        return [int(r) for r in rows]
