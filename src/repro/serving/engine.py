"""Continuous-batching serving engine: slot scheduler + masked chunked
prefill + per-row-position decode, with an optional paged block-table KV
cache.

Requests are ``submit()``-ed into a queue and admitted MID-FLIGHT into a
fixed pool of decode slots: a freed slot (eos / max_new) is refilled from
the queue on the next ``step()``, so the decode batch stays full under
streaming arrivals instead of draining to the slowest request. Admission
runs prompts through the chunked prefill step — and it is BATCHED: up to
``admit_k`` queued requests run their chunks in ONE stacked call per step
(per-row offsets/masks keep every row exact), so bursty arrivals no longer
serialize one prefill per request. Decoding advances every live slot at its
OWN position (vector positions, donated cache, live-slot mask). Mixed-length
batches are EXACT: pad/tail tokens are masked out of attention and are
identity steps in the SSM scan (MoE layers remain subject to per-chunk
capacity routing, the standard batched-MoE caveat).

With ``page_size > 0`` the K/V cache is PAGED (serving/paged_cache.py):
K/V live in shared fixed-size page pools, each request owns just enough
pages for its ``prompt + max_new`` budget through a block table, and pages
return to the free list at eos — so admission is gated on the FREE-PAGE
budget, not on ``slots × max_seq`` regions, and the same cache memory holds
``~max_seq / mean_request_budget`` times more live requests. SSM conv/SSD
state stay dense per-slot (they are O(1) per request).

The same engine runs on a mesh (pjit shardings from the step builders) or a
single device. Plans resolve per latency phase: the decode step looks up
``:phdecode`` entries (ranked on per-step latency — tiny-M shapes legalize
toward bcast/small ring groups), the chunk step ``:phprefill`` ones.

``generate(prompts, ...)`` remains as a convenience wrapper: submit all,
run to completion, return a batch result. Any number of prompts works —
more prompts than slots simply queue.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train_step import (build_decode_step,
                                     build_prefill_chunk_step)
from repro.models import lm
from repro.serving.paged_cache import BlockAllocator, pages_for


def stitch_prefill_cache(cfg, decode_cache, prefill_cache, prompt_len: int):
    """Insert prefill cache entries — stacked (n_periods, B, S, ...) from the
    layer scan — into the fixed-size decode cache at positions [0, S).
    Used by the batched (non-chunked) prefill path in tests/tools."""
    out = []
    for entry, pre in zip(decode_cache, prefill_cache):
        e = {}
        for k in entry:
            if k in ("k", "v"):
                e[k] = entry[k].at[:, :, :prompt_len].set(
                    pre[k].astype(entry[k].dtype))
            elif k in ("xk", "xv"):
                src = pre[k]
                e[k] = entry[k].at[:, :, :src.shape[2]].set(
                    src.astype(entry[k].dtype))
            elif k == "conv":
                e[k] = pre[k].astype(entry[k].dtype)
            else:                                   # ssm state (fp32)
                e[k] = pre[k]
        out.append(e)
    return tuple(out)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    lengths: np.ndarray         # (B,) tokens before eos/max
    prefill_tokens: int
    decode_steps: int


@dataclasses.dataclass
class Request:
    """One in-flight generation request (streaming API handle)."""
    rid: int
    prompt: List[int]
    max_new: int
    eos_id: Optional[int]
    tokens: List[int] = dataclasses.field(default_factory=list)
    length: int = -1            # tokens before eos; -1 while running
    slot: int = -1
    submit_t: float = 0.0
    first_token_t: float = 0.0  # TTFT = first_token_t - submit_t
    done_t: float = 0.0

    @property
    def done(self) -> bool:
        return self.length >= 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.submit_t


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, mesh=None,
                 max_seq: int = 256, batch_size: int = 4, seed: int = 0,
                 plan_cache: Optional[str] = None, plan_hw: str = "",
                 chunk: int = 0, page_size: int = 0, n_pages: int = 0,
                 admit_k: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.max_seq = max_seq
        self.B = batch_size                       # decode slots
        self.plan_cache = plan_cache
        # legalize the chunk to a divisor of max_seq: the chunk grid then
        # tiles the cache exactly and the last chunk of any admissible
        # prompt stays inside [0, max_seq) — otherwise the tail chunk's
        # dynamic_update_slice would CLAMP its start and silently corrupt
        # earlier chunks' K/V
        chunk = max(1, min(chunk or min(32, max_seq), max_seq))
        while max_seq % chunk:
            chunk -= 1
        self.chunk = chunk
        # paged block-table KV cache: page_size > 0 pools K/V as shared
        # fixed-size pages and admits against the free-page budget. The
        # page is legalized to a divisor of max_seq the same way (a block
        # table must tile [0, max_seq) exactly).
        if page_size:
            page_size = max(1, min(page_size, max_seq))
            while max_seq % page_size:
                page_size -= 1
        self.page_size = page_size
        self.paged = page_size > 0
        self.max_blocks = (max_seq // page_size) if self.paged else 0
        if self.paged and not n_pages:
            # parity capacity by default: every slot can still hold max_seq
            n_pages = batch_size * self.max_blocks + 1
        self.n_pages = n_pages if self.paged else 0
        # how many queued requests one step() may admit in ONE stacked
        # chunk call (0 = up to every free slot)
        self.admit_k = admit_k
        # ONE shape describes the shared donated cache: both steps derive
        # identical cache shardings from it on a mesh (paged: the K/V page
        # pools + per-slot SSM state)
        dshape = ShapeConfig("serve_decode", seq_len=max_seq,
                             global_batch=batch_size, kind="decode",
                             page_size=self.page_size, n_pages=self.n_pages)
        self.prefill = build_prefill_chunk_step(cfg, dshape, mesh,
                                                chunk=self.chunk,
                                                plan_cache=plan_cache,
                                                plan_hw=plan_hw)
        self.decode = build_decode_step(cfg, dshape, mesh,
                                        plan_cache=plan_cache,
                                        plan_hw=plan_hw)
        if params is None:
            params = lm.init_params(cfg, jax.random.PRNGKey(seed),
                                    self.prefill["ctx"])
        self.params = params
        # device state: the decode cache, donated through every chunk/decode
        # call — contiguous: one region (batch row) per slot; paged: shared
        # K/V page pools + dense per-slot SSM entries
        if self.paged:
            self.cache = lm.init_paged_cache(cfg, batch_size, self.n_pages,
                                             page_size, self.decode["ctx"])
            self.alloc = BlockAllocator(self.n_pages, page_size,
                                        self.max_blocks)
            self.block_tables = np.zeros((batch_size, self.max_blocks),
                                         np.int32)
        else:
            self.cache = lm.init_cache(cfg, batch_size, max_seq,
                                       self.decode["ctx"])
            self.alloc = None
            self.block_tables = None
        # host scheduler state
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros((batch_size,), np.int32)      # next write index
        self.live = np.zeros((batch_size,), bool)
        self.last_tok = np.zeros((batch_size,), np.int32)
        self.queue: deque = deque()
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        # per-phase accounting (the CLI summary prints these)
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.prefill_tokens = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.admissions = 0
        self.admit_rounds = 0       # stacked chunk-admission calls

    # -- streaming API ------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int = 32,
               eos_id: Optional[int] = None) -> int:
        """Queue a request; returns its id. Admission happens on the next
        ``step()`` (or immediately inside ``run()``)."""
        assert len(prompt) + max_new <= self.max_seq, "exceeds engine max_seq"
        assert len(prompt) > 0, "empty prompt"
        if self.paged:
            # a budget beyond the POOL capacity would never fit, and the
            # FIFO admission gate would stall on it (and everything queued
            # behind it) forever — reject it at the door instead
            need = pages_for(len(prompt) + max_new, self.page_size)
            assert need <= self.n_pages - 1, (
                f"request needs {need} pages, pool holds {self.n_pages - 1}")
        req = Request(self._next_rid, list(prompt), max_new, eos_id,
                      submit_t=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    @property
    def pending(self) -> bool:
        return bool(self.queue) or bool(self.live.any())

    @property
    def free_pages(self) -> int:
        """Free pages in the pool (paged mode; contiguous reports 0)."""
        return self.alloc.free_pages if self.paged else 0

    def _record_token(self, req: Request, tok: int, t_idx: int) -> bool:
        """Append a generated token; returns True when the request is done
        (eos — possibly on its very FIRST decoded token — or max_new)."""
        req.tokens.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            req.length = t_idx
            return True
        if t_idx + 1 >= req.max_new:
            req.length = req.max_new
            return True
        return False

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done_t = time.perf_counter()
        req.slot = -1
        self.finished[req.rid] = req
        self.slot_req[slot] = None
        self.live[slot] = False
        if self.paged:
            # pages back to the free list; the zeroed table row steers any
            # write from this (now dead) decode row into the null page
            self.alloc.free_slot(slot)
            self.block_tables[slot] = 0

    def _gather_admissions(self) -> List[Tuple[int, Request]]:
        """Pop queued requests (FIFO) into free slots, gating on the free-
        page budget in paged mode. Pages are claimed here, before the
        stacked chunk call, so the batch can never oversubscribe the pool.
        Admission stays in arrival order: when the head does not fit, we
        wait for pages rather than admitting around it."""
        k = self.admit_k or self.B
        free = [s for s in range(self.B) if not self.live[s]
                and self.slot_req[s] is None]
        pairs: List[Tuple[int, Request]] = []
        while self.queue and free and len(pairs) < k:
            req = self.queue[0]
            budget = len(req.prompt) + req.max_new
            if self.paged:
                if not self.alloc.can_admit(budget):
                    break
                slot = free.pop(0)
                pages = self.alloc.allocate(slot, budget)
                row = np.zeros((self.max_blocks,), np.int32)
                row[:len(pages)] = pages
                self.block_tables[slot] = row
            else:
                slot = free.pop(0)
            self.queue.popleft()
            pairs.append((slot, req))
        return pairs

    def _admit_batch(self, pairs: List[Tuple[int, Request]]):
        """Chunked prefill of every (slot, request) pair in ONE stacked call
        per chunk step: per-row offsets and tail masks keep rows exact, rows
        whose prompt already ended ride along as identity rows (their K/V
        writes are masked — paged: steered to the null page). Each request's
        first generated token comes from its LAST chunk's logits row.

        The stacked row count is padded UP to the next power of two using
        leftover FREE slots as all-identity parking rows (valid_len 0, so
        a parking row only scribbles on a free slot's region — scrubbed at
        its next admission anyway — or the null page): distinct XLA
        compiles stay O(log slots) instead of one per admission count."""
        t0 = time.perf_counter()
        C = self.chunk
        A = len(pairs)
        taken = {s for s, _ in pairs}
        parking = [s for s in range(self.B)
                   if not self.live[s] and self.slot_req[s] is None
                   and s not in taken]
        n_pad = min(len(parking),
                    (1 << max(0, A - 1).bit_length()) - A)
        slots = np.array([s for s, _ in pairs] + parking[:n_pad], np.int32)
        plens = np.array([len(r.prompt) for _, r in pairs] + [0] * n_pad,
                         np.int32)
        A = A + n_pad
        nchunks = np.maximum(1, -(-plens // C))
        fn = self.prefill["jit"]
        first_tok = np.zeros((A,), np.int32)
        for j in range(int(nchunks.max())):
            toks = np.zeros((A, C), np.int32)
            valids = np.clip(plens - j * C, 0, C).astype(np.int32)
            for a, (_, r) in enumerate(pairs):
                part = r.prompt[j * C:(j + 1) * C]
                toks[a, :len(part)] = part
            offs = np.full((A,), j * C, np.int32)
            args = (self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(offs), jnp.asarray(valids),
                    jnp.asarray(slots))
            if self.paged:
                bt = jnp.asarray(self.block_tables[slots])
                logits, self.cache = fn(*args, bt)
            else:
                logits, self.cache = fn(*args)
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            last = nchunks == j + 1
            first_tok[last] = nxt[last]
        self.prefill_s += time.perf_counter() - t0
        self.prefill_tokens += int(plens.sum())
        self.admissions += len(pairs)               # parking rows don't count
        self.admit_rounds += 1
        now = time.perf_counter()
        for a, (slot, req) in enumerate(pairs):
            req.slot = slot
            req.first_token_t = now
            self.slot_req[slot] = req
            self.pos[slot] = int(plens[a])
            self.last_tok[slot] = int(first_tok[a])
            self.live[slot] = True
            if self._record_token(req, int(first_tok[a]), 0):
                self._retire(slot)                # finished on token 0
        return pairs

    def step(self) -> bool:
        """One scheduler iteration: refill free slots from the queue (one
        stacked chunk-admission call for up to ``admit_k`` requests, gated
        on the free-page budget when paged), then advance every live slot
        by one decoded token. Returns whether any work remains."""
        pairs = self._gather_admissions()
        if pairs:
            self._admit_batch(pairs)
        if self.live.any():
            t0 = time.perf_counter()
            toks = jnp.asarray(self.last_tok[:, None])
            args = (self.params, self.cache, toks, jnp.asarray(self.pos),
                    jnp.asarray(self.live))
            if self.paged:
                nxt, _, self.cache = self.decode["jit"](
                    *args, jnp.asarray(self.block_tables))
            else:
                nxt, _, self.cache = self.decode["jit"](*args)
            nxt = np.asarray(nxt)[:, 0]
            self.decode_s += time.perf_counter() - t0
            self.decode_steps += 1
            self.decode_tokens += int(self.live.sum())
            for slot in range(self.B):
                if not self.live[slot]:
                    continue
                req = self.slot_req[slot]
                self.pos[slot] += 1
                self.last_tok[slot] = int(nxt[slot])
                if self._record_token(req, int(nxt[slot]), len(req.tokens)):
                    self._retire(slot)
        return self.pending

    def run(self) -> Dict[int, Request]:
        """Drain queue + slots; returns {rid: finished Request}."""
        while self.pending:
            self.step()
        return self.finished

    def collect(self, rid: int) -> Request:
        """Pop a finished request's record. Long-running streaming servers
        must collect results (or clear ``finished``) — the engine keeps a
        reference to every uncollected request, tokens included."""
        return self.finished.pop(rid)

    # -- batch convenience wrapper -----------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 eos_id: Optional[int] = None) -> GenerateResult:
        """Submit every prompt, run to completion, return a batch result
        (rows in submit order). More prompts than slots simply queue —
        freed slots are refilled mid-decode."""
        base_steps = self.decode_steps
        rids = [self.submit(p, max_new=max_new, eos_id=eos_id)
                for p in prompts]
        self.run()
        n = len(prompts)
        out = np.zeros((n, max_new), np.int32)
        lengths = np.zeros((n,), np.int64)
        for i, rid in enumerate(rids):
            req = self.collect(rid)
            t = req.tokens[:max_new]
            out[i, :len(t)] = t
            lengths[i] = req.length
        return GenerateResult(out, lengths,
                              prefill_tokens=sum(len(p) for p in prompts),
                              decode_steps=self.decode_steps - base_steps)
