"""Batched serving engine: prefill → KV-cache stitch → greedy decode loop.

Static-batch offline serving (the shape the decode_32k / long_500k cells
lower): requests are left-padded to a common prompt length, prefilled in one
jitted call, and decoded token-by-token with the donated-cache decode step.
Per-request stop handling masks finished rows. The same engine runs on a mesh
(pjit shardings from build_*_step) or a single device.

Limitation (documented): left padding carries no attention mask, so pad
tokens participate in attention for shorter prompts — exact parity with an
unpadded forward holds for equal-length prompts (tested); mixed lengths get
an approximation, as in mask-free batched-serving setups. Adding a prefill
pad mask is a straightforward extension of attention's kv_mask argument.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train_step import build_decode_step, build_prefill_step
from repro.models import lm


def stitch_prefill_cache(cfg, decode_cache, prefill_cache, prompt_len: int):
    """Insert prefill cache entries — stacked (n_periods, B, S, ...) from the
    layer scan — into the fixed-size decode cache at positions [0, S)."""
    out = []
    for entry, pre in zip(decode_cache, prefill_cache):
        e = {}
        for k in entry:
            if k in ("k", "v"):
                e[k] = entry[k].at[:, :, :prompt_len].set(
                    pre[k].astype(entry[k].dtype))
            elif k in ("xk", "xv"):
                src = pre[k]
                e[k] = entry[k].at[:, :, :src.shape[2]].set(
                    src.astype(entry[k].dtype))
            elif k == "conv":
                e[k] = pre[k].astype(entry[k].dtype)
            else:                                   # ssm state (fp32)
                e[k] = pre[k]
        out.append(e)
    return tuple(out)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    lengths: np.ndarray         # (B,) tokens before eos/max
    prefill_tokens: int
    decode_steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, mesh=None,
                 max_seq: int = 256, batch_size: int = 4, seed: int = 0,
                 plan_cache: Optional[str] = None, plan_hw: str = ""):
        self.cfg = cfg
        self.mesh = mesh
        self.max_seq = max_seq
        self.B = batch_size
        self.plan_cache = plan_cache
        pshape = ShapeConfig("serve_prefill", seq_len=max_seq,
                             global_batch=batch_size, kind="prefill")
        dshape = ShapeConfig("serve_decode", seq_len=max_seq,
                             global_batch=batch_size, kind="decode")
        self.prefill = build_prefill_step(cfg, pshape, mesh,
                                          plan_cache=plan_cache,
                                          plan_hw=plan_hw)
        self.decode = build_decode_step(cfg, dshape, mesh,
                                        plan_cache=plan_cache,
                                        plan_hw=plan_hw)
        if params is None:
            params = lm.init_params(cfg, jax.random.PRNGKey(seed),
                                    self.prefill["ctx"])
        self.params = params

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 eos_id: Optional[int] = None) -> GenerateResult:
        B = len(prompts)
        assert B == self.B, f"engine compiled for batch {self.B}, got {B}"
        plen = max(len(p) for p in prompts)
        assert plen + max_new <= self.max_seq, "exceeds engine max_seq"
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p              # left-pad to align last
        batch = {"tokens": jnp.asarray(toks)}

        # ---- prefill: one jitted call over the whole padded batch ---------
        logits, pre_cache = self.prefill["fn"](self.params, batch)
        cache = lm.init_cache(self.cfg, B, self.max_seq,
                              self.prefill["ctx"])
        cache = stitch_prefill_cache(self.cfg, cache, pre_cache, plen)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

        # ---- greedy decode loop -------------------------------------------
        out = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        lengths = np.full((B,), max_new, np.int64)
        step_fn = self.decode["jit"]
        steps = 0
        for t in range(max_new):
            out[:, t] = np.asarray(nxt[:, 0])
            if eos_id is not None:
                newly = (out[:, t] == eos_id) & ~done
                lengths[newly] = t
                done |= newly
                if done.all():
                    steps = t + 1
                    break
            nxt, _, cache = step_fn(self.params, cache, nxt,
                                    jnp.int32(plen + t))
            steps = t + 1
        return GenerateResult(out, lengths, prefill_tokens=B * plen,
                              decode_steps=steps)
