"""Continuous-batching serving engine: slot scheduler + masked chunked
prefill + per-row-position decode, with an optional paged block-table KV
cache and a production fault model.

Requests are ``submit()``-ed into a queue and admitted MID-FLIGHT into a
fixed pool of decode slots: a freed slot (eos / max_new) is refilled from
the queue on the next ``step()``, so the decode batch stays full under
streaming arrivals instead of draining to the slowest request. Admission
runs prompts through the chunked prefill step — and it is BATCHED: up to
``admit_k`` queued requests run their chunks in ONE stacked call per step
(per-row offsets/masks keep every row exact), so bursty arrivals no longer
serialize one prefill per request. Decoding advances every live slot at its
OWN position (vector positions, donated cache, live-slot mask). Mixed-length
batches are EXACT: pad/tail tokens are masked out of attention and are
identity steps in the SSM scan (MoE layers remain subject to per-chunk
capacity routing, the standard batched-MoE caveat).

With ``page_size > 0`` the K/V cache is PAGED (serving/paged_cache.py):
K/V live in shared fixed-size page pools, each request owns just enough
pages for its ``prompt + max_new`` budget through a block table, and pages
return to the free list at eos — so admission is gated on the FREE-PAGE
budget, not on ``slots × max_seq`` regions.

ROBUSTNESS MODEL (mirrors the trainer's checkpoint/restart + straggler
machinery for the serving workload):

* Every request carries a terminal ``status`` — ``ok / rejected /
  cancelled / expired / quarantined / failed`` — and malformed submissions
  raise a typed :class:`RejectedRequest` (reason enum) instead of killing
  the engine with an assert.
* Per-request DEADLINES (TTFT + total latency) are checked at step
  boundaries; a bounded queue (``max_queue``) sheds load via a pluggable
  policy (reject-new, or deadline-aware drop of the least-slack request).
* ``cancel(rid)`` works on queued AND live requests, freeing the slot and
  its pages immediately.
* Non-finite logits are QUARANTINED per row: the poisoned request retires
  with ``status="quarantined"`` and the rest of the batch is untouched.
* ``snapshot()/restore()`` capture the full scheduler state (queue,
  slot↔request map, positions, page allocator) together with the KV/SSM
  pools through checkpoint/manager.py's atomic writer; on a step failure
  the engine restores the last snapshot and REPLAYS — an in-memory event
  log of post-snapshot submits/cancels closes the gap, and a monotonic
  per-request emission watermark makes token delivery EXACTLY-ONCE
  (replayed tokens below the watermark are regenerated bit-identically
  but never re-emitted).
* A :class:`~repro.serving.faults.FaultInjector` plugs into a narrow hook
  in ``step()`` to drive all of the above deterministically.

``generate(prompts, ...)`` remains as a convenience wrapper: submit all,
run to completion, return a batch result. Any number of prompts works —
more prompts than slots simply queue. Prompts may be raw token sequences
or typed :class:`RequestSpec` values; a malformed prompt surfaces its
:class:`RejectedRequest` per-row instead of aborting the batch.

WORKER API (the disaggregated topology in serving/disagg.py builds on
these — they are first-class engine API, not internals):

* ``prefill_step()`` — queued-deadline expiry + one stacked chunk-
  admission call; ``decode_step()`` — one decoded token per live slot +
  live-deadline expiry. ``step()`` is exactly ``prefill_step(); decode_
  step()`` under the fault/snapshot envelope; a ``role``-restricted
  engine (``role="prefill"`` / ``"decode"``) builds only the step it
  runs and skips the other entirely.
* ``export_handoff(slot)`` / ``migrate(handoff)`` — KV handoff as paged-
  page MIGRATION: a finished prefill's page contents (+ per-slot SSM
  carry) move into another engine's pool through a :class:`Handoff`
  record, so the decode worker resumes at the prefill position without
  re-prefill, bit-exact vs the single-engine path.
* :class:`EngineConfig` — one construction surface (config groups:
  engine / paging / robustness / chaos / disagg) shared by the CLI and
  the benchmarks; ``EngineConfig.build()`` returns a ServeEngine, or the
  Router topology when ``disagg`` is set.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import (Callable, Dict, List, Optional, Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train_step import (build_decode_step,
                                     build_prefill_chunk_step)
from repro.models import lm
from repro.serving.paged_cache import BlockAllocator, pages_for
from repro.training.trainer import StragglerMonitor


def stitch_prefill_cache(cfg, decode_cache, prefill_cache, prompt_len: int):
    """Insert prefill cache entries — stacked (n_periods, B, S, ...) from the
    layer scan — into the fixed-size decode cache at positions [0, S).
    Used by the batched (non-chunked) prefill path in tests/tools."""
    out = []
    for entry, pre in zip(decode_cache, prefill_cache):
        e = {}
        for k in entry:
            if k in ("k", "v"):
                e[k] = entry[k].at[:, :, :prompt_len].set(
                    pre[k].astype(entry[k].dtype))
            elif k in ("xk", "xv"):
                src = pre[k]
                e[k] = entry[k].at[:, :, :src.shape[2]].set(
                    src.astype(entry[k].dtype))
            elif k == "conv":
                e[k] = pre[k].astype(entry[k].dtype)
            else:                                   # ssm state (fp32)
                e[k] = pre[k]
        out.append(e)
    return tuple(out)


# ---------------------------------------------------------------------------
# Request lifecycle types
# ---------------------------------------------------------------------------


class RequestStatus(str, enum.Enum):
    """Lifecycle states. QUEUED/RUNNING are transient; the rest terminal."""
    QUEUED = "queued"
    RUNNING = "running"
    OK = "ok"
    REJECTED = "rejected"
    CANCELLED = "cancelled"
    EXPIRED = "expired"
    QUARANTINED = "quarantined"
    FAILED = "failed"


TERMINAL_STATUSES = frozenset({
    RequestStatus.OK, RequestStatus.REJECTED, RequestStatus.CANCELLED,
    RequestStatus.EXPIRED, RequestStatus.QUARANTINED, RequestStatus.FAILED})


class RejectReason(str, enum.Enum):
    EMPTY_PROMPT = "empty_prompt"
    TOO_LONG = "too_long"               # prompt + max_new > max_seq
    OVER_CAPACITY = "over_capacity"     # page budget beyond the whole pool
    QUEUE_FULL = "queue_full"           # bounded queue, shed policy said no
    INVALID = "invalid"                 # spec field failed validation


class RejectedRequest(Exception):
    """Typed submission rejection. Carries the reason enum and the
    (terminal, status=rejected) request record; the engine stays fully
    serviceable after raising this."""

    def __init__(self, reason: RejectReason, msg: str, request=None):
        super().__init__(f"{reason.value}: {msg}")
        self.reason = reason
        self.msg = msg
        self.request = request


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """Typed submission: everything ``submit()`` accepts, as ONE validated
    value object — replacing the growing kwarg sprawl (``max_new`` /
    ``eos_id`` / ``ttft_deadline_s`` / ``deadline_s`` / routing hints).
    The kwargs path on ``submit()``/``generate()`` still works and builds
    the spec internally, so both doors validate identically.

    Validation runs in ``__post_init__`` and raises
    :class:`RejectedRequest` (reason ``EMPTY_PROMPT`` / ``INVALID``) for
    anything malformed in ISOLATION; engine-relative checks (``TOO_LONG``
    / ``OVER_CAPACITY`` / ``QUEUE_FULL``) stay in ``submit()``, where the
    engine geometry is known. Deadlines of None inherit the engine
    defaults at submit time. ``route_hint`` is a disaggregated-topology
    hint — preferred prefill-worker index (best-effort; the Router wraps
    it into range, a single engine ignores it)."""
    prompt: Tuple[int, ...]
    max_new: int = 32
    eos_id: Optional[int] = None
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    route_hint: Optional[int] = None

    def __post_init__(self):
        if isinstance(self.prompt, (str, bytes)):
            raise RejectedRequest(
                RejectReason.INVALID,
                "prompt must be a sequence of token ids, not text")
        try:
            prompt = tuple(int(t) for t in self.prompt)
        except (TypeError, ValueError) as e:
            raise RejectedRequest(
                RejectReason.INVALID,
                f"prompt must be a sequence of token ids ({e})") from e
        object.__setattr__(self, "prompt", prompt)
        if not prompt:
            raise RejectedRequest(RejectReason.EMPTY_PROMPT, "empty prompt")
        if not isinstance(self.max_new, (int, np.integer)) or \
                self.max_new < 1:
            raise RejectedRequest(
                RejectReason.INVALID,
                f"max_new must be a positive int, got {self.max_new!r}")
        if self.eos_id is not None and \
                not isinstance(self.eos_id, (int, np.integer)):
            raise RejectedRequest(
                RejectReason.INVALID,
                f"eos_id must be an int or None, got {self.eos_id!r}")
        for name in ("ttft_deadline_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, (int, float))
                                  or isinstance(v, bool) or v <= 0):
                raise RejectedRequest(
                    RejectReason.INVALID,
                    f"{name} must be a positive number or None, got {v!r}")
        if self.route_hint is not None and \
                (not isinstance(self.route_hint, (int, np.integer))
                 or self.route_hint < 0):
            raise RejectedRequest(
                RejectReason.INVALID,
                f"route_hint must be a worker index >= 0 or None, "
                f"got {self.route_hint!r}")

    @property
    def budget_tokens(self) -> int:
        """Cache budget this request admits against (prompt + max_new)."""
        return len(self.prompt) + self.max_new


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    lengths: np.ndarray         # (B,) tokens before eos/max
    prefill_tokens: int
    decode_steps: int
    # per-row terminal status values + the typed rejection for each row
    # that never entered the engine (malformed prompt); appended after the
    # original fields so positional construction stays compatible
    statuses: List[str] = dataclasses.field(default_factory=list)
    rejected: Dict[int, RejectedRequest] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class Request:
    """One in-flight generation request (streaming API handle)."""
    rid: int
    prompt: List[int]
    max_new: int
    eos_id: Optional[int]
    tokens: List[int] = dataclasses.field(default_factory=list)
    length: int = -1            # tokens before eos; -1 while running
    slot: int = -1
    submit_t: float = 0.0
    first_token_t: float = 0.0  # TTFT = first_token_t - submit_t
    done_t: float = 0.0
    status: RequestStatus = RequestStatus.QUEUED
    error: str = ""
    ttft_deadline_s: Optional[float] = None   # first token within this
    deadline_s: Optional[float] = None        # whole request within this
    route_hint: Optional[int] = None          # preferred prefill worker

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.submit_t


_REQ_FIELDS = ("rid", "prompt", "max_new", "eos_id", "tokens", "length",
               "slot", "submit_t", "first_token_t", "done_t", "error",
               "ttft_deadline_s", "deadline_s", "route_hint")


def _req_to_json(r: Request) -> Dict:
    d = {k: getattr(r, k) for k in _REQ_FIELDS}
    d["status"] = r.status.value
    return d


def _req_from_json(d: Dict) -> Request:
    # .get: route_hint is absent from pre-disagg snapshots/logs
    kw = {k: d.get(k) if k == "route_hint" else d[k] for k in _REQ_FIELDS}
    kw["prompt"] = list(kw["prompt"])
    kw["tokens"] = list(kw["tokens"])
    return Request(status=RequestStatus(d["status"]), **kw)


@dataclasses.dataclass(frozen=True)
class Handoff:
    """One finished prefill crossing the worker boundary — everything a
    decode pool needs to resume the request at its prefill position
    WITHOUT re-prefill. The page CONTENTS ride the handoff as immutable
    gathered arrays (detached from the exporting pool, which reclaims its
    pages the moment the export returns), so the record stays valid even
    if the exporting worker crashes, restores, or reuses the pages — the
    router re-migrates from the same record after a decode-worker loss.

    ``pages`` is the SOURCE pool's page-id list for the request's full
    ``prompt + max_new`` budget (what admission allocated); only the
    ``n_content_pages`` prefix holds written K/V and travels in ``kv`` —
    the tail pages' contents are garbage on both sides, masked by
    position validity exactly like a reused contiguous slot."""
    rid: int
    req_json: Dict              # request state at handoff (tokens=[first])
    pos: int                    # cache position = prompt length
    last_tok: int               # feeds the first decode step
    budget_tokens: int          # prompt + max_new (import page budget)
    pages: Tuple[int, ...]      # source page ids, block-table order
    block_table: Tuple[int, ...]  # source row (import cross-check)
    n_content_pages: int        # written prefix actually copied
    kv: Tuple                   # per cache entry: K/V page gather | SSM row


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, mesh=None,
                 max_seq: int = 256, batch_size: int = 4, seed: int = 0,
                 plan_cache: Optional[str] = None, plan_hw: str = "",
                 chunk: int = 0, page_size: int = 0, n_pages: int = 0,
                 admit_k: int = 0, max_queue: int = 0,
                 shed_policy: Union[str, Callable] = "reject",
                 ttft_deadline_s: Optional[float] = None,
                 deadline_s: Optional[float] = None,
                 snapshot_dir: Optional[str] = None, snapshot_every: int = 8,
                 max_restarts: int = 3, recover: Optional[bool] = None,
                 faults=None, straggler_factor: float = 2.5,
                 clock: Optional[Callable[[], float]] = None,
                 on_token: Optional[Callable[[int, int, int], None]] = None,
                 role: str = "both"):
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"role must be both|prefill|decode, got {role!r}")
        self.cfg = cfg
        self.mesh = mesh
        self.role = role
        self.max_seq = max_seq
        self.B = batch_size                       # decode slots
        self.plan_cache = plan_cache
        # legalize the chunk to a divisor of max_seq: the chunk grid then
        # tiles the cache exactly and the last chunk of any admissible
        # prompt stays inside [0, max_seq) — otherwise the tail chunk's
        # dynamic_update_slice would CLAMP its start and silently corrupt
        # earlier chunks' K/V
        chunk = max(1, min(chunk or min(32, max_seq), max_seq))
        while max_seq % chunk:
            chunk -= 1
        self.chunk = chunk
        # paged block-table KV cache: page_size > 0 pools K/V as shared
        # fixed-size pages and admits against the free-page budget. The
        # page is legalized to a divisor of max_seq the same way (a block
        # table must tile [0, max_seq) exactly).
        if page_size:
            page_size = max(1, min(page_size, max_seq))
            while max_seq % page_size:
                page_size -= 1
        self.page_size = page_size
        self.paged = page_size > 0
        self.max_blocks = (max_seq // page_size) if self.paged else 0
        if self.paged and not n_pages:
            # parity capacity by default: every slot can still hold max_seq
            n_pages = batch_size * self.max_blocks + 1
        self.n_pages = n_pages if self.paged else 0
        # how many queued requests one step() may admit in ONE stacked
        # chunk call (0 = up to every free slot)
        self.admit_k = admit_k
        # -- robustness knobs ------------------------------------------------
        self.max_queue = max_queue               # 0 = unbounded
        self.shed_policy = shed_policy           # "reject"|"deadline"|callable
        self.ttft_deadline_s = ttft_deadline_s   # per-request defaults
        self.deadline_s = deadline_s
        self.max_restarts = max_restarts         # consecutive step failures
        self.faults = faults                     # FaultInjector or None
        self.monitor = StragglerMonitor(straggler_factor)
        self._clock = clock or time.perf_counter
        self.on_token = on_token                 # exactly-once emission cb
        self.snapshot_every = snapshot_every
        self.ckpt = (CheckpointManager(snapshot_dir, keep=3,
                                       async_save=False)
                     if snapshot_dir else None)
        # recovery on step failure: restore last snapshot (or reset empty)
        # + replay the post-snapshot event log. Default on iff snapshots
        # are configured; force with recover=True/False.
        self.auto_recover = (recover if recover is not None
                             else snapshot_dir is not None)
        # ONE shape describes the shared donated cache: both steps derive
        # identical cache shardings from it on a mesh (paged: the K/V page
        # pools + per-slot SSM state)
        dshape = ShapeConfig("serve_decode", seq_len=max_seq,
                             global_batch=batch_size, kind="decode",
                             page_size=self.page_size, n_pages=self.n_pages)
        # a role-restricted worker builds ONLY the step it runs: a decode
        # worker never compiles prefill plans and vice versa
        self.prefill = (build_prefill_chunk_step(cfg, dshape, mesh,
                                                 chunk=self.chunk,
                                                 plan_cache=plan_cache,
                                                 plan_hw=plan_hw)
                        if role != "decode" else None)
        self.decode = (build_decode_step(cfg, dshape, mesh,
                                         plan_cache=plan_cache,
                                         plan_hw=plan_hw)
                       if role != "prefill" else None)
        ctx = (self.decode or self.prefill)["ctx"]
        if params is None:
            params = lm.init_params(cfg, jax.random.PRNGKey(seed), ctx)
        self.params = params
        # device state: the decode cache, donated through every chunk/decode
        # call — contiguous: one region (batch row) per slot; paged: shared
        # K/V page pools + dense per-slot SSM entries
        if self.paged:
            self.cache = lm.init_paged_cache(cfg, batch_size, self.n_pages,
                                             page_size, ctx)
            self.alloc = BlockAllocator(self.n_pages, page_size,
                                        self.max_blocks)
            self.block_tables = np.zeros((batch_size, self.max_blocks),
                                         np.int32)
        else:
            self.cache = lm.init_cache(cfg, batch_size, max_seq, ctx)
            self.alloc = None
            self.block_tables = None
        # host scheduler state
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros((batch_size,), np.int32)      # next write index
        self.live = np.zeros((batch_size,), bool)
        self.last_tok = np.zeros((batch_size,), np.int32)
        self.queue: deque = deque()
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        # exactly-once delivery ledger: rid -> tokens emitted so far. Never
        # rolled back by restore — replayed tokens below the watermark are
        # regenerated (bit-identically) but not re-emitted.
        self.emitted: Dict[int, int] = {}
        # write-ahead event log since the last committed snapshot: replayed
        # after a restore so post-snapshot submits/cancels are never lost
        self._log: List[Tuple] = []
        # per-phase accounting (the CLI summary prints these)
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.prefill_tokens = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.admissions = 0
        self.admit_rounds = 0       # stacked chunk-admission calls
        # fault/recovery accounting
        self.step_idx = 0           # monotonic; NEVER rolled back by restore
        self.failures = 0           # total step failures
        self.recoveries = 0         # successful restore+replay cycles
        self.shed = 0               # queued requests dropped by load shedding
        self.expired = 0
        self.quarantined = 0
        self._consec_failures = 0
        # page-migration accounting (disaggregated handoff)
        self.handoffs_out = 0       # finished prefills exported
        self.migrations_in = 0      # handoffs imported into this pool
        self.pages_exported = 0     # content pages copied out
        self.pages_imported = 0     # content pages copied in

    # -- streaming API ------------------------------------------------------

    def _reject(self, req: Request, reason: RejectReason, msg: str):
        req.status = RequestStatus.REJECTED
        req.error = f"{reason.value}: {msg}"
        req.done_t = self._clock()
        raise RejectedRequest(reason, msg, request=req)

    def _coerce_spec(self, request, max_new, eos_id, ttft_deadline_s,
                     deadline_s) -> RequestSpec:
        """Kwargs → :class:`RequestSpec` (a spec passes through). A spec
        validation failure is re-raised with a terminal (status=rejected)
        Request record attached, so the kwargs door keeps its contract:
        every rejection carries an inspectable request."""
        if isinstance(request, RequestSpec):
            return request
        try:
            return RequestSpec(prompt=request, max_new=max_new,
                               eos_id=eos_id,
                               ttft_deadline_s=ttft_deadline_s,
                               deadline_s=deadline_s)
        except RejectedRequest as e:
            try:
                prompt = ([] if isinstance(request, (str, bytes))
                          else [int(t) for t in request])
            except Exception:
                prompt = []
            rec = Request(self._next_rid, prompt,
                          max_new if isinstance(max_new, int) else 0,
                          None, submit_t=self._clock())
            self._next_rid += 1            # rids stay unique on reject
            rec.status = RequestStatus.REJECTED
            rec.error = f"{e.reason.value}: {e.msg}"
            rec.done_t = self._clock()
            raise RejectedRequest(e.reason, e.msg, request=rec) from e

    def submit(self, request: Union[RequestSpec, Sequence[int]],
               max_new: int = 32, eos_id: Optional[int] = None,
               ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request; returns its id. ``request`` is a
        :class:`RequestSpec` or a raw prompt (token sequence) plus the
        legacy kwargs, which build a spec internally. Admission happens on
        the next ``step()`` (or immediately inside ``run()``). Malformed
        requests raise :class:`RejectedRequest` (typed reason, engine
        untouched); a full bounded queue applies the shedding policy
        first."""
        if self.role == "decode":
            raise RuntimeError(
                "decode-role worker takes migrated requests only "
                "(migrate()); submit through the router")
        spec = self._coerce_spec(request, max_new, eos_id,
                                 ttft_deadline_s, deadline_s)
        req = Request(self._next_rid, list(spec.prompt), spec.max_new,
                      spec.eos_id, submit_t=self._clock(),
                      ttft_deadline_s=(self.ttft_deadline_s
                                       if spec.ttft_deadline_s is None
                                       else spec.ttft_deadline_s),
                      deadline_s=(self.deadline_s if spec.deadline_s is None
                                  else spec.deadline_s),
                      route_hint=spec.route_hint)
        self._next_rid += 1                    # rids stay unique on reject
        if spec.budget_tokens > self.max_seq:
            self._reject(req, RejectReason.TOO_LONG,
                         f"prompt {len(req.prompt)} + max_new "
                         f"{spec.max_new} exceeds engine max_seq "
                         f"{self.max_seq}")
        if self.paged:
            # a budget beyond the POOL capacity would never fit, and the
            # FIFO admission gate would stall on it (and everything queued
            # behind it) forever — reject it at the door instead
            need = pages_for(spec.budget_tokens, self.page_size)
            if need > min(self.n_pages - 1, self.max_blocks):
                self._reject(req, RejectReason.OVER_CAPACITY,
                             f"request needs {need} pages, pool holds "
                             f"{min(self.n_pages - 1, self.max_blocks)}")
        if self.max_queue and len(self.queue) >= self.max_queue:
            victim = self._shed_victim(req)
            if victim is None:
                self._reject(req, RejectReason.QUEUE_FULL,
                             f"queue at max_queue={self.max_queue}")
            self._drop_queued(victim, RequestStatus.EXPIRED,
                              "shed: queue full")
            self.shed += 1
        self.enqueue(req)
        return req.rid

    def enqueue(self, req: Request) -> None:
        """Append an ALREADY-VALIDATED Request to this engine's queue and
        write-ahead log (the router dispatches through this after doing
        its own admission; ``submit()`` lands here too). The log entry
        makes the request crash-durable on THIS engine: a post-snapshot
        restore replays it from token 0, watermark-deduped."""
        req.status = RequestStatus.QUEUED
        self.queue.append(req)
        self._log.append(("submit", _req_to_json(req)))

    def _shed_victim(self, new_req: Request) -> Optional[Request]:
        """Pick the queued request to drop when the bounded queue is full
        (None = reject the new request instead). The "deadline" policy
        drops whichever request has the LEAST deadline slack — it is the
        one most likely to miss anyway; requests without deadlines have
        infinite slack and are never shed."""
        if callable(self.shed_policy):
            return self.shed_policy(self, new_req)
        if self.shed_policy == "reject":
            return None
        if self.shed_policy == "deadline":
            now = self._clock()

            def slack(r: Request) -> float:
                dls = [d for d in (r.ttft_deadline_s, r.deadline_s)
                       if d is not None]
                if not dls:
                    return float("inf")
                return min(dls) - (now - r.submit_t)

            if not self.queue:
                return None
            victim = min(self.queue, key=slack)
            return victim if slack(victim) < slack(new_req) else None
        raise ValueError(f"unknown shed_policy {self.shed_policy!r}")

    def _drop_queued(self, req: Request, status: RequestStatus, error: str):
        """Remove a queued request and retire it terminally (shed/cancel/
        deadline); logged so crash replay re-applies the drop."""
        self.queue.remove(req)
        req.status = status
        req.error = error
        req.done_t = self._clock()
        if req.length < 0:
            req.length = len(req.tokens)
        self.finished[req.rid] = req
        self._log.append(("drop", req.rid, status.value, error))

    def cancel(self, rid: int) -> bool:
        """Cancel a request by id: queued requests leave the queue, LIVE
        requests retire immediately (slot + pages freed, partial tokens
        kept). Returns False if the rid is unknown or already terminal."""
        for r in self.queue:
            if r.rid == rid:
                self._drop_queued(r, RequestStatus.CANCELLED, "cancelled")
                return True
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                self._retire(slot, RequestStatus.CANCELLED, "cancelled")
                self._log.append(("drop", rid,
                                  RequestStatus.CANCELLED.value, "cancelled"))
                return True
        return False

    @property
    def pending(self) -> bool:
        return bool(self.queue) or bool(self.live.any())

    @property
    def free_pages(self) -> int:
        """Free pages in the pool (paged mode; contiguous reports 0)."""
        return self.alloc.free_pages if self.paged else 0

    def _record_token(self, req: Request, tok: int, t_idx: int) -> bool:
        """Append a generated token; returns True when the request is done
        (eos — possibly on its very FIRST decoded token — or max_new).
        Emission is exactly-once: tokens at an index below the request's
        watermark (regenerated during crash replay) are recorded but NOT
        re-emitted through ``on_token``."""
        req.tokens.append(tok)
        idx = len(req.tokens) - 1
        if idx >= self.emitted.get(req.rid, 0):
            self.emitted[req.rid] = idx + 1
            if self.on_token is not None:
                self.on_token(req.rid, idx, tok)
        if req.eos_id is not None and tok == req.eos_id:
            req.length = t_idx
            return True
        if t_idx + 1 >= req.max_new:
            req.length = req.max_new
            return True
        return False

    def _retire(self, slot: int, status: RequestStatus = RequestStatus.OK,
                error: str = ""):
        req = self.slot_req[slot]
        req.done_t = self._clock()
        req.slot = -1
        req.status = status
        req.error = error
        if req.length < 0:
            req.length = len(req.tokens)
        self.finished[req.rid] = req
        self.slot_req[slot] = None
        self.live[slot] = False
        if self.paged:
            # pages back to the free list; the zeroed table row steers any
            # write from this (now dead) decode row into the null page
            self.alloc.free_slot(slot)
            self.block_tables[slot] = 0

    # -- deadlines ----------------------------------------------------------

    def _expire_queued(self):
        now = self._clock()
        for r in list(self.queue):
            age = now - r.submit_t
            if r.ttft_deadline_s is not None and age > r.ttft_deadline_s:
                self._drop_queued(r, RequestStatus.EXPIRED,
                                  f"ttft deadline {r.ttft_deadline_s:.3f}s "
                                  f"exceeded in queue")
                self.expired += 1
            elif r.deadline_s is not None and age > r.deadline_s:
                self._drop_queued(r, RequestStatus.EXPIRED,
                                  f"deadline {r.deadline_s:.3f}s exceeded "
                                  f"in queue")
                self.expired += 1

    def _expire_live(self):
        now = self._clock()
        for slot in range(self.B):
            r = self.slot_req[slot]
            if r is None or not self.live[slot]:
                continue
            if r.deadline_s is not None and now - r.submit_t > r.deadline_s:
                self._retire(slot, RequestStatus.EXPIRED,
                             f"deadline {r.deadline_s:.3f}s exceeded "
                             f"after {len(r.tokens)} tokens")
                self.expired += 1

    # -- admission ----------------------------------------------------------

    def _gather_admissions(self) -> List[Tuple[int, Request]]:
        """Pop queued requests (FIFO) into free slots, gating on the free-
        page budget in paged mode. Pages are claimed here, before the
        stacked chunk call, so the batch can never oversubscribe the pool.
        Admission stays in arrival order: when the head does not fit, we
        wait for pages rather than admitting around it."""
        k = self.admit_k or self.B
        free = [s for s in range(self.B) if not self.live[s]
                and self.slot_req[s] is None]
        pairs: List[Tuple[int, Request]] = []
        while self.queue and free and len(pairs) < k:
            req = self.queue[0]
            budget = len(req.prompt) + req.max_new
            if self.paged:
                if not self.alloc.can_admit(budget):
                    break
                slot = free.pop(0)
                pages = self.alloc.allocate(slot, budget)
                row = np.zeros((self.max_blocks,), np.int32)
                row[:len(pages)] = pages
                self.block_tables[slot] = row
            else:
                slot = free.pop(0)
            self.queue.popleft()
            pairs.append((slot, req))
        return pairs

    def _admit_batch(self, pairs: List[Tuple[int, Request]]):
        """Chunked prefill of every (slot, request) pair in ONE stacked call
        per chunk step: per-row offsets and tail masks keep rows exact, rows
        whose prompt already ended ride along as identity rows (their K/V
        writes are masked — paged: steered to the null page). Each request's
        first generated token comes from its LAST chunk's logits row.

        The stacked row count is padded UP to the next power of two using
        leftover FREE slots as all-identity parking rows (valid_len 0, so
        a parking row only scribbles on a free slot's region — scrubbed at
        its next admission anyway — or the null page): distinct XLA
        compiles stay O(log slots) instead of one per admission count."""
        t0 = time.perf_counter()
        C = self.chunk
        A = len(pairs)
        taken = {s for s, _ in pairs}
        parking = [s for s in range(self.B)
                   if not self.live[s] and self.slot_req[s] is None
                   and s not in taken]
        n_pad = min(len(parking),
                    (1 << max(0, A - 1).bit_length()) - A)
        slots = np.array([s for s, _ in pairs] + parking[:n_pad], np.int32)
        plens = np.array([len(r.prompt) for _, r in pairs] + [0] * n_pad,
                         np.int32)
        A = A + n_pad
        nchunks = np.maximum(1, -(-plens // C))
        fn = self.prefill["jit"]
        first_tok = np.zeros((A,), np.int32)
        row_ok = np.ones((A,), bool)
        for j in range(int(nchunks.max())):
            toks = np.zeros((A, C), np.int32)
            valids = np.clip(plens - j * C, 0, C).astype(np.int32)
            for a, (_, r) in enumerate(pairs):
                part = r.prompt[j * C:(j + 1) * C]
                toks[a, :len(part)] = part
            offs = np.full((A,), j * C, np.int32)
            args = (self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(offs), jnp.asarray(valids),
                    jnp.asarray(slots))
            if self.paged:
                bt = jnp.asarray(self.block_tables[slots])
                logits, self.cache = fn(*args, bt)
            else:
                logits, self.cache = fn(*args)
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            finite = np.asarray(jnp.isfinite(logits).all(axis=-1))
            last = nchunks == j + 1
            first_tok[last] = nxt[last]
            row_ok[last] = finite[last]
        self.prefill_s += time.perf_counter() - t0
        self.prefill_tokens += int(plens.sum())
        self.admissions += len(pairs)               # parking rows don't count
        self.admit_rounds += 1
        now = self._clock()
        for a, (slot, req) in enumerate(pairs):
            req.slot = slot
            req.status = RequestStatus.RUNNING
            if req.first_token_t <= 0:              # preserve TTFT on replay
                req.first_token_t = now
            self.slot_req[slot] = req
            self.pos[slot] = int(plens[a])
            self.last_tok[slot] = int(first_tok[a])
            self.live[slot] = True
            if not row_ok[a]:
                # non-finite prefill logits: quarantine THIS request only;
                # its garbage first token is never recorded
                self._retire(slot, RequestStatus.QUARANTINED,
                             "non-finite prefill logits")
                self.quarantined += 1
            elif self._record_token(req, int(first_tok[a]), 0):
                self._retire(slot)                # finished on token 0
        return pairs

    # -- the scheduler step -------------------------------------------------

    def step(self) -> bool:
        """One scheduler iteration: fault hooks fire first, then queued
        deadline expiry, queue refill (one stacked chunk-admission call,
        gated on the free-page budget when paged), one decoded token per
        live slot (non-finite rows quarantined), live deadline expiry, and
        a periodic snapshot. On a step failure the engine recovers
        (restore + replay) when ``auto_recover`` is on, re-raising only
        after ``max_restarts`` consecutive failures. Returns whether any
        work remains."""
        self.step_idx += 1
        t0 = self._clock()
        try:
            self._step_inner()
        except RejectedRequest:
            raise
        except Exception as e:
            self.failures += 1
            self._consec_failures += 1
            if not self.auto_recover or \
                    self._consec_failures > self.max_restarts:
                self._fail_all(e)
                raise
            self._recover(e)
            return self.pending
        self._consec_failures = 0
        self.monitor.observe(self.step_idx, self._clock() - t0)
        return self.pending

    def _step_inner(self):
        if self.faults is not None:
            self.faults.begin_step(self)   # latency / pressure / crash hook
        if self.role != "decode":
            self.prefill_step()
        if self.role != "prefill":
            self.decode_step()
        self._after_phases()
        if self.ckpt is not None and self.snapshot_every and \
                self.step_idx % self.snapshot_every == 0:
            self.snapshot()

    # -- worker API: the two phases of step(), callable separately ----------

    def prefill_step(self) -> List[Tuple[int, Request]]:
        """The admission phase of one scheduler iteration: queued-deadline
        expiry, then ONE stacked chunk-admission call (free-page gated
        when paged). Returns the admitted (slot, request) pairs. This is
        the entire step of a ``role="prefill"`` worker."""
        self._expire_queued()
        pairs = self._gather_admissions()
        if pairs:
            self._admit_batch(pairs)
        return pairs

    def decode_step(self) -> int:
        """The decode phase of one scheduler iteration: one decoded token
        per live slot (non-finite rows quarantined), then live-deadline
        expiry. Returns how many rows decoded. This is the entire step of
        a ``role="decode"`` worker."""
        n = int(self.live.sum())
        if n:
            self._decode_once()
        self._expire_live()
        return n

    def _after_phases(self):
        """Post-phase hook between the scheduler phases and the periodic
        snapshot — the PrefillWorker overrides this to export finished
        prefills as page-migration handoffs. Base engine: no-op."""

    def _decode_once(self):
        t0 = time.perf_counter()
        toks = jnp.asarray(self.last_tok[:, None])
        args = (self.params, self.cache, toks, jnp.asarray(self.pos),
                jnp.asarray(self.live))
        if self.paged:
            nxt, logits, self.cache = self.decode["jit"](
                *args, jnp.asarray(self.block_tables))
        else:
            nxt, logits, self.cache = self.decode["jit"](*args)
        nxt = np.asarray(nxt)[:, 0]
        # per-row health: a poisoned request must retire alone instead of
        # taking the engine (or its batch neighbours) down
        row_ok = np.asarray(jnp.isfinite(logits).all(axis=-1))
        poisoned = (set(self.faults.poison_rows(self))
                    if self.faults is not None else set())
        self.decode_s += time.perf_counter() - t0
        self.decode_steps += 1
        self.decode_tokens += int(self.live.sum())
        for slot in range(self.B):
            if not self.live[slot]:
                continue
            req = self.slot_req[slot]
            if slot in poisoned or not row_ok[slot]:
                self._retire(slot, RequestStatus.QUARANTINED,
                             f"non-finite logits after {len(req.tokens)} "
                             f"tokens")
                self.quarantined += 1
                continue
            self.pos[slot] += 1
            self.last_tok[slot] = int(nxt[slot])
            if self._record_token(req, int(nxt[slot]), len(req.tokens)):
                self._retire(slot)

    # -- page-migration handoff (disaggregated prefill/decode) --------------

    def export_handoff(self, slot: int) -> Handoff:
        """Detach a live request from this engine as a :class:`Handoff`:
        gather its written K/V page contents (and per-slot SSM carry) out
        of the pools into immutable arrays, free the slot and its pages,
        and return the record. The request is NOT retired — it continues
        on whichever engine imports the handoff; this engine forgets it
        entirely (its capacity is back immediately)."""
        if not self.paged:
            raise RuntimeError("page-migration handoff needs a paged cache")
        req = self.slot_req[slot]
        if req is None or not self.live[slot]:
            raise RuntimeError(f"export_handoff({slot}): slot is not live")
        pos = int(self.pos[slot])
        n_content = pages_for(pos, self.page_size)
        owned = self.alloc.owned(slot)
        content = jnp.asarray(np.asarray(owned[:n_content], np.int32))
        kv = []
        for e in self.cache:
            if "k" in e:     # shared page pool: gather the written prefix
                kv.append({k: jnp.take(e[k], content, axis=1)
                           for k in ("k", "v")})
            else:            # dense per-slot SSM carry: copy the slot row
                kv.append({k: e[k][:, slot] for k in e})
        hand = Handoff(rid=req.rid, req_json=_req_to_json(req), pos=pos,
                       last_tok=int(self.last_tok[slot]),
                       budget_tokens=len(req.prompt) + req.max_new,
                       pages=tuple(owned),
                       block_table=tuple(int(p) for p in
                                         self.block_tables[slot]),
                       n_content_pages=n_content, kv=tuple(kv))
        self.alloc.export_pages(slot)
        self.block_tables[slot] = 0
        self.slot_req[slot] = None
        self.live[slot] = False
        self.pos[slot] = 0
        req.slot = -1
        self.handoffs_out += 1
        self.pages_exported += n_content
        return hand

    def can_import(self, hand: Handoff) -> bool:
        """Whether :meth:`migrate` would succeed RIGHT NOW (a free slot
        and the handoff's full page budget). The router's backpressure
        gate — a False keeps the handoff queued at the router."""
        free = any(not self.live[s] and self.slot_req[s] is None
                   for s in range(self.B))
        return (self.paged and free
                and self.alloc.can_admit(hand.budget_tokens))

    def migrate(self, hand: Handoff) -> bool:
        """Import a migrated prefill into this engine: bind a free slot,
        allocate the destination page budget (``import_pages`` — fresh
        ids, handoff metadata cross-checked), scatter the content pages
        and SSM carry into the pools, and resume the request at its
        handoff position. Returns False WITHOUT side effects when no slot
        or pages are available (backpressure); raises AllocatorError only
        on a genuinely torn handoff."""
        if not self.paged:
            raise RuntimeError("page-migration handoff needs a paged cache")
        if self.role == "prefill":
            raise RuntimeError("prefill-role worker cannot import decodes")
        if not self.can_import(hand):
            return False
        slot = next(s for s in range(self.B)
                    if not self.live[s] and self.slot_req[s] is None)
        dst = self.alloc.import_pages(slot, hand.pages, hand.block_table)
        row = np.zeros((self.max_blocks,), np.int32)
        row[:len(dst)] = dst
        self.block_tables[slot] = row
        dst_content = jnp.asarray(
            np.asarray(dst[:hand.n_content_pages], np.int32))
        cache = []
        for e, h in zip(self.cache, hand.kv):
            if "k" in e:
                cache.append({k: e[k].at[:, dst_content].set(
                    h[k].astype(e[k].dtype)) for k in ("k", "v")})
            else:
                cache.append({k: e[k].at[:, slot].set(
                    h[k].astype(e[k].dtype)) for k in e})
        self.cache = tuple(cache)
        req = _req_from_json(hand.req_json)
        req.slot = slot
        req.status = RequestStatus.RUNNING
        self.slot_req[slot] = req
        self.pos[slot] = hand.pos
        self.last_tok[slot] = hand.last_tok
        self.live[slot] = True
        self.migrations_in += 1
        self.pages_imported += hand.n_content_pages
        return True

    # -- snapshot / restore / recovery --------------------------------------

    def _device_state(self) -> Dict:
        state = {"cache": self.cache, "pos": self.pos, "live": self.live,
                 "last_tok": self.last_tok}
        if self.paged:
            state["block_tables"] = self.block_tables
        return state

    def snapshot(self):
        """Commit scheduler state + KV/SSM pools atomically (one rename —
        readers never observe a torn snapshot). Clears the write-ahead
        event log: everything before this point is folded into the
        snapshot, everything after is replayable."""
        if self.ckpt is None:
            raise RuntimeError("snapshot() needs snapshot_dir")
        by_rid: Dict[int, Request] = {r.rid: r for r in self.queue}
        by_rid.update({r.rid: r for r in self.slot_req if r is not None})
        by_rid.update(self.finished)
        extra = {
            "requests": {str(rid): _req_to_json(r)
                         for rid, r in by_rid.items()},
            "queue": [r.rid for r in self.queue],
            "slots": [r.rid if r is not None else None
                      for r in self.slot_req],
            "finished": sorted(self.finished),
            "next_rid": self._next_rid,
            "alloc": self.alloc.snapshot_state() if self.paged else None,
        }
        self.ckpt.save(self.step_idx, self._device_state(), wait=True,
                       extra=extra)
        self._log = []

    def restore(self, step: Optional[int] = None):
        """Restore scheduler + cache from the latest (or a given) committed
        snapshot. The monotonic fault clock (``step_idx``) and the
        exactly-once emission ledger are NOT rolled back."""
        if self.ckpt is None:
            raise RuntimeError("restore() needs snapshot_dir")
        self.ckpt.wait()
        state, step = self.ckpt.restore(self._device_state(), step=step)
        extra = self.ckpt.load_extra(step)
        self.cache = state["cache"]
        self.pos = np.asarray(state["pos"], np.int32).copy()
        self.live = np.asarray(state["live"], bool).copy()
        self.last_tok = np.asarray(state["last_tok"], np.int32).copy()
        if self.paged:
            self.block_tables = np.asarray(state["block_tables"],
                                           np.int32).copy()
            self.alloc.restore_state(extra["alloc"])
            # injected page squeezes (negative pseudo-slots) are transient
            # memory pressure, not scheduler state — don't resurrect them
            # (the injector's own release is owns()-guarded, so this can
            # never turn into a double free)
            for s in [int(s) for s in extra["alloc"]["owned"]
                      if int(s) < 0]:
                self.alloc.free_slot(s)
        reqs = {int(rid): _req_from_json(d)
                for rid, d in extra["requests"].items()}
        self.queue = deque(reqs[rid] for rid in extra["queue"])
        self.slot_req = [reqs[rid] if rid is not None else None
                         for rid in extra["slots"]]
        self.finished = {rid: reqs[rid] for rid in extra["finished"]}
        self._next_rid = max(self._next_rid, int(extra["next_rid"]))

    def _reset_empty(self):
        """No committed snapshot: reset to the engine's initial (empty)
        state; the full event log then replays every submission."""
        self.cache = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, x.dtype), self.cache)
        self.pos[:] = 0
        self.live[:] = False
        self.last_tok[:] = 0
        self.queue = deque()
        self.slot_req = [None] * self.B
        if self.paged:
            self.alloc = BlockAllocator(self.n_pages, self.page_size,
                                        self.max_blocks)
            self.block_tables = np.zeros((self.B, self.max_blocks), np.int32)

    def _replay_log(self):
        """Re-apply post-snapshot external events (submits, cancels/sheds)
        in order. Replayed submissions start from token 0 — regeneration
        is bit-identical and the emission watermark suppresses duplicates,
        so delivery stays exactly-once."""
        log, self._log = self._log, []
        for ev in log:
            if ev[0] == "submit":
                d = dict(ev[1])
                d["tokens"], d["length"] = [], -1
                d["slot"], d["first_token_t"], d["done_t"] = -1, 0.0, 0.0
                d["status"] = RequestStatus.QUEUED.value
                req = _req_from_json(d)
                self.queue.append(req)
                self._log.append(("submit", ev[1]))
            elif ev[0] == "drop":
                _, rid, status, error = ev
                self._apply_drop(int(rid), RequestStatus(status), error)

    def _apply_drop(self, rid: int, status: RequestStatus, error: str):
        for r in list(self.queue):
            if r.rid == rid:
                self._drop_queued(r, status, error)
                return
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                self._retire(slot, status, error)
                self._log.append(("drop", rid, status.value, error))
                return

    def _recover(self, error: Exception):
        """Restore the last committed snapshot (or reset empty) and replay
        the event log. In-flight work resumes exactly where the snapshot
        left it; post-snapshot submissions re-enter the queue."""
        have = self.ckpt.latest_step() if self.ckpt is not None else None
        if have is not None:
            self.restore(have)
        else:
            self._reset_empty()
        self._replay_log()
        self.recoveries += 1
        print(f"[serve] step {self.step_idx} failed "
              f"({type(error).__name__}: {error}); restored snapshot "
              f"{'@step %d' % have if have is not None else '(initial)'} "
              f"+ replayed log ({self._consec_failures}/"
              f"{self.max_restarts} consecutive)")

    def _fail_all(self, error: Exception):
        """Unrecoverable engine failure: every non-terminal request reaches
        the terminal ``failed`` status so callers are never left hanging."""
        msg = f"engine failure: {type(error).__name__}: {error}"
        for r in list(self.queue):
            self._drop_queued(r, RequestStatus.FAILED, msg)
        for slot, r in enumerate(self.slot_req):
            if r is not None:
                self._retire(slot, RequestStatus.FAILED, msg)

    # -- drain / collect ----------------------------------------------------

    def run(self) -> Dict[int, Request]:
        """Drain queue + slots; returns {rid: finished Request}."""
        while self.pending:
            self.step()
        return self.finished

    def collect(self, rid: int) -> Request:
        """Pop a finished request's record. Long-running streaming servers
        must collect results (or clear ``finished``) — the engine keeps a
        reference to every uncollected request, tokens included."""
        self.emitted.pop(rid, None)
        return self.finished.pop(rid)

    # -- batch convenience wrapper -----------------------------------------

    def generate(self, prompts: Sequence[Union[Sequence[int], RequestSpec]],
                 max_new: int = 32,
                 eos_id: Optional[int] = None) -> GenerateResult:
        """Submit every prompt, run to completion, return a batch result
        (rows in submit order). More prompts than slots simply queue —
        freed slots are refilled mid-decode. Prompts may be raw token
        sequences (the kwargs apply) or per-row :class:`RequestSpec`
        values (the spec's own fields win). A malformed prompt does NOT
        abort the batch: its row comes back zeroed (length 0, status
        "rejected") with the typed exception in ``result.rejected``."""
        base_steps = self.decode_steps
        rids: List[Optional[int]] = []
        rejected: Dict[int, RejectedRequest] = {}
        widths: List[int] = []
        pre_toks = 0
        for i, p in enumerate(prompts):
            widths.append(p.max_new if isinstance(p, RequestSpec)
                          else max_new)
            try:
                rids.append(self.submit(p, max_new=max_new, eos_id=eos_id))
                pre_toks += len(p.prompt if isinstance(p, RequestSpec)
                                else p)
            except RejectedRequest as e:
                rejected[i] = e
                rids.append(None)
        self.run()
        n = len(prompts)
        width = max(widths, default=max_new)
        out = np.zeros((n, width), np.int32)
        lengths = np.zeros((n,), np.int64)
        statuses: List[str] = []
        for i, rid in enumerate(rids):
            if rid is None:
                statuses.append(RequestStatus.REJECTED.value)
                continue
            req = self.collect(rid)
            t = req.tokens[:width]
            out[i, :len(t)] = t
            lengths[i] = req.length
            statuses.append(req.status.value)
        return GenerateResult(out, lengths, prefill_tokens=pre_toks,
                              decode_steps=self.decode_steps - base_steps,
                              statuses=statuses, rejected=rejected)


# ---------------------------------------------------------------------------
# Engine construction config
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineConfig:
    """Engine construction, consolidated: the ~20 flat CLI flags of
    ``launch/serve.py`` and the duplicated keyword soup of the serving
    benchmarks, as ONE validated dataclass with the same groups the CLI
    shows (engine / paging / robustness / chaos / disagg) and ONE builder.
    ``build(model_cfg)`` returns a :class:`ServeEngine` — or, when
    ``disagg`` is set, the router/worker topology
    (:class:`~repro.serving.disagg.Router`) behind the same streaming
    API. ``add_cli_args`` / ``from_cli_args`` keep the flag names the CLI
    always had, grouped."""
    # engine
    max_seq: int = 256
    batch_size: int = 4
    chunk: int = 0
    seed: int = 0
    plan_cache: Optional[str] = None
    plan_hw: str = ""
    # paging
    page_size: int = 0
    n_pages: int = 0
    admit_k: int = 0
    # robustness
    max_queue: int = 0
    shed_policy: Union[str, Callable] = "reject"
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 8
    max_restarts: int = 3
    recover: Optional[bool] = None
    # chaos (seeded fault injection; rate 0 = off)
    chaos_rate: float = 0.0
    chaos_seed: int = 0
    chaos_horizon: int = 256
    # disagg (router/worker topology; requires paging — the handoff IS
    # page migration)
    disagg: bool = False
    prefill_workers: int = 1
    decode_workers: int = 1
    prefill_slots: int = 0      # 0 = batch_size
    decode_slots: int = 0       # 0 = batch_size

    def __post_init__(self):
        for name in ("max_seq", "batch_size", "prefill_workers",
                     "decode_workers"):
            if int(getattr(self, name)) < 1:
                raise ValueError(f"{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        for name in ("chunk", "page_size", "n_pages", "admit_k",
                     "max_queue", "snapshot_every", "max_restarts",
                     "prefill_slots", "decode_slots"):
            if int(getattr(self, name)) < 0:
                raise ValueError(f"{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if not callable(self.shed_policy) and \
                self.shed_policy not in ("reject", "deadline"):
            raise ValueError(f"shed_policy must be reject|deadline|callable,"
                             f" got {self.shed_policy!r}")
        if self.chaos_rate < 0:
            raise ValueError(f"chaos_rate must be >= 0, "
                             f"got {self.chaos_rate}")
        if self.disagg and self.page_size <= 0:
            raise ValueError(
                "disagg mode needs a paged KV cache (page_size > 0): the "
                "prefill→decode handoff is page migration")

    # -- chaos --------------------------------------------------------------

    def worker_targets(self) -> Tuple[Tuple[str, int], ...]:
        """Every (role, index) in the disagg topology, crash-target
        order."""
        return (tuple(("prefill", i) for i in range(self.prefill_workers))
                + tuple(("decode", i) for i in range(self.decode_workers)))

    def make_faults(self, role: Optional[Tuple[str, int]] = None):
        """Seeded chaos injector from the chaos group (None when the rate
        is 0). In disagg mode, crash draws target single workers and each
        worker gets a role-scoped injector over the SAME plan."""
        if self.chaos_rate <= 0:
            return None
        from repro.serving.faults import FaultInjector, FaultPlan
        plan = FaultPlan.poisson(
            self.chaos_seed, self.chaos_horizon,
            crash_rate=self.chaos_rate, nan_rate=self.chaos_rate,
            spike_rate=self.chaos_rate,
            workers=self.worker_targets() if self.disagg else ())
        return FaultInjector(plan, role=role)

    # -- the one builder ----------------------------------------------------

    def build(self, model_cfg: ModelConfig, params=None, mesh=None,
              clock: Optional[Callable[[], float]] = None,
              on_token: Optional[Callable[[int, int, int], None]] = None,
              faults="auto"):
        """Construct the engine this config describes: a ServeEngine, or
        the Router topology when ``disagg`` is set. ``faults="auto"``
        derives injector(s) from the chaos group; pass an injector or
        None to override. Chaos with unset ``recover`` turns recovery
        on."""
        recover = self.recover
        if recover is None and self.chaos_rate > 0:
            recover = True
        if self.disagg:
            from repro.serving.disagg import Router   # disagg imports us
            return Router(model_cfg, self, params=params, mesh=mesh,
                          clock=clock, on_token=on_token, faults=faults)
        inj = self.make_faults() if faults == "auto" else faults
        return ServeEngine(
            model_cfg, params=params, mesh=mesh, max_seq=self.max_seq,
            batch_size=self.batch_size, seed=self.seed,
            plan_cache=self.plan_cache, plan_hw=self.plan_hw,
            chunk=self.chunk, page_size=self.page_size,
            n_pages=self.n_pages, admit_k=self.admit_k,
            max_queue=self.max_queue, shed_policy=self.shed_policy,
            ttft_deadline_s=self.ttft_deadline_s, deadline_s=self.deadline_s,
            snapshot_dir=self.snapshot_dir,
            snapshot_every=self.snapshot_every,
            max_restarts=self.max_restarts, recover=recover, faults=inj,
            clock=clock, on_token=on_token)

    # -- CLI mapping --------------------------------------------------------

    @staticmethod
    def add_cli_args(ap) -> None:
        """Register the flag groups on an argparse parser (same flag
        names ``launch/serve.py`` always had, now grouped)."""
        g = ap.add_argument_group("engine")
        g.add_argument("--max-seq", type=int, default=128)
        g.add_argument("--batch", type=int, default=4,
                       help="decode slots (disagg: default per-role slots)")
        g.add_argument("--chunk", type=int, default=16,
                       help="prefill chunk length")
        g.add_argument("--seed", type=int, default=0)
        g.add_argument("--plan-cache", default=None)
        g.add_argument("--plan-hw", default="")
        g = ap.add_argument_group("paging")
        g.add_argument("--page-size", type=int, default=0,
                       help="paged KV page length (0 = contiguous cache)")
        g.add_argument("--pages", type=int, default=0,
                       help="pool size incl. null page (0 = parity)")
        g.add_argument("--admit-k", type=int, default=0,
                       help="max stacked admissions per step (0 = slots)")
        g = ap.add_argument_group("robustness")
        g.add_argument("--max-queue", type=int, default=0,
                       help="bounded queue (0 = unbounded)")
        g.add_argument("--shed", default="reject",
                       choices=["reject", "deadline"])
        g.add_argument("--ttft-deadline", type=float, default=None)
        g.add_argument("--deadline", type=float, default=None)
        g.add_argument("--snapshot-dir", default=None)
        g.add_argument("--snapshot-every", type=int, default=8)
        g.add_argument("--max-restarts", type=int, default=3)
        g = ap.add_argument_group("chaos")
        g.add_argument("--chaos", type=float, default=0.0,
                       help="per-step fault rate (0 = off)")
        g.add_argument("--chaos-seed", type=int, default=0)
        g = ap.add_argument_group("disagg")
        g.add_argument("--disagg", action="store_true",
                       help="router/worker topology (needs --page-size)")
        g.add_argument("--prefill-workers", type=int, default=1)
        g.add_argument("--decode-workers", type=int, default=1)
        g.add_argument("--prefill-slots", type=int, default=0,
                       help="slots per prefill worker (0 = --batch)")
        g.add_argument("--decode-slots", type=int, default=0,
                       help="slots per decode worker (0 = --batch)")

    @classmethod
    def from_cli_args(cls, args, chaos_horizon: int = 0) -> "EngineConfig":
        """Parsed argparse namespace → EngineConfig (flag names as
        registered by :meth:`add_cli_args`)."""
        return cls(max_seq=args.max_seq, batch_size=args.batch,
                   chunk=args.chunk, seed=args.seed,
                   plan_cache=args.plan_cache, plan_hw=args.plan_hw,
                   page_size=args.page_size, n_pages=args.pages,
                   admit_k=args.admit_k, max_queue=args.max_queue,
                   shed_policy=args.shed,
                   ttft_deadline_s=args.ttft_deadline,
                   deadline_s=args.deadline,
                   snapshot_dir=args.snapshot_dir,
                   snapshot_every=args.snapshot_every,
                   max_restarts=args.max_restarts,
                   chaos_rate=args.chaos, chaos_seed=args.chaos_seed,
                   chaos_horizon=chaos_horizon or 256,
                   disagg=args.disagg,
                   prefill_workers=args.prefill_workers,
                   decode_workers=args.decode_workers,
                   prefill_slots=args.prefill_slots,
                   decode_slots=args.decode_slots)
