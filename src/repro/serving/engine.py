"""Continuous-batching serving engine: slot scheduler + masked chunked
prefill + per-row-position decode.

Requests are ``submit()``-ed into a queue and admitted MID-FLIGHT into a
fixed pool of decode slots: a freed slot (eos / max_new) is refilled from
the queue on the next ``step()``, so the decode batch stays full under
streaming arrivals instead of draining to the slowest request. Admission
runs the prompt through the chunked prefill step — fixed-size chunks
against the slot's cache region, the final partial chunk tail-masked — and
decoding advances every live slot at its OWN position (vector positions,
donated cache, live-slot mask). Mixed-length batches are EXACT: pad/tail
tokens are masked out of attention and are identity steps in the SSM scan
(the old left-padding approximation is gone; MoE layers remain subject to
per-chunk capacity routing, the standard batched-MoE caveat).

The same engine runs on a mesh (pjit shardings from the step builders) or a
single device. Plans resolve per latency phase: the decode step looks up
``:phdecode`` entries (ranked on per-step latency — tiny-M shapes legalize
toward bcast/small ring groups), the chunk step ``:phprefill`` ones.

``generate(prompts, ...)`` remains as a convenience wrapper: submit all,
run to completion, return a batch result. Any number of prompts works —
more prompts than slots simply queue.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train_step import (build_decode_step,
                                     build_prefill_chunk_step)
from repro.models import lm


def stitch_prefill_cache(cfg, decode_cache, prefill_cache, prompt_len: int):
    """Insert prefill cache entries — stacked (n_periods, B, S, ...) from the
    layer scan — into the fixed-size decode cache at positions [0, S).
    Used by the batched (non-chunked) prefill path in tests/tools."""
    out = []
    for entry, pre in zip(decode_cache, prefill_cache):
        e = {}
        for k in entry:
            if k in ("k", "v"):
                e[k] = entry[k].at[:, :, :prompt_len].set(
                    pre[k].astype(entry[k].dtype))
            elif k in ("xk", "xv"):
                src = pre[k]
                e[k] = entry[k].at[:, :, :src.shape[2]].set(
                    src.astype(entry[k].dtype))
            elif k == "conv":
                e[k] = pre[k].astype(entry[k].dtype)
            else:                                   # ssm state (fp32)
                e[k] = pre[k]
        out.append(e)
    return tuple(out)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    lengths: np.ndarray         # (B,) tokens before eos/max
    prefill_tokens: int
    decode_steps: int


@dataclasses.dataclass
class Request:
    """One in-flight generation request (streaming API handle)."""
    rid: int
    prompt: List[int]
    max_new: int
    eos_id: Optional[int]
    tokens: List[int] = dataclasses.field(default_factory=list)
    length: int = -1            # tokens before eos; -1 while running
    slot: int = -1
    submit_t: float = 0.0
    first_token_t: float = 0.0  # TTFT = first_token_t - submit_t
    done_t: float = 0.0

    @property
    def done(self) -> bool:
        return self.length >= 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.submit_t


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, mesh=None,
                 max_seq: int = 256, batch_size: int = 4, seed: int = 0,
                 plan_cache: Optional[str] = None, plan_hw: str = "",
                 chunk: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.max_seq = max_seq
        self.B = batch_size                       # decode slots
        self.plan_cache = plan_cache
        # legalize the chunk to a divisor of max_seq: the chunk grid then
        # tiles the cache exactly and the last chunk of any admissible
        # prompt stays inside [0, max_seq) — otherwise the tail chunk's
        # dynamic_update_slice would CLAMP its start and silently corrupt
        # earlier chunks' K/V
        chunk = max(1, min(chunk or min(32, max_seq), max_seq))
        while max_seq % chunk:
            chunk -= 1
        self.chunk = chunk
        # ONE shape describes the shared donated cache (slots × max_seq):
        # both steps derive identical cache shardings from it on a mesh
        dshape = ShapeConfig("serve_decode", seq_len=max_seq,
                             global_batch=batch_size, kind="decode")
        self.prefill = build_prefill_chunk_step(cfg, dshape, mesh,
                                                chunk=self.chunk,
                                                plan_cache=plan_cache,
                                                plan_hw=plan_hw)
        self.decode = build_decode_step(cfg, dshape, mesh,
                                        plan_cache=plan_cache,
                                        plan_hw=plan_hw)
        if params is None:
            params = lm.init_params(cfg, jax.random.PRNGKey(seed),
                                    self.prefill["ctx"])
        self.params = params
        # device state: the decode cache, donated through every chunk/decode
        # call, holds one region (batch row) per slot
        self.cache = lm.init_cache(cfg, batch_size, max_seq,
                                   self.decode["ctx"])
        # host scheduler state
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.pos = np.zeros((batch_size,), np.int32)      # next write index
        self.live = np.zeros((batch_size,), bool)
        self.last_tok = np.zeros((batch_size,), np.int32)
        self.queue: deque = deque()
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        # per-phase accounting (the CLI summary prints these)
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.prefill_tokens = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.admissions = 0

    # -- streaming API ------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int = 32,
               eos_id: Optional[int] = None) -> int:
        """Queue a request; returns its id. Admission happens on the next
        ``step()`` (or immediately inside ``run()``)."""
        assert len(prompt) + max_new <= self.max_seq, "exceeds engine max_seq"
        assert len(prompt) > 0, "empty prompt"
        req = Request(self._next_rid, list(prompt), max_new, eos_id,
                      submit_t=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    @property
    def pending(self) -> bool:
        return bool(self.queue) or bool(self.live.any())

    def _record_token(self, req: Request, tok: int, t_idx: int) -> bool:
        """Append a generated token; returns True when the request is done
        (eos — possibly on its very FIRST decoded token — or max_new)."""
        req.tokens.append(tok)
        if req.eos_id is not None and tok == req.eos_id:
            req.length = t_idx
            return True
        if t_idx + 1 >= req.max_new:
            req.length = req.max_new
            return True
        return False

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done_t = time.perf_counter()
        req.slot = -1
        self.finished[req.rid] = req
        self.slot_req[slot] = None
        self.live[slot] = False

    def _admit(self, slot: int, req: Request):
        """Chunked prefill of ``req`` into ``slot``'s cache region; the
        first generated token comes from the last chunk's logits."""
        t0 = time.perf_counter()
        C = self.chunk
        plen = len(req.prompt)
        fn = self.prefill["jit"]
        logits = None
        for off in range(0, plen, C):
            part = req.prompt[off:off + C]
            valid = len(part)
            part = part + [0] * (C - valid)
            toks = jnp.asarray([part], jnp.int32)
            logits, self.cache = fn(self.params, self.cache, toks,
                                    jnp.int32(off), jnp.int32(valid),
                                    jnp.int32(slot))
        first = int(np.asarray(jnp.argmax(logits[0])))
        self.prefill_s += time.perf_counter() - t0
        self.prefill_tokens += plen
        self.admissions += 1
        req.slot = slot
        req.first_token_t = time.perf_counter()
        self.slot_req[slot] = req
        self.pos[slot] = plen
        self.last_tok[slot] = first
        self.live[slot] = True
        if self._record_token(req, first, 0):
            self._retire(slot)                    # finished on token 0

    def step(self) -> bool:
        """One scheduler iteration: refill free slots from the queue, then
        advance every live slot by one decoded token. Returns whether any
        work remains."""
        for slot in range(self.B):
            if not self.live[slot] and self.queue:
                self._admit(slot, self.queue.popleft())
        if self.live.any():
            t0 = time.perf_counter()
            toks = jnp.asarray(self.last_tok[:, None])
            nxt, _, self.cache = self.decode["jit"](
                self.params, self.cache, toks, jnp.asarray(self.pos),
                jnp.asarray(self.live))
            nxt = np.asarray(nxt)[:, 0]
            self.decode_s += time.perf_counter() - t0
            self.decode_steps += 1
            self.decode_tokens += int(self.live.sum())
            for slot in range(self.B):
                if not self.live[slot]:
                    continue
                req = self.slot_req[slot]
                self.pos[slot] += 1
                self.last_tok[slot] = int(nxt[slot])
                if self._record_token(req, int(nxt[slot]), len(req.tokens)):
                    self._retire(slot)
        return self.pending

    def run(self) -> Dict[int, Request]:
        """Drain queue + slots; returns {rid: finished Request}."""
        while self.pending:
            self.step()
        return self.finished

    def collect(self, rid: int) -> Request:
        """Pop a finished request's record. Long-running streaming servers
        must collect results (or clear ``finished``) — the engine keeps a
        reference to every uncollected request, tokens included."""
        return self.finished.pop(rid)

    # -- batch convenience wrapper -----------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 eos_id: Optional[int] = None) -> GenerateResult:
        """Submit every prompt, run to completion, return a batch result
        (rows in submit order). More prompts than slots simply queue —
        freed slots are refilled mid-decode."""
        base_steps = self.decode_steps
        rids = [self.submit(p, max_new=max_new, eos_id=eos_id)
                for p in prompts]
        self.run()
        n = len(prompts)
        out = np.zeros((n, max_new), np.int32)
        lengths = np.zeros((n,), np.int64)
        for i, rid in enumerate(rids):
            req = self.collect(rid)
            t = req.tokens[:max_new]
            out[i, :len(t)] = t
            lengths[i] = req.length
        return GenerateResult(out, lengths,
                              prefill_tokens=sum(len(p) for p in prompts),
                              decode_steps=self.decode_steps - base_steps)
