"""Paged block-table KV cache: the host-side page allocator.

The contiguous serving cache gives every decode slot a full ``max_seq``
region, so device memory — not compute — caps the concurrent-request
count. The paged layout replaces the per-slot regions with one shared
pool of fixed-size PAGES per layer: ``(n_pages, page_size, Hkv, hd)``
instead of ``(n_slots, max_seq, Hkv, hd)``. Each request owns just
enough pages for its own budget (``prompt_len + max_new`` tokens), a
block table maps its logical positions to physical pages, and pages
return to the free list the moment the request retires (eos / max_new).
``max_seq`` becomes a per-request *budget* instead of a per-slot
*allocation*: at equal cache memory the pool admits
``~max_seq / mean_request_budget`` times more live requests.

Page id 0 is the NULL page. It is never handed out: block-table rows of
free slots are all-zero, and writes from dead rows / tail-pad tokens are
steered into it, so the device-side scatter needs no branches. Reads
through unmapped table entries gather the null page and are masked by
position validity (``index <= pos``) exactly like stale contiguous-cache
rows were.

The allocator enforces its ownership invariants DEFENSIVELY: freeing a
slot that owns nothing and handing out a page that is already owned both
raise :class:`AllocatorError` instead of silently corrupting the free
list — a double-free that re-lists an owned page would hand the same
physical page to two requests and cross-contaminate their K/V.

This module is pure host-side bookkeeping (plain Python ints — no jax);
the device-side gather/scatter lives in ``models/attention.py`` and the
engine threads the block tables into the jitted steps as ``(n_slots,
max_blocks) int32`` operands.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

NULL_PAGE = 0


class AllocatorError(RuntimeError):
    """Page-ownership invariant violation (double free, double ownership,
    free of an empty slot). Raised *before* the free list is corrupted."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache rows (ceil division)."""
    return -(-max(0, n_tokens) // page_size)


@dataclasses.dataclass
class PagedCacheConfig:
    """Geometry of the shared pool. ``max_blocks`` bounds one request's
    block table (= max_seq / page_size); ``n_pages`` includes the null
    page, so the allocatable budget is ``n_pages - 1``."""
    n_pages: int
    page_size: int
    max_blocks: int

    @property
    def capacity_tokens(self) -> int:
        return (self.n_pages - 1) * self.page_size


class BlockAllocator:
    """Free-list page allocator with per-slot ownership.

    Allocation is all-at-once at admission (the request's full
    ``prompt + max_new`` budget), so a live request can never starve
    mid-decode; reclaim is all-at-once at retire. A LIFO free list keeps
    reuse hot and makes fragmentation a non-issue — pages are fixed-size
    and fungible, any free page serves any block-table entry.

    Every mutation checks the ownership invariant (``used + free ==
    n_pages - 1``, no page owned twice, the null page never leaves) and
    raises :class:`AllocatorError` on violation rather than corrupting
    the free list silently.
    """

    def __init__(self, n_pages: int, page_size: int, max_blocks: int):
        # real exceptions, not asserts: the serving loop must keep these
        # invariants even under python -O
        if n_pages < 2:
            raise ValueError("need at least the null page + one real page")
        if page_size < 1 or max_blocks < 1:
            raise ValueError(f"page_size={page_size}, "
                             f"max_blocks={max_blocks} must be >= 1")
        self.cfg = PagedCacheConfig(n_pages, page_size, max_blocks)
        # page 0 reserved as the null page
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self._owner: Dict[int, int] = {}          # page -> owning slot

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return sum(len(v) for v in self._owned.values())

    def pages_needed(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.cfg.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        """Whether a request with an ``n_tokens`` budget fits right now:
        enough free pages AND within one block table's reach."""
        need = self.pages_needed(n_tokens)
        return 0 < need <= min(self.free_pages, self.cfg.max_blocks)

    def owns(self, slot: int) -> bool:
        return slot in self._owned

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    # -- mutation -----------------------------------------------------------

    def allocate(self, slot: int, n_tokens: int) -> List[int]:
        """Claim the full page budget for ``slot``; returns the page ids in
        block-table order. Raises if the slot already owns pages or the
        budget does not fit (callers gate on ``can_admit``)."""
        if slot in self._owned:
            raise AllocatorError(f"slot {slot} already owns pages")
        need = self.pages_needed(n_tokens)
        if need > self.cfg.max_blocks:
            raise ValueError(
                f"budget {n_tokens} tokens needs {need} pages "
                f"> max_blocks {self.cfg.max_blocks}")
        if need > self.free_pages:
            raise ValueError(
                f"budget {n_tokens} tokens needs {need} pages, "
                f"only {self.free_pages} free")
        pages = []
        for _ in range(need):
            p = self._free.pop()
            if p == NULL_PAGE or p in self._owner:
                # a corrupted free list (double-listed / null page) must
                # surface before the page is handed to a second request
                self._free.extend(reversed(pages))
                raise AllocatorError(
                    f"free list corrupt: page {p} "
                    f"{'is the null page' if p == NULL_PAGE else 'already owned by slot %d' % self._owner.get(p, -1)}")
            self._owner[p] = slot
            pages.append(p)
        self._owned[slot] = pages
        return pages

    def free_slot(self, slot: int) -> int:
        """Reclaim every page ``slot`` owns (slot free / eos); returns how
        many were reclaimed. Freeing a slot that owns nothing raises
        :class:`AllocatorError` — it is always a double free or a stale
        slot id, and silently ignoring it is how ownership bugs hide."""
        if slot not in self._owned:
            raise AllocatorError(
                f"free_slot({slot}): slot owns no pages (double free or "
                f"stale slot id)")
        pages = self._owned.pop(slot)
        for p in pages:
            if self._owner.get(p) != slot:
                raise AllocatorError(
                    f"free_slot({slot}): page {p} owner map disagrees "
                    f"(owned by {self._owner.get(p)})")
            del self._owner[p]
        self._free.extend(pages)
        return len(pages)

    # -- migration (disaggregated prefill/decode handoff) -------------------

    def export_pages(self, slot: int) -> List[int]:
        """Detach ``slot``'s pages for MIGRATION to another pool: returns
        the page ids in block-table order and reclaims them (they join this
        pool's free list immediately, so the exporting worker's capacity is
        back the moment the handoff leaves). The caller must copy the page
        CONTENTS out of the device pool *before* calling this — after it
        returns, the ids may be handed straight to the next admission."""
        if slot not in self._owned:
            raise AllocatorError(
                f"export_pages({slot}): slot owns no pages "
                f"(double export or stale slot id)")
        pages = list(self._owned[slot])
        self.free_slot(slot)
        return pages

    def import_pages(self, slot: int, pages: Sequence[int],
                     block_table: Sequence[int]) -> List[int]:
        """Admit a migrated request into THIS pool: allocate one fresh
        destination page per exported source id, owned by ``slot``. The
        handoff carries the request's FULL ``prompt + max_new`` budget
        (that is what the exporting pool allocated at admission), so the
        all-at-once admission invariant — a live request can never starve
        mid-decode — survives the migration. ``pages`` and ``block_table``
        both come from the exporting pool; the table's non-null prefix
        must equal ``pages``, so a torn handoff (metadata stitched from
        two different exports) fails HERE, before any page content lands.
        Returns the destination ids positionally matched to ``pages``; the
        caller copies page contents src→dst and writes its own table row.
        """
        pages = [int(p) for p in pages]
        table = [int(p) for p in list(block_table)]
        if not pages:
            raise AllocatorError(f"import_pages({slot}): empty page list")
        if NULL_PAGE in pages:
            raise AllocatorError(
                f"import_pages({slot}): null page in the handoff")
        if table[:len(pages)] != pages or \
                any(p != NULL_PAGE for p in table[len(pages):]):
            raise AllocatorError(
                f"import_pages({slot}): block table {table} does not "
                f"describe exported pages {pages} (torn handoff)")
        return self.allocate(slot, len(pages) * self.cfg.page_size)

    # -- invariants / snapshot ---------------------------------------------

    def check(self):
        """Assert the full ownership invariant; raises AllocatorError."""
        total = self.cfg.n_pages - 1
        if self.used_pages + self.free_pages != total:
            raise AllocatorError(
                f"used {self.used_pages} + free {self.free_pages} "
                f"!= total {total}")
        seen: Dict[int, str] = {}
        for p in self._free:
            if p == NULL_PAGE:
                raise AllocatorError("null page on the free list")
            if p in seen:
                raise AllocatorError(f"page {p} listed free twice")
            seen[p] = "free"
        for slot, pages in self._owned.items():
            for p in pages:
                if p == NULL_PAGE:
                    raise AllocatorError(f"null page owned by slot {slot}")
                if p in seen:
                    raise AllocatorError(
                        f"page {p} owned by slot {slot} but also {seen[p]}")
                if self._owner.get(p) != slot:
                    raise AllocatorError(f"owner map stale for page {p}")
                seen[p] = f"owned by {slot}"

    def snapshot_state(self) -> Dict:
        """JSON-serializable state for the engine's crash snapshots."""
        return {"free": list(self._free),
                "owned": {str(s): list(p) for s, p in self._owned.items()}}

    def restore_state(self, state: Dict):
        """Rebuild free list + ownership from :meth:`snapshot_state`."""
        self._free = [int(p) for p in state["free"]]
        self._owned = {int(s): [int(p) for p in pages]
                       for s, pages in state["owned"].items()}
        self._owner = {p: s for s, pages in self._owned.items()
                       for p in pages}
        self.check()
