"""Mesh construction + axis context shared by the whole framework."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import compat


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    n = math.prod(shape)
    if n > len(jax.devices()):
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {n} devices, have {len(jax.devices())}; "
            "the dry-run launcher sets XLA_FLAGS=--xla_force_host_platform_device_count")
    return compat.make_mesh(tuple(shape), tuple(axes))


@dataclass(frozen=True)
class AxisCtx:
    """How the model maps onto mesh axes. ep*etp must equal the model-axis size."""
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ()      # batch axes, e.g. ("pod", "data")
    model_axis: str = ""               # TP / EP / SP axis
    ep: int = 1                        # expert-parallel group size
    etp: int = 1                       # expert-tensor-parallel (d_ff) group size
    seq_shard: bool = False            # sequence-parallel activations into MoE

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.model_axis != ""

    @property
    def world(self) -> int:
        return self.ep * self.etp

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.dp_axes) if self.dp_axes else 1

    @property
    def model_size(self) -> int:
        if self.mesh is None or not self.model_axis:
            return 1
        return self.mesh.shape[self.model_axis]

    def tp_groups(self):
        """axis_index_groups: ranks sharing tp index (EP collectives), size ep."""
        if self.etp == 1:
            return None
        return [[g * self.etp + t for g in range(self.ep)] for t in range(self.etp)]

    def etp_groups(self):
        """axis_index_groups: ranks sharing ep group (ETP psum), size etp."""
        if self.etp == 1:
            return None
        return [[g * self.etp + t for t in range(self.etp)] for g in range(self.ep)]


def choose_ep(num_experts: int, model_size: int, requested: int = 0) -> Tuple[int, int]:
    """Pick (ep, etp) with ep*etp == model_size, ep | num_experts, maximizing ep."""
    if requested:
        if model_size % requested or num_experts % requested:
            raise ValueError(f"requested ep={requested} incompatible with "
                             f"E={num_experts}, model={model_size}")
        return requested, model_size // requested
    ep = 1
    for cand in range(1, model_size + 1):
        if model_size % cand == 0 and num_experts % cand == 0:
            ep = cand
    return ep, model_size // ep


def batch_sharding(ctx: AxisCtx):
    if not ctx.active:
        return None
    return NamedSharding(ctx.mesh, P(ctx.dp_axes if ctx.dp_axes else None, None))


def local_ctx() -> AxisCtx:
    return AxisCtx()
