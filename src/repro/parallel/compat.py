"""JAX version compatibility: manual-SPMD entry points across 0.4.x–0.6.x.

The repo targets three generations of the JAX sharding API:

* ``shard_map`` — top-level ``jax.shard_map`` (0.5.3+, keyword ``check_vma``)
  vs ``jax.experimental.shard_map.shard_map`` (0.4.x–0.6.x, keyword
  ``check_rep``). Same semantics; only the import path and the name of the
  replication-check flag changed.
* mesh activation — ``jax.set_mesh`` (0.6+) vs ``jax.sharding.use_mesh``
  (0.5.x) vs plain ``Mesh.__enter__`` (0.4.x). All are usable as
  ``with use_mesh(mesh): ...``.
* ``make_mesh`` — ``jax.make_mesh`` (0.4.35+) vs hand-rolled
  ``mesh_utils.create_device_mesh`` + ``Mesh``.

Every call site in the repo goes through this module so a JAX upgrade (or
downgrade, as on the CI CPU image) is a no-op for the rest of the code.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def _resolve_shard_map():
    """Pick (shard_map function, replication-check kwarg name) once."""
    import inspect
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    try:
        params = inspect.signature(fn).parameters
        flag = "check_vma" if "check_vma" in params else "check_rep"
    except (TypeError, ValueError):      # signature not introspectable
        flag = "check_rep"
    return fn, flag


_SHARD_MAP, _CHECK_FLAG = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kwargs):
    """Version-portable ``shard_map``. Accepts the modern ``check_vma``
    keyword and translates it to ``check_rep`` for older JAX."""
    kwargs[_CHECK_FLAG] = check_vma
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def use_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for the enclosed computation."""
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(jax, "set_mesh"):
        prev = getattr(jax.sharding, "get_mesh", lambda: None)()
        cm = jax.set_mesh(mesh)
        # jax.set_mesh is a context manager in recent releases; versions
        # where it is a pure global setter return None — restore the
        # previously active mesh on exit then.
        if cm is not None and hasattr(cm, "__enter__"):
            return cm
        return _restore_mesh_on_exit(prev)
    return mesh  # 0.4.x: Mesh is itself a context manager


@contextlib.contextmanager
def _restore_mesh_on_exit(prev):
    try:
        yield
    finally:
        jax.set_mesh(prev)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None,
              axis_types: Optional[Sequence] = None) -> Mesh:
    """Build a device mesh of ``shape`` with named ``axes``.

    ``axis_types`` (jax.sharding.AxisType entries, 0.6+) is forwarded when
    this JAX accepts it and silently dropped otherwise — callers state
    intent once and stay version-portable."""
    shape, axes = tuple(shape), tuple(axes)
    if devices is None and hasattr(jax, "make_mesh"):
        if axis_types is not None:
            try:
                return jax.make_mesh(shape, axes,
                                     axis_types=tuple(axis_types))
            except TypeError:   # older jax without the axis_types kwarg
                pass
        return jax.make_mesh(shape, axes)
    import numpy as np
    devs = np.asarray(devices if devices is not None else jax.devices())
    import math
    n = math.prod(shape)
    return Mesh(devs[:n].reshape(shape), axes)
