"""Logical-axis → mesh-axis rules (MaxText-style) + context construction."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ParamDecl
from repro.parallel.mesh import AxisCtx, choose_ep

# logical axes used by the schemas:
#   vocab, embed, embed_v (norm vectors), qheads, kvheads, ffn,
#   expert_shard, experts_v, ssm_in, ssm_conv, ssm_inner, ssm_heads, layers


def make_rules(fsdp: bool) -> Dict[str, Optional[str]]:
    return {
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "embed_v": None,
        "qheads": "model",
        "kvheads": "model",
        "ffn": "model",
        "expert_shard": "model",
        "experts_v": None,
        "ssm_in": "model",
        "ssm_conv": "model",
        "ssm_inner": "model",
        "ssm_heads": None,
        "layers": None,
    }


def decl_spec(decl: ParamDecl, rules: Dict[str, Optional[str]],
              axis_sizes: Dict[str, int]) -> P:
    axes = []
    used = set()
    for dim, logical in zip(decl.shape, decl.logical):
        ax = rules.get(logical) if logical is not None else None
        if ax is not None and (dim % axis_sizes.get(ax, 1) != 0 or ax in used):
            ax = None                       # non-divisible or repeated: replicate
        if ax is not None:
            used.add(ax)
        axes.append(ax)
    return P(*axes)


def param_specs(schema, mesh: Mesh, fsdp: bool):
    rules = make_rules(fsdp)
    sizes = dict(mesh.shape)
    return jax.tree_util.tree_map(
        lambda d: decl_spec(d, rules, sizes), schema,
        is_leaf=lambda x: isinstance(x, ParamDecl))


def make_ctx(cfg, mesh: Optional[Mesh], seq_shard: bool = True) -> AxisCtx:
    if mesh is None:
        return AxisCtx()
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    msize = mesh.shape.get("model", 1)
    ep = etp = 1
    if cfg.moe is not None:
        ep, etp = choose_ep(cfg.moe.num_experts, msize, cfg.moe.ep)
        # also require d_expert divisible by etp
        while etp > 1 and cfg.moe.d_expert % etp:
            etp //= 2
            ep = msize // etp
        if cfg.moe.num_experts % ep:
            raise ValueError(f"no valid (ep, etp) for E={cfg.moe.num_experts} "
                             f"on model axis {msize}")
    else:
        ep, etp = msize, 1
    return AxisCtx(mesh=mesh, dp_axes=dp_axes, model_axis="model",
                   ep=ep, etp=etp, seq_shard=seq_shard)


def cache_specs(cfg, ctx: AxisCtx, batch: int, seq_len: int, enc_len: int = 0):
    """PartitionSpec tree matching lm.init_cache layout: shard KV over
    (batch→dp, heads→model if divisible else seq→model if divisible)."""
    from repro.models.lm import period_of
    msize = ctx.model_size
    dp = ctx.dp_axes
    dp_ok = batch % max(1, ctx.dp_size) == 0 and batch > 1
    bspec = dp if dp_ok else None

    def kv_spec(n_heads, slen):
        if n_heads % msize == 0:
            return P(None, bspec, None, "model", None)
        if slen % msize == 0:
            return P(None, bspec, "model", None, None)
        return P(None, bspec, None, None, None)

    p = period_of(cfg)
    a = cfg.attn
    specs = []
    for pos in range(p):
        kind = cfg.layer_kind(pos)
        if kind == "a":
            e = {"k": kv_spec(a.n_kv_heads, seq_len),
                 "v": kv_spec(a.n_kv_heads, seq_len)}
            if cfg.n_enc_layers:
                e["xk"] = kv_spec(a.n_kv_heads, enc_len)
                e["xv"] = kv_spec(a.n_kv_heads, enc_len)
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.d_state
            e = {"conv": P(None, bspec, None,
                           "model" if conv_ch % msize == 0 else None),
                 "state": P(None, bspec, "model" if nh % msize == 0 else None,
                            None, None)}
        specs.append(e)
    return tuple(specs)


def paged_cache_specs(cfg, ctx: AxisCtx, n_slots: int):
    """PartitionSpec tree matching lm.init_paged_cache layout: K/V page
    pools (n_periods, n_pages, page, Hkv, hd) shard on the kv-head axis
    when divisible (the pool's page axis is position-interleaved — never
    sharded); SSM entries keep the dense per-slot specs."""
    from repro.models.lm import period_of
    msize = ctx.model_size
    dp_ok = n_slots % max(1, ctx.dp_size) == 0 and n_slots > 1
    bspec = ctx.dp_axes if dp_ok else None
    a = cfg.attn
    kv = P(None, None, None,
           "model" if a is not None and a.n_kv_heads % msize == 0 else None,
           None)
    s = cfg.ssm
    specs = []
    for pos in range(period_of(cfg)):
        if cfg.layer_kind(pos) == "a":
            e = {"k": kv, "v": kv}
        else:
            d_in = s.expand * cfg.d_model
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.d_state
            e = {"conv": P(None, bspec, None,
                           "model" if conv_ch % msize == 0 else None),
                 "state": P(None, bspec, "model" if nh % msize == 0 else None,
                            None, None)}
        specs.append(e)
    return tuple(specs)
