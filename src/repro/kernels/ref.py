"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_gemm_ref(lhs, rhs, out_dtype=None):
    """lhs: (E, M, K); rhs: (E, K, N) -> (E, M, N), fp32 accumulation."""
    out = jnp.einsum("emk,ekn->emn", lhs.astype(jnp.float32),
                     rhs.astype(jnp.float32))
    return out.astype(out_dtype or lhs.dtype)


def flash_attention_ref(q, k, v, causal=True):
    """q: (B,Hq,Sq,hd); k/v: (B,Hkv,Sk,hd). fp32 softmax oracle."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-5):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def fused_mlp_ref(rows, w_gate, w_up, w_down, activation):
    """Unfused oracle for the fused expert-MLP kernel: GEMM1 -> activation ->
    GEMM2 with the hidden materialized, numerics matching the xla backend
    (einsum in the input dtype). rows: (E, R, d) -> (E, R, N)."""
    from repro.models.common import activate
    up = jnp.einsum("erd,edf->erf", rows, w_up)
    if w_gate is not None:
        gate = jnp.einsum("erd,edf->erf", rows, w_gate)
        h = activate(activation, gate, up)
    else:
        h = activate(activation, None, up)
    return jnp.einsum("erf,efn->ern", h.astype(rows.dtype), w_down)


def topk_combine_ref(rows, weights):
    out = jnp.einsum("tkd,tk->td", rows.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return out.astype(rows.dtype)


def ssd_ref(x, dt, A, Bm, Cm, D):
    """Sequential SSD recurrence oracle (== models/ssm.ssd_reference).
    x: (B,S,nh,hd); dt: (B,S,nh); A/D: (nh,); Bm/Cm: (B,S,ds)."""
    from repro.models.ssm import ssd_reference
    return ssd_reference(x, dt, A, Bm, Cm, D)
