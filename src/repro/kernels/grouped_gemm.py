"""Grouped GEMM Pallas-TPU kernel with Comet traversal orders.

Computes ``out[e] = lhs[e] @ rhs[e]`` for all local experts e in ONE kernel
(the paper's GroupGEMM), with fp32 accumulation in VMEM scratch and MXU-sized
(128-multiple) tiles.

The Comet-specific feature is the **grid traversal order** (paper Fig. 6):

* ``order="expert_major"`` — grid (E, Mt, Nt, Kt): finish expert 0's whole
  output, then expert 1, … The combine for any output column can only start
  after the LAST expert finishes: no early tiles for the consumer.
* ``order="n_major"`` — grid (Nt, E, Mt, Kt): column-block 0 of EVERY expert
  completes first, so the layer-1 consumer (top-k reduce + return traffic) can
  start after a 1/Nt fraction of compute — exactly the paper's rescheduled
  column-major GroupGEMM. On real TPU the consumer is the async combine DMA;
  the traversal order controls *tile completion order*, which is what the
  overlap schedule keys on.

Grid iteration on TPU is sequential row-major over the grid tuple, so placing
N (resp. E) first is a faithful realization of the two schedules.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(lhs_ref, rhs_ref, out_ref, acc_ref, *, nk: int):
    """One (bm, bn) tile of one expert; K-loop innermost via the grid."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(lhs_ref[0], rhs_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def grouped_gemm(lhs: jnp.ndarray, rhs: jnp.ndarray, *,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 order: str = "expert_major",
                 out_dtype=None,
                 interpret: bool = False) -> jnp.ndarray:
    """lhs: (E, M, K); rhs: (E, K, N) -> (E, M, N).

    Block sizes are clamped to the problem and must divide it (callers pad);
    MXU alignment wants multiples of 128 on M/N and of 256 on K for bf16.
    """
    E, M, K = lhs.shape
    E2, K2, N = rhs.shape
    assert E == E2 and K == K2, (lhs.shape, rhs.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"blocks ({bm},{bn},{bk}) must divide problem ({M},{N},{K})"
    mt, nt, kt = M // bm, N // bn, K // bk
    out_dtype = out_dtype or lhs.dtype

    if order == "expert_major":
        grid = (E, mt, nt, kt)
        lhs_map = lambda e, m, n, k: (e, m, k)
        rhs_map = lambda e, m, n, k: (e, k, n)
        out_map = lambda e, m, n, k: (e, m, n)
    elif order == "n_major":
        grid = (nt, E, mt, kt)
        lhs_map = lambda n, e, m, k: (e, m, k)
        rhs_map = lambda n, e, m, k: (e, k, n)
        out_map = lambda n, e, m, k: (e, m, n)
    else:
        raise ValueError(f"unknown order {order!r}")

    kernel = functools.partial(_gg_kernel, nk=kt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lhs_map),
            pl.BlockSpec((1, bk, bn), rhs_map),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), out_map),
        out_shape=jax.ShapeDtypeStruct((E, M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs)


def grouped_gemm_padded(lhs, rhs, *, bm=128, bn=128, bk=512,
                        order="expert_major", out_dtype=None,
                        interpret=False):
    """Pads M/N/K up to block multiples, runs the kernel, slices back."""
    E, M, K = lhs.shape
    N = rhs.shape[-1]
    pad = lambda x, b: (b - x % b) % b
    bm_, bn_, bk_ = min(bm, max(M, 1)), min(bn, max(N, 1)), min(bk, max(K, 1))
    pm, pn, pk = pad(M, bm_), pad(N, bn_), pad(K, bk_)
    if pm or pk:
        lhs = jnp.pad(lhs, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        rhs = jnp.pad(rhs, ((0, 0), (0, pk), (0, pn)))
    out = grouped_gemm(lhs, rhs, bm=bm_, bn=bn_, bk=bk_, order=order,
                       out_dtype=out_dtype, interpret=interpret)
    return out[:, :M, :N]
