"""Flash attention (causal, GQA) as a Pallas-TPU kernel.

Grid (B*Hq, Sq/bq, Sk/bk) with the KV dimension innermost; the running
(max, sum, acc) state lives in VMEM scratch and persists across the KV grid
dimension (standard TPU flash pattern). GQA is handled in the K/V BlockSpec
index maps (query head h reads KV head h // (Hq/Hkv)) — no materialized
repeat. Causal masking compares absolute q/k positions; fully-masked KV
blocks are skipped via ``pl.when`` (upper-triangle tiles cost zero MXU work,
the same block-skip the fused CUTLASS kernels in the paper rely on).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, nk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    first_q = qi * bq                      # absolute position of this q block
    first_k = ki * bk
    run = (not causal) or (first_k <= first_q + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = first_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    qr = q.reshape(B * Hq, Sq, hd)
    kr = k.reshape(B * Hkv, Sk, hd)
    vr = v.reshape(B * Hkv, Sk, hd)

    # bh enumerates (b, hq): kv row index = b * Hkv + hq // rep
    def kv_map(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // rep, ki, 0)

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, nk=nk,
        scale=1.0 / (hd ** 0.5), causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, hd)
