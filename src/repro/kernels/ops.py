"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on TPU the same
``pallas_call`` compiles to Mosaic. ``interpret`` auto-detects the backend
unless forced via keyword.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _gg
from repro.kernels import rmsnorm as _rn
from repro.kernels import topk_combine as _tc


def _interp(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "order",
                                             "interpret"))
def grouped_gemm(lhs, rhs, bm: int = 128, bn: int = 128, bk: int = 512,
                 order: str = "expert_major", interpret: Optional[bool] = None):
    return _gg.grouped_gemm_padded(lhs, rhs, bm=bm, bn=bn, bk=bk, order=order,
                                   interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-5, bt: int = 256,
            interpret: Optional[bool] = None):
    return _rn.rmsnorm(x, scale, eps=eps, bt=bt, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def topk_combine(rows, weights, bt: int = 256,
                 interpret: Optional[bool] = None):
    return _tc.topk_combine(rows, weights, bt=bt, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_forward(x, dt, A, Bm, Cm, D, chunk: int = 64,
                interpret: Optional[bool] = None):
    from repro.kernels import ssd as _ssd
    return _ssd.ssd_forward(x, dt, A, Bm, Cm, D, chunk=chunk,
                            interpret=_interp(interpret))
