"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on TPU the same
``pallas_call`` compiles to Mosaic. ``interpret`` auto-detects the backend
unless forced via keyword.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _gg
from repro.kernels import rmsnorm as _rn
from repro.kernels import topk_combine as _tc


def _interp(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "order",
                                             "interpret"))
def grouped_gemm(lhs, rhs, bm: int = 128, bn: int = 128, bk: int = 512,
                 order: str = "expert_major", interpret: Optional[bool] = None):
    return _gg.grouped_gemm_padded(lhs, rhs, bm=bm, bn=bn, bk=bk, order=order,
                                   interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-5, bt: int = 256,
            interpret: Optional[bool] = None):
    return _rn.rmsnorm(x, scale, eps=eps, bt=bt, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def topk_combine(rows, weights, bt: int = 256,
                 interpret: Optional[bool] = None):
    return _tc.topk_combine(rows, weights, bt=bt, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def topk_combine_diff(rows, weights, bt: int = 256,
                      interpret: Optional[bool] = None):
    """Differentiable combine kernel (custom_vjp) — what routing.combine
    calls inside the MoE layer."""
    return _tc.topk_combine_diff(rows, weights, bt=bt,
                                 interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("activation", "col_slice",
                                             "order", "bm", "bf", "bn",
                                             "interpret"))
def fused_mlp(rows, w, activation: str,
              col_slice: Optional[tuple] = None, order: str = "expert_major",
              bm: int = 128, bf: int = 512, bn: int = 0,
              interpret: Optional[bool] = None):
    """Fused GEMM1→activation→GEMM2 expert MLP (kernels/fused_mlp.py) — the
    ``"pallas_fused"`` GroupGEMM backend. ``w`` is the expert-weight dict
    (w_gate optional, w_up, w_down); ``col_slice=(start, width)`` computes
    only that output-column block (transport_comet's layer-1 decomposition),
    recomputing the hidden in VMEM instead of re-reading it from HBM."""
    from jax import lax

    from repro.kernels import fused_mlp as _fm
    wd = w["w_down"]
    if col_slice is not None:
        wd = lax.dynamic_slice_in_dim(wd, col_slice[0], col_slice[1], axis=2)
    return _fm.fused_mlp_padded(rows, w.get("w_gate"), w["w_up"], wd,
                                activation=activation, bm=bm, bf=bf, bn=bn,
                                order=order, interpret=_interp(interpret))


def _sliced_wd(w, col_slice):
    from jax import lax
    wd = w["w_down"]
    if col_slice is not None:
        wd = lax.dynamic_slice_in_dim(wd, col_slice[0], col_slice[1], axis=2)
    return wd


@functools.partial(jax.jit, static_argnames=("activation", "col_slice",
                                             "bm", "bf", "interpret"))
def fused_mlp_dgrad(rows, w, dy, activation: str,
                    col_slice: Optional[tuple] = None,
                    bm: int = 128, bf: int = 512,
                    interpret: Optional[bool] = None):
    """Explicit dgrad of the fused expert MLP (kernels/fused_mlp.py):
    dX from a (possibly column-sliced) dY, hidden recomputed in VMEM.
    Per-block calls sum to the full dX (linearity in dY) — the comet
    backward ring's per-column-block dY consumption."""
    from repro.kernels import fused_mlp as _fm
    return _fm.fused_mlp_dgrad_padded(
        rows, w.get("w_gate"), w["w_up"], _sliced_wd(w, col_slice), dy,
        activation=activation, bm=bm, bf=bf, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("activation", "col_slice",
                                             "bm", "bf", "interpret"))
def fused_mlp_wgrad(rows, w, dy, activation: str,
                    col_slice: Optional[tuple] = None,
                    bm: int = 128, bf: int = 512,
                    interpret: Optional[bool] = None):
    """Explicit wgrad of the fused expert MLP: (dw_gate|None, dw_up,
    dw_down). With ``col_slice`` the returned dw_down covers only that
    column block; dw_up/dw_gate are the block's partials (they sum over
    blocks to the full gradient)."""
    from repro.kernels import fused_mlp as _fm
    return _fm.fused_mlp_wgrad_padded(
        rows, w.get("w_gate"), w["w_up"], _sliced_wd(w, col_slice), dy,
        activation=activation, bm=bm, bf=bf, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_forward(x, dt, A, Bm, Cm, D, chunk: int = 64,
                interpret: Optional[bool] = None):
    from repro.kernels import ssd as _ssd
    return _ssd.ssd_forward(x, dt, A, Bm, Cm, D, chunk=chunk,
                            interpret=_interp(interpret))
