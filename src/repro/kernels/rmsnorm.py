"""RMSNorm Pallas-TPU kernel: row tiles in VMEM, fp32 statistics."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (bt, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-5,
            bt: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (T, d); scale: (d,) -> (T, d)."""
    T, d = x.shape
    bt = min(bt, T)
    pad = (bt - T % bt) % bt
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=((T + pad) // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T + pad, d), x.dtype),
        interpret=interpret,
    )(xp, scale)
    return out[:T] if pad else out
