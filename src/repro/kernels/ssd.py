"""Mamba-2 SSD (state-space duality) forward as a Pallas-TPU kernel.

The XLA lowering of the chunked dual form materializes the (B, NC, Q, Q, nh)
decay tensor L and the per-chunk scan residuals in HBM — EXPERIMENTS.md §Perf
measured that traffic dominating mamba2's memory term. This kernel keeps the
whole intra-chunk working set (cum, L, CB, states) in VMEM:

  grid = (B*nh, NC)  — NC innermost; TPU grid iteration is sequential, so the
  inter-chunk recurrence h ← h·exp(total) + state carries through a VMEM
  scratch across the NC dimension exactly like flash attention's (m, l, acc).

Per (bh, c) step, everything is (Q, Q) / (Q, hd) / (ds, hd) tiles:
  cum   = cumsum(dt·A)                      (Q,)
  L     = tril(exp(cum_i - cum_j))          (Q, Q)    — never leaves VMEM
  CB    = C @ Bᵀ                            (Q, Q)
  y     = (CB ⊙ L) @ (x·dt)  +  exp(cum)·C @ h  +  D·x
  h    += exp(total - cum_j)·Bᵀ @ (x·dt)    (ds, hd)

HBM traffic is exactly the boundary: read x, dt, B, C once; write y once —
the roofline-model contract behind the ``__fusable__`` accounting.
The pure-jnp oracle is kernels/ref.py::ssd_ref (== models/ssm.ssd_reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref,
                h_ref, *, nc: int, Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)                  # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)                # (Q, 1)
    A = a_ref[0, 0]                                   # scalar (this head)
    Bm = b_ref[0].astype(jnp.float32)                 # (Q, ds)
    Cm = c_ref[0].astype(jnp.float32)                 # (Q, ds)
    D = d_ref[0, 0]

    xd = x * dt                                       # discretized input
    la = dt[:, 0] * A                                 # (Q,) log-decay ≤ 0
    cum = jnp.cumsum(la)                              # (Q,)
    total = cum[-1]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j, else 0
    diff = cum[:, None] - cum[None, :]                # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    y = jnp.dot(CB * L, xd, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state, then update it
    h = h_ref[...]                                    # (ds, hd) fp32
    y = y + jnp.exp(cum)[:, None] * jnp.dot(Cm, h,
                                            preferred_element_type=jnp.float32)
    decay_to_end = jnp.exp(total - cum)               # (Q,)
    h_ref[...] = h * jnp.exp(total) + jax.lax.dot_general(
        Bm * decay_to_end[:, None], xd, (((0,), (0,)), ((), ())))

    y_ref[0] = (y + D * x).astype(y_ref.dtype)


def ssd_forward(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, D: jnp.ndarray, *,
                chunk: int = 64, interpret: bool = False) -> jnp.ndarray:
    """x: (B, S, nh, hd); dt: (B, S, nh); A/D: (nh,); Bm/Cm: (B, S, ds).
    Returns y: (B, S, nh, hd). S must be a multiple of ``chunk``."""
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    # (B*nh, NC*Q, ·) layouts so the grid can be (B*nh, NC)
    xr = x.transpose(0, 2, 1, 3).reshape(Bsz * nh, S, hd)
    dtr = dt.transpose(0, 2, 1).reshape(Bsz * nh, S, 1)
    br = jnp.broadcast_to(Bm[:, None], (Bsz, nh, S, ds)).reshape(
        Bsz * nh, S, ds)
    cr = jnp.broadcast_to(Cm[:, None], (Bsz, nh, S, ds)).reshape(
        Bsz * nh, S, ds)
    ar = jnp.broadcast_to(A[None, :], (Bsz, nh)).reshape(Bsz * nh, 1)
    dr = jnp.broadcast_to(D[None, :], (Bsz, nh)).reshape(Bsz * nh, 1)

    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    out = pl.pallas_call(
        kernel,
        grid=(Bsz * nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
            pl.BlockSpec((1, Q, ds), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, ds), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1), lambda bh, c: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, hd), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz * nh, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, hd), jnp.float32)],   # carried state
        interpret=interpret,
    )(xr, dtr, ar, br, cr, dr)
    return out.reshape(Bsz, nh, S, hd).transpose(0, 2, 1, 3)
