"""Fused expert-MLP Pallas-TPU kernel: GEMM1 → activation → GEMM2 in ONE
``pallas_call`` — the per-tile hidden activations live only in VMEM.

The unfused pipeline (``transport.expert_gemm1`` + ``expert_gemm2``)
materializes the hidden tensor ``h`` of shape (E_loc, R, f_loc) in HBM
between the two GroupGEMMs, and every N-decomposed GEMM2 column-block call
re-reads all of it. This kernel eliminates that round trip entirely: for
each (expert, row-tile, column-tile) output tile it streams f-chunks of the
expert weights through VMEM, computes the corresponding hidden chunk
``act(x @ w_gate[:, fc], x @ w_up[:, fc])`` on the fly, and accumulates
``h_chunk @ w_down[fc, :]`` into an fp32 VMEM accumulator. ``h`` never has
an HBM address.

Traversal orders mirror ``grouped_gemm.py`` (paper Fig. 6):

* ``order="expert_major"`` — grid (E, Mt, Nt, Ft): expert 0's output
  finishes first.
* ``order="n_major"``     — grid (Nt, E, Mt, Ft): column-block 0 of EVERY
  expert completes first, so the layer-1 consumer (combine + return
  traffic) can start after a 1/Nt fraction of the output.

Column-sliced calls (``transport_comet``'s N-decomposed early return) pass
a pre-sliced ``w_down`` — each per-block call recomputes its GEMM1 chunks
instead of re-reading an HBM-resident ``h``; the adaptive cost model
(``core/adaptive.py``) weighs exactly this recompute-vs-traffic trade.

VMEM budget per grid step: x tile (bm, d) + w_gate/w_up chunks (d, bf) +
w_down chunk (bf, bn) + fp32 accumulator (bm, bn). The d (d_model)
contraction is NOT chunked — callers with d ≳ 8k should shrink bf/bn.

Gradients: ``pallas_call`` has no automatic VJP, so ``fused_mlp_padded``
carries a ``jax.custom_vjp`` whose backward pass differentiates the pure-jnp
oracle (``kernels/ref.fused_mlp_ref``) — rematerialized, numerically the
same contraction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.common import activate


def _fused_kernel(*refs, nf: int, activation: str, glu: bool, n_pos: int):
    """One (bm, bn) output tile of one expert; F-chunk loop via the grid
    (innermost dim). ``n_pos`` is the grid position of the N index (2 for
    expert_major, 0 for n_major) — unused in the body but documents that the
    F axis (position 3) is the only accumulation axis."""
    del n_pos
    if glu:
        x_ref, wg_ref, wu_ref, wd_ref, out_ref, acc_ref = refs
    else:
        x_ref, wu_ref, wd_ref, out_ref, acc_ref = refs
        wg_ref = None
    fi = pl.program_id(3)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                            # (bm, d)
    up = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    if glu:
        gate = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        h = activate(activation, gate, up)                  # (bm, bf) fp32
    else:
        h = activate(activation, None, up)
    # match the unfused pipeline, which materializes h in the input dtype
    h = h.astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def fused_mlp(rows, w_gate, w_up, w_down, *, activation: str,
              bm: int = 128, bf: int = 512, bn: int = 0,
              order: str = "expert_major", out_dtype=None,
              interpret: bool = False) -> jnp.ndarray:
    """rows: (E, R, d); w_gate/w_up: (E, d, f) (w_gate None for non-GLU);
    w_down: (E, f, N) -> (E, R, N). Block sizes must divide the problem
    (callers pad); ``bn == 0`` means one full-width N tile."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    glu = w_gate is not None
    bm, bf = min(bm, R), min(bf, f)
    bn = N if bn <= 0 else min(bn, N)
    assert R % bm == 0 and f % bf == 0 and N % bn == 0, \
        f"blocks ({bm},{bf},{bn}) must divide problem (R={R},f={f},N={N})"
    mt, nt, ft = R // bm, N // bn, f // bf
    out_dtype = out_dtype or rows.dtype

    if order == "expert_major":
        grid = (E, mt, nt, ft)
        ix = lambda e, m, n, fi: (e, m, 0)
        iw1 = lambda e, m, n, fi: (e, 0, fi)
        iwd = lambda e, m, n, fi: (e, fi, n)
        io = lambda e, m, n, fi: (e, m, n)
        n_pos = 2
    elif order == "n_major":
        grid = (nt, E, mt, ft)
        ix = lambda n, e, m, fi: (e, m, 0)
        iw1 = lambda n, e, m, fi: (e, 0, fi)
        iwd = lambda n, e, m, fi: (e, fi, n)
        io = lambda n, e, m, fi: (e, m, n)
        n_pos = 0
    else:
        raise ValueError(f"unknown order {order!r}")

    in_specs = [pl.BlockSpec((1, bm, d), ix)]
    args = [rows]
    if glu:
        in_specs.append(pl.BlockSpec((1, d, bf), iw1))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, d, bf), iw1))
    args.append(w_up)
    in_specs.append(pl.BlockSpec((1, bf, bn), iwd))
    args.append(w_down)

    kernel = functools.partial(_fused_kernel, nf=ft, activation=activation,
                               glu=glu, n_pos=n_pos)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), io),
        out_shape=jax.ShapeDtypeStruct((E, R, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)


def _fused_mlp_run(rows, w_gate, w_up, w_down, *, activation, bm, bf, bn,
                   order, interpret):
    """Pads R/f/N up to block multiples, runs the kernel, slices back.
    Zero-padding is exact: padded x rows give zero outputs (sliced off), and
    padded f columns contribute ``h_pad @ 0`` because w_down's padded rows
    are zero."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    pad = lambda x, b: (b - x % b) % b
    bm_, bf_ = min(bm, max(R, 1)), min(bf, max(f, 1))
    bn_ = N if bn <= 0 else min(bn, N)
    pr, pf, pn = pad(R, bm_), pad(f, bf_), pad(N, bn_)
    if pr:
        rows = jnp.pad(rows, ((0, 0), (0, pr), (0, 0)))
    if pf:
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pf)))
        if w_gate is not None:
            w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pf)))
    if pf or pn:
        w_down = jnp.pad(w_down, ((0, 0), (0, pf), (0, pn)))
    out = fused_mlp(rows, w_gate, w_up, w_down, activation=activation,
                    bm=bm_, bf=bf_, bn=bn_, order=order, interpret=interpret)
    return out[:, :R, :N]


@functools.lru_cache(maxsize=None)
def _diff_fused(activation: str, bm: int, bf: int, bn: int, order: str,
                interpret: bool):
    """custom_vjp closure per static config: forward = Pallas kernel,
    backward = VJP of the jnp oracle (rematerializes the hidden chunk)."""
    from repro.kernels import ref as _ref

    def ref_fn(rows, w_gate, w_up, w_down):
        return _ref.fused_mlp_ref(rows, w_gate, w_up, w_down, activation)

    @jax.custom_vjp
    def f(rows, w_gate, w_up, w_down):
        return _fused_mlp_run(rows, w_gate, w_up, w_down,
                              activation=activation, bm=bm, bf=bf, bn=bn,
                              order=order, interpret=interpret)

    def fwd(rows, w_gate, w_up, w_down):
        return f(rows, w_gate, w_up, w_down), (rows, w_gate, w_up, w_down)

    def bwd(res, ct):
        _, vjp = jax.vjp(ref_fn, *res)
        return vjp(ct)

    f.defvjp(fwd, bwd)
    return f


def fused_mlp_padded(rows, w_gate, w_up, w_down, *, activation: str,
                     bm: int = 128, bf: int = 512, bn: int = 0,
                     order: str = "expert_major",
                     interpret: bool = False) -> jnp.ndarray:
    """Differentiable padded entry point (see module docstring)."""
    fn = _diff_fused(activation, bm, bf, bn, order, bool(interpret))
    return fn(rows, w_gate, w_up, w_down)
