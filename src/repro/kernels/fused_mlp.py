"""Fused expert-MLP Pallas-TPU kernel: GEMM1 → activation → GEMM2 in ONE
``pallas_call`` — the per-tile hidden activations live only in VMEM.

The unfused pipeline (``transport.expert_gemm1`` + ``expert_gemm2``)
materializes the hidden tensor ``h`` of shape (E_loc, R, f_loc) in HBM
between the two GroupGEMMs, and every N-decomposed GEMM2 column-block call
re-reads all of it. This kernel eliminates that round trip entirely: for
each (expert, row-tile, column-tile) output tile it streams f-chunks of the
expert weights through VMEM, computes the corresponding hidden chunk
``act(x @ w_gate[:, fc], x @ w_up[:, fc])`` on the fly, and accumulates
``h_chunk @ w_down[fc, :]`` into an fp32 VMEM accumulator. ``h`` never has
an HBM address.

Traversal orders mirror ``grouped_gemm.py`` (paper Fig. 6):

* ``order="expert_major"`` — grid (E, Mt, Nt, Ft): expert 0's output
  finishes first.
* ``order="n_major"``     — grid (Nt, E, Mt, Ft): column-block 0 of EVERY
  expert completes first, so the layer-1 consumer (combine + return
  traffic) can start after a 1/Nt fraction of the output.

Column-sliced calls (``transport_comet``'s N-decomposed early return) pass
a pre-sliced ``w_down`` — each per-block call recomputes its GEMM1 chunks
instead of re-reading an HBM-resident ``h``; the adaptive cost model
(``core/adaptive.py``) weighs exactly this recompute-vs-traffic trade.

VMEM budget per grid step: x tile (bm, d) + w_gate/w_up chunks (d, bf) +
w_down chunk (bf, bn) + fp32 accumulator (bm, bn). The d (d_model)
contraction is NOT chunked — callers with d ≳ 8k should shrink bf/bn.

Gradients: ``pallas_call`` has no automatic VJP, so ``fused_mlp_padded``
carries a ``jax.custom_vjp``. Its backward runs the explicit dgrad/wgrad
kernels below (PR 3): both rematerialize the hidden chunk in VMEM exactly
like the forward (``h`` never gets an HBM address in either direction), and
both accept a pre-sliced ``w_down``/``dy`` so the comet backward ring can
consume the dcombine stream per column block (the layer-1 N-decomposition
applied to the backward). ``fused_mlp_dgrad`` accumulates
``dX = dgate·w_gateᵀ + dup·w_upᵀ`` over f-chunks; ``fused_mlp_wgrad``
accumulates ``dW`` over row tiles, flushing per f-chunk output blocks. The
pure-jnp oracle (``kernels/ref.fused_mlp_ref``) remains the numerics
reference the tests compare both against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.common import activate


def _fused_kernel(*refs, nf: int, activation: str, glu: bool, n_pos: int):
    """One (bm, bn) output tile of one expert; F-chunk loop via the grid
    (innermost dim). ``n_pos`` is the grid position of the N index (2 for
    expert_major, 0 for n_major) — unused in the body but documents that the
    F axis (position 3) is the only accumulation axis."""
    del n_pos
    if glu:
        x_ref, wg_ref, wu_ref, wd_ref, out_ref, acc_ref = refs
    else:
        x_ref, wu_ref, wd_ref, out_ref, acc_ref = refs
        wg_ref = None
    fi = pl.program_id(3)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                            # (bm, d)
    up = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    if glu:
        gate = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        h = activate(activation, gate, up)                  # (bm, bf) fp32
    else:
        h = activate(activation, None, up)
    # match the unfused pipeline, which materializes h in the input dtype
    h = h.astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def fused_mlp(rows, w_gate, w_up, w_down, *, activation: str,
              bm: int = 128, bf: int = 512, bn: int = 0,
              order: str = "expert_major", out_dtype=None,
              interpret: bool = False) -> jnp.ndarray:
    """rows: (E, R, d); w_gate/w_up: (E, d, f) (w_gate None for non-GLU);
    w_down: (E, f, N) -> (E, R, N). Block sizes must divide the problem
    (callers pad); ``bn == 0`` means one full-width N tile."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    glu = w_gate is not None
    bm, bf = min(bm, R), min(bf, f)
    bn = N if bn <= 0 else min(bn, N)
    assert R % bm == 0 and f % bf == 0 and N % bn == 0, \
        f"blocks ({bm},{bf},{bn}) must divide problem (R={R},f={f},N={N})"
    mt, nt, ft = R // bm, N // bn, f // bf
    out_dtype = out_dtype or rows.dtype

    if order == "expert_major":
        grid = (E, mt, nt, ft)
        ix = lambda e, m, n, fi: (e, m, 0)
        iw1 = lambda e, m, n, fi: (e, 0, fi)
        iwd = lambda e, m, n, fi: (e, fi, n)
        io = lambda e, m, n, fi: (e, m, n)
        n_pos = 2
    elif order == "n_major":
        grid = (nt, E, mt, ft)
        ix = lambda n, e, m, fi: (e, m, 0)
        iw1 = lambda n, e, m, fi: (e, 0, fi)
        iwd = lambda n, e, m, fi: (e, fi, n)
        io = lambda n, e, m, fi: (e, m, n)
        n_pos = 0
    else:
        raise ValueError(f"unknown order {order!r}")

    in_specs = [pl.BlockSpec((1, bm, d), ix)]
    args = [rows]
    if glu:
        in_specs.append(pl.BlockSpec((1, d, bf), iw1))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, d, bf), iw1))
    args.append(w_up)
    in_specs.append(pl.BlockSpec((1, bf, bn), iwd))
    args.append(w_down)

    kernel = functools.partial(_fused_kernel, nf=ft, activation=activation,
                               glu=glu, n_pos=n_pos)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), io),
        out_shape=jax.ShapeDtypeStruct((E, R, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*args)


def _fused_mlp_run(rows, w_gate, w_up, w_down, *, activation, bm, bf, bn,
                   order, interpret):
    """Pads R/f/N up to block multiples, runs the kernel, slices back.
    Zero-padding is exact: padded x rows give zero outputs (sliced off), and
    padded f columns contribute ``h_pad @ 0`` because w_down's padded rows
    are zero."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    pad = lambda x, b: (b - x % b) % b
    bm_, bf_ = min(bm, max(R, 1)), min(bf, max(f, 1))
    bn_ = N if bn <= 0 else min(bn, N)
    pr, pf, pn = pad(R, bm_), pad(f, bf_), pad(N, bn_)
    if pr:
        rows = jnp.pad(rows, ((0, 0), (0, pr), (0, 0)))
    if pf:
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pf)))
        if w_gate is not None:
            w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pf)))
    if pf or pn:
        w_down = jnp.pad(w_down, ((0, 0), (0, pf), (0, pn)))
    out = fused_mlp(rows, w_gate, w_up, w_down, activation=activation,
                    bm=bm_, bf=bf_, bn=bn_, order=order, interpret=interpret)
    return out[:, :R, :N]


# ---------------------------------------------------------------------------
# Backward kernels: explicit dgrad / wgrad entry points (PR 3)
# ---------------------------------------------------------------------------


def _act_vjp(activation: str, glu: bool, gate, up, dh):
    """(dgate, dup) for h = activate(gate, up) given cotangent dh — traced
    jnp math, so it lowers inside the Pallas kernel body."""
    if glu:
        _, vjp = jax.vjp(lambda g, u: activate(activation, g, u), gate, up)
        return vjp(dh)
    _, vjp = jax.vjp(lambda u: activate(activation, None, u), up)
    return None, vjp(dh)[0]


def _dgrad_kernel(*refs, nf: int, activation: str, glu: bool):
    """One (bm, d) dX tile of one expert; f-chunk loop via the grid: each
    chunk recomputes its hidden slice in VMEM (gate/up from x), pulls its
    dh slice out of dY through w_downᵀ, and accumulates both layer-0
    transposed GEMMs into the fp32 dX accumulator."""
    if glu:
        x_ref, wg_ref, wu_ref, wd_ref, dy_ref, dx_ref, acc_ref = refs
    else:
        x_ref, wu_ref, wd_ref, dy_ref, dx_ref, acc_ref = refs
        wg_ref = None
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                            # (bm, d)
    dy = dy_ref[0]                                          # (bm, N)
    up = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    gate = (jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
            if glu else None)
    dh = jnp.dot(dy, wd_ref[0].T, preferred_element_type=jnp.float32)
    # the forward casts h to the input dtype before GEMM2; mirror it so the
    # cotangent enters the activation VJP at matching precision
    dh = dh.astype(x_ref.dtype)
    dgate, dup = _act_vjp(activation, glu, gate, up, dh.astype(jnp.float32))
    acc_ref[...] += jnp.dot(dup.astype(x_ref.dtype), wu_ref[0].T,
                            preferred_element_type=jnp.float32)
    if glu:
        acc_ref[...] += jnp.dot(dgate.astype(x_ref.dtype), wg_ref[0].T,
                                preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _flush():
        dx_ref[0] = acc_ref[...].astype(dx_ref.dtype)


def fused_mlp_dgrad(rows, w_gate, w_up, w_down, dy, *, activation: str,
                    bm: int = 128, bf: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """rows: (E, R, d); w_down: (E, f, N); dy: (E, R, N) -> dX (E, R, d).
    ``w_down``/``dy`` may be a column block of the full output (the comet
    backward ring's per-block dY consumption). Block sizes must divide the
    problem (callers pad); d and N are not chunked."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    glu = w_gate is not None
    bm, bf = min(bm, R), min(bf, f)
    assert R % bm == 0 and f % bf == 0, \
        f"blocks ({bm},{bf}) must divide problem (R={R},f={f})"
    mt, ft = R // bm, f // bf

    grid = (E, mt, ft)
    ix = lambda e, m, fi: (e, m, 0)
    iw1 = lambda e, m, fi: (e, 0, fi)
    iwd = lambda e, m, fi: (e, fi, 0)
    idy = lambda e, m, fi: (e, m, 0)

    in_specs = [pl.BlockSpec((1, bm, d), ix)]
    args = [rows]
    if glu:
        in_specs.append(pl.BlockSpec((1, d, bf), iw1))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, d, bf), iw1))
    args.append(w_up)
    in_specs.append(pl.BlockSpec((1, bf, N), iwd))
    args.append(w_down)
    in_specs.append(pl.BlockSpec((1, bm, N), idy))
    args.append(dy)

    kernel = functools.partial(_dgrad_kernel, nf=ft, activation=activation,
                               glu=glu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, d), ix),
        out_shape=jax.ShapeDtypeStruct((E, R, d), rows.dtype),
        scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        interpret=interpret,
    )(*args)


def _wgrad_kernel(*refs, nm: int, activation: str, glu: bool):
    """One f-chunk of all three dW outputs for one expert; row-tile loop via
    the grid (innermost). Recomputes the hidden chunk in VMEM, consumes the
    dY tile into dw_down = hᵀ·dY and (through the activation VJP) into
    dw_up/dw_gate = xᵀ·d{up,gate}, accumulating fp32 until the last tile."""
    if glu:
        (x_ref, wg_ref, wu_ref, wd_ref, dy_ref,
         dwg_ref, dwu_ref, dwd_ref, accg_ref, accu_ref, accd_ref) = refs
    else:
        (x_ref, wu_ref, wd_ref, dy_ref,
         dwu_ref, dwd_ref, accu_ref, accd_ref) = refs
        wg_ref = dwg_ref = accg_ref = None
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        accd_ref[...] = jnp.zeros_like(accd_ref)
        if glu:
            accg_ref[...] = jnp.zeros_like(accg_ref)

    x = x_ref[0]                                            # (bm, d)
    dy = dy_ref[0]                                          # (bm, N)
    up = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    gate = (jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
            if glu else None)
    h = activate(activation, gate, up) if glu \
        else activate(activation, None, up)
    h = h.astype(x_ref.dtype)                               # matches forward
    accd_ref[...] += jnp.dot(h.T, dy, preferred_element_type=jnp.float32)
    dh = jnp.dot(dy, wd_ref[0].T, preferred_element_type=jnp.float32)
    dh = dh.astype(x_ref.dtype)
    dgate, dup = _act_vjp(activation, glu, gate, up, dh.astype(jnp.float32))
    accu_ref[...] += jnp.dot(x.T, dup.astype(x_ref.dtype),
                             preferred_element_type=jnp.float32)
    if glu:
        accg_ref[...] += jnp.dot(x.T, dgate.astype(x_ref.dtype),
                                 preferred_element_type=jnp.float32)

    @pl.when(mi == nm - 1)
    def _flush():
        dwu_ref[0] = accu_ref[...].astype(dwu_ref.dtype)
        dwd_ref[0] = accd_ref[...].astype(dwd_ref.dtype)
        if glu:
            dwg_ref[0] = accg_ref[...].astype(dwg_ref.dtype)


def fused_mlp_wgrad(rows, w_gate, w_up, w_down, dy, *, activation: str,
                    bm: int = 128, bf: int = 512, interpret: bool = False):
    """rows: (E, R, d); dy: (E, R, N) -> (dw_gate | None, dw_up, dw_down)
    with dw_gate/dw_up: (E, d, f) and dw_down: (E, f, N). A column-sliced
    call (pre-sliced w_down/dy) yields the matching dw_down column block
    and the full-width dw_up/dw_gate PARTIALS for that block — the per-
    column-block contributions sum to the full wgrad (linearity in dY)."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    N = w_down.shape[-1]
    glu = w_gate is not None
    bm, bf = min(bm, R), min(bf, f)
    assert R % bm == 0 and f % bf == 0, \
        f"blocks ({bm},{bf}) must divide problem (R={R},f={f})"
    mt, ft = R // bm, f // bf

    grid = (E, ft, mt)
    ix = lambda e, fi, m: (e, m, 0)
    iw1 = lambda e, fi, m: (e, 0, fi)
    iwd = lambda e, fi, m: (e, fi, 0)
    idy = lambda e, fi, m: (e, m, 0)

    in_specs = [pl.BlockSpec((1, bm, d), ix)]
    args = [rows]
    if glu:
        in_specs.append(pl.BlockSpec((1, d, bf), iw1))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, d, bf), iw1))
    args.append(w_up)
    in_specs.append(pl.BlockSpec((1, bf, N), iwd))
    args.append(w_down)
    in_specs.append(pl.BlockSpec((1, bm, N), idy))
    args.append(dy)

    out_specs = []
    out_shapes = []
    if glu:
        out_specs.append(pl.BlockSpec((1, d, bf), iw1))
        out_shapes.append(jax.ShapeDtypeStruct((E, d, f), w_gate.dtype))
    out_specs.append(pl.BlockSpec((1, d, bf), iw1))
    out_shapes.append(jax.ShapeDtypeStruct((E, d, f), w_up.dtype))
    out_specs.append(pl.BlockSpec((1, bf, N), iwd))
    out_shapes.append(jax.ShapeDtypeStruct((E, f, N), w_down.dtype))

    scratch = []
    if glu:
        scratch.append(pltpu.VMEM((d, bf), jnp.float32))
    scratch.append(pltpu.VMEM((d, bf), jnp.float32))
    scratch.append(pltpu.VMEM((bf, N), jnp.float32))

    kernel = functools.partial(_wgrad_kernel, nm=mt, activation=activation,
                               glu=glu)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    if glu:
        return outs[0], outs[1], outs[2]
    return None, outs[0], outs[1]


def _pad_bwd_args(rows, w_gate, w_up, w_down, dy, bm, bf):
    """Shared zero-padding for the backward kernels (R up to bm, f up to
    bf). Exact: padded rows/f-columns contribute zero to every grad (the
    padded weights are zero, and act(0)·0 chains vanish)."""
    E, R, d = rows.shape
    f = w_up.shape[-1]
    pad = lambda x, b: (b - x % b) % b
    bm_, bf_ = min(bm, max(R, 1)), min(bf, max(f, 1))
    pr, pf = pad(R, bm_), pad(f, bf_)
    if pr:
        rows = jnp.pad(rows, ((0, 0), (0, pr), (0, 0)))
        dy = jnp.pad(dy, ((0, 0), (0, pr), (0, 0)))
    if pf:
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pf)))
        if w_gate is not None:
            w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pf)))
        w_down = jnp.pad(w_down, ((0, 0), (0, pf), (0, 0)))
    return rows, w_gate, w_up, w_down, dy, bm_, bf_, R, f


def fused_mlp_dgrad_padded(rows, w_gate, w_up, w_down, dy, *,
                           activation: str, bm: int = 128, bf: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    rows, w_gate, w_up, w_down, dy, bm_, bf_, R, _ = _pad_bwd_args(
        rows, w_gate, w_up, w_down, dy, bm, bf)
    dx = fused_mlp_dgrad(rows, w_gate, w_up, w_down, dy,
                         activation=activation, bm=bm_, bf=bf_,
                         interpret=interpret)
    return dx[:, :R, :]


def fused_mlp_wgrad_padded(rows, w_gate, w_up, w_down, dy, *,
                           activation: str, bm: int = 128, bf: int = 512,
                           interpret: bool = False):
    rows, w_gate, w_up, w_down, dy, bm_, bf_, _, f = _pad_bwd_args(
        rows, w_gate, w_up, w_down, dy, bm, bf)
    dwg, dwu, dwd = fused_mlp_wgrad(rows, w_gate, w_up, w_down, dy,
                                    activation=activation, bm=bm_, bf=bf_,
                                    interpret=interpret)
    if dwg is not None:
        dwg = dwg[:, :, :f]
    return dwg, dwu[:, :, :f], dwd[:, :f, :]


@functools.lru_cache(maxsize=None)
def _diff_fused(activation: str, bm: int, bf: int, bn: int, order: str,
                interpret: bool):
    """custom_vjp closure per static config: forward = fused Pallas kernel,
    backward = the explicit dgrad + wgrad kernels (hidden rematerialized in
    VMEM both ways)."""

    @jax.custom_vjp
    def f(rows, w_gate, w_up, w_down):
        return _fused_mlp_run(rows, w_gate, w_up, w_down,
                              activation=activation, bm=bm, bf=bf, bn=bn,
                              order=order, interpret=interpret)

    def fwd(rows, w_gate, w_up, w_down):
        return f(rows, w_gate, w_up, w_down), (rows, w_gate, w_up, w_down)

    def bwd(res, ct):
        rows, w_gate, w_up, w_down = res
        ct = ct.astype(rows.dtype)
        dx = fused_mlp_dgrad_padded(rows, w_gate, w_up, w_down, ct,
                                    activation=activation, bm=bm, bf=bf,
                                    interpret=interpret)
        dwg, dwu, dwd = fused_mlp_wgrad_padded(rows, w_gate, w_up, w_down,
                                               ct, activation=activation,
                                               bm=bm, bf=bf,
                                               interpret=interpret)
        return dx, dwg, dwu, dwd

    f.defvjp(fwd, bwd)
    return f


def fused_mlp_padded(rows, w_gate, w_up, w_down, *, activation: str,
                     bm: int = 128, bf: int = 512, bn: int = 0,
                     order: str = "expert_major",
                     interpret: bool = False) -> jnp.ndarray:
    """Differentiable padded entry point (see module docstring)."""
    fn = _diff_fused(activation, bm, bf, bn, order, bool(interpret))
    return fn(rows, w_gate, w_up, w_down)
