"""Top-k combine epilogue as a Pallas-TPU kernel.

The paper's layer-1 consumer: after the N-major GroupGEMM produces expert
outputs, each token's k expert rows are weighted-summed in fp32. On TPU the
*gather* (slot → token) stays outside the kernel (dynamic HBM gathers belong
to XLA's gather engine, not VMEM tiles — hardware-adaptation note in
DESIGN.md); the kernel fuses the (T, k, d) weighted reduction, which is the
bandwidth-bound part that runs per column block in the overlap schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(rows_ref, w_ref, o_ref):
    rows = rows_ref[...].astype(jnp.float32)          # (bt, k, d)
    w = w_ref[...].astype(jnp.float32)                # (bt, k)
    o_ref[...] = jnp.einsum("tkd,tk->td", rows, w).astype(o_ref.dtype)


@functools.lru_cache(maxsize=None)
def _diff_combine(bt: int, interpret: bool):
    """custom_vjp closure (``pallas_call`` has no automatic VJP): forward =
    kernel, backward = the analytic fp32 gradients of the weighted sum —
    routing.combine differentiates through this inside the MoE layer."""

    @jax.custom_vjp
    def f(rows, weights):
        return topk_combine(rows, weights, bt=bt, interpret=interpret)

    def fwd(rows, weights):
        return f(rows, weights), (rows, weights)

    def bwd(res, ct):
        rows, weights = res
        g = ct.astype(jnp.float32)[:, None, :]                # (T, 1, d)
        d_rows = (weights.astype(jnp.float32)[..., None] * g
                  ).astype(rows.dtype)                        # (T, k, d)
        d_w = jnp.sum(rows.astype(jnp.float32) * g, axis=-1
                      ).astype(weights.dtype)                 # (T, k)
        return d_rows, d_w

    f.defvjp(fwd, bwd)
    return f


def topk_combine_diff(rows, weights, *, bt: int = 256,
                      interpret: bool = False):
    """Differentiable entry point for the combine kernel."""
    return _diff_combine(bt, bool(interpret))(rows, weights)


def topk_combine(rows: jnp.ndarray, weights: jnp.ndarray, *,
                 bt: int = 256, interpret: bool = False) -> jnp.ndarray:
    """rows: (T, k, d) expert outputs per (token, choice); weights: (T, k).
    Returns (T, d) fp32-accumulated weighted sum, cast to rows.dtype."""
    T, k, d = rows.shape
    bt = min(bt, T)
    pad = (bt - T % bt) % bt
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0), (0, 0)))
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _combine_kernel,
        grid=((T + pad) // bt,),
        in_specs=[
            pl.BlockSpec((bt, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T + pad, d), rows.dtype),
        interpret=interpret,
    )(rows, weights)
    return out[:T] if pad else out
