"""Assigned architectures (10) + the paper's evaluation models (3).

Every register() also registers a ``<name>-smoke`` reduced config of the same
family for CPU tests. Sources are noted per config; dims follow the assignment
sheet verbatim.
"""
from __future__ import annotations

from repro.configs.base import (AttnConfig, ModelConfig, MoEConfig, SSMConfig,
                                reduced, register)


def _reg(name, build):
    register(name)(build)
    register(name + "-smoke")(lambda: reduced(build()))


# --- granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base] --------
def granite():
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, d_ff=0, vocab_size=49155,
        attn=AttnConfig(n_heads=24, n_kv_heads=8, head_dim=64),
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
        activation="swiglu", tie_embeddings=True)


# --- granite-moe-bigmac [arXiv:2408.eprint BigMac-style descend-ascend] ------
# Same skeleton as granite-moe-3b-a800m but experts read/write a narrow
# wire_dim=384 (= d_model/4) bus: a shared descend projection before dispatch
# and ascend after combine, shrinking all-to-all traffic 4x.
def bigmac():
    return ModelConfig(
        name="granite-moe-bigmac", family="moe",
        n_layers=32, d_model=1536, d_ff=0, vocab_size=49155,
        attn=AttnConfig(n_heads=24, n_kv_heads=8, head_dim=64),
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512, wire_dim=384),
        activation="swiglu", tie_embeddings=True)


# --- qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B] ---------------------------
def qwen3moe():
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, d_ff=0, vocab_size=151936,
        attn=AttnConfig(n_heads=64, n_kv_heads=4, head_dim=128),
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536),
        activation="swiglu")


# --- llava-next-34b (Yi/Hermes backbone) [vlm; anyres frontend stubbed] ------
def llava():
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, d_ff=20480, vocab_size=64000,
        attn=AttnConfig(n_heads=56, n_kv_heads=8, head_dim=128),
        activation="swiglu", frontend="stub_patch")


# --- phi3-medium-14b [arXiv:2404.14219] --------------------------------------
def phi3():
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, d_ff=17920, vocab_size=100352,
        attn=AttnConfig(n_heads=40, n_kv_heads=10, head_dim=128),
        activation="swiglu")


# --- nemotron-4-340b [arXiv:2402.16819] — squared-ReLU, GQA ------------------
def nemotron():
    return ModelConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, d_ff=73728, vocab_size=256000,
        attn=AttnConfig(n_heads=96, n_kv_heads=8, head_dim=192),
        activation="relu2", norm="layernorm")


# --- qwen2-0.5b [arXiv:2407.10671] — QKV bias, tied embeddings ---------------
def qwen2_05b():
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, d_ff=4864, vocab_size=151936,
        attn=AttnConfig(n_heads=14, n_kv_heads=2, head_dim=64, qkv_bias=True),
        activation="swiglu", tie_embeddings=True)


# --- qwen1.5-4b [hf:Qwen/Qwen1.5-4B] — QKV bias, MHA (kv == heads) -----------
def qwen15_4b():
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, d_ff=6912, vocab_size=151936,
        attn=AttnConfig(n_heads=20, n_kv_heads=20, head_dim=128, qkv_bias=True),
        activation="swiglu")


# --- whisper-small [arXiv:2212.04356] — enc-dec, conv frontend stubbed -------
def whisper():
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, n_enc_layers=12, d_model=768, d_ff=3072, vocab_size=51865,
        attn=AttnConfig(n_heads=12, n_kv_heads=12, head_dim=64, rope_theta=0.0),
        activation="gelu", norm="layernorm", frontend="stub_audio")


# --- jamba-v0.1-52b [arXiv:2403.19887] — attn:mamba 1:7, MoE 16e top-2 -------
def jamba():
    # period 8: attention at offset 4 (attn_layer_period=8, offset=4);
    # MoE every 2nd layer at odd offsets (expert_layer_period=2, offset=1).
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=0.0),
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336,
                      every_k_layers=2, layer_offset=1),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=64, conv_width=4),
        layer_pattern="mmmmammm",
        activation="swiglu")


# --- mamba2-780m [arXiv:2405.21060] — SSD, attention-free --------------------
def mamba2():
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, d_ff=0, vocab_size=50280,
        attn=None,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4),
        layer_pattern="m", activation="swiglu")


# --- paper models (Table 2) --------------------------------------------------
def mixtral():
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, d_ff=0, vocab_size=32000,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128),
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336),
        activation="swiglu")


def qwen2moe():
    return ModelConfig(
        name="qwen2-moe-2.7b", family="moe",
        n_layers=24, d_model=2048, d_ff=0, vocab_size=151936,
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=4, d_expert=1408),
        activation="swiglu")


def phi35moe():
    return ModelConfig(
        name="phi3.5-moe", family="moe",
        n_layers=32, d_model=4096, d_ff=0, vocab_size=32064,
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128),
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
        activation="swiglu")


_reg("granite-moe-3b-a800m", granite)
_reg("granite-moe-bigmac", bigmac)
_reg("qwen3-moe-235b-a22b", qwen3moe)
_reg("llava-next-34b", llava)
_reg("phi3-medium-14b", phi3)
_reg("nemotron-4-340b", nemotron)
_reg("qwen2-0.5b", qwen2_05b)
_reg("qwen1.5-4b", qwen15_4b)
_reg("whisper-small", whisper)
_reg("jamba-v0.1-52b", jamba)
_reg("mamba2-780m", mamba2)
_reg("mixtral-8x7b", mixtral)
_reg("qwen2-moe-2.7b", qwen2moe)
_reg("phi3.5-moe", phi35moe)
