from repro.configs.base import (ASSIGNED_ARCHS, LM_SHAPES, PAPER_ARCHS,
                                SMOKE_SHAPE, AttnConfig, ModelConfig,
                                MoEConfig, ShapeConfig, SSMConfig, get_config,
                                list_archs, reduced, register,
                                shape_applicable)
from repro.configs import archs  # noqa: F401  — populates the registry
