"""Config system: dataclass model/run configs + input-shape sets + registry.

Every assigned architecture lives in its own module under ``repro.configs``
and registers a full-size config plus a reduced ``-smoke`` variant of the
same family. The full configs are only ever lowered (ShapeDtypeStruct), the
smoke configs actually run on CPU in tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # hidden size of each expert FFN
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True      # renormalize top-k probs (Mixtral-style)
    aux_loss_coef: float = 0.01
    every_k_layers: int = 1            # MoE block on layers where (i % k == offset)
    layer_offset: int = 0
    # Comet execution knobs (the paper's technique):
    impl: str = "comet"                # naive | coarse | comet | comet_hier
                                       # | dense
    ep: int = 0                        # expert-parallel group size; 0 = auto
    n_col_blocks: int = 0              # layer-1 N-decomposition; 0 = adaptive
    ring_group: int = 1                # source chunks fused per GroupGEMM step
    intra_group: int = 1               # comet_hier: devices per node — the
                                       # EP axis factors as inter-node ×
                                       # intra-node rings; 1 = flat
    wire_dtype: str = "fp32"           # comet_hier wire format for dispatch
                                       # payloads + combine partials (fp32 |
                                       # bf16 | fp8_e4m3); fp32 = native
                                       # width, no quantization
    fused_combine: bool = False        # comet: combine each column block as
                                       # it arrives (streaming layer-1
                                       # consumer) instead of after the
                                       # full-width concatenation
    gemm_impl: str = ""                # GroupGEMM backend (xla | pallas |
                                       # pallas_fused); "" = the static
                                       # "xla" default. Set by Plan.apply —
                                       # threaded explicitly, never via a
                                       # module global.
    coarse_chunks: int = 2             # FasterMoE-style pipeline degree
    # Adaptive transport autotuner (core/adaptive.py): path to a JSON plan
    # cache; "" disables lookup (the knobs above then apply verbatim). With a
    # cache configured, plan_override=True is the escape hatch pinning the
    # explicit knobs anyway.
    plan_cache: str = ""
    plan_override: bool = False
    plan_hw: str = ""                  # hardware key for plan lookup;
                                       # "" -> $REPRO_PLAN_HW or tpu_v5e
    plan_phase: str = "train"          # latency phase for plan lookup
                                       # (train | prefill | decode): serving
                                       # step builders set it so decode
                                       # resolves latency-ranked plans,
                                       # prefill chunk-throughput ones
    # BigMac-style descend-ascend experts (PAPERS.md): tokens are projected
    # d_model -> wire_dim by a shared descend matrix BEFORE dispatch and
    # back wire_dim -> d_model by a shared ascend matrix AFTER combine, so
    # both rings move wire_dim/d_model of the bytes. 0 = full-width experts.
    wire_dim: int = 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256
    dt_rank: int = 0                   # unused in SSD (per-head dt)


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    window: int = 0                    # 0 = full attention
    # pad q/kv heads up to model-axis divisibility so attention runs fully
    # head-sharded (TP) instead of sequence-sharded: dummy heads attend to
    # zero K/V and their outputs are dropped before the o-projection, so the
    # math is exact; costs extra SDPA FLOPs, removes the seq-TP dW
    # all-reduces (EXPERIMENTS.md §Perf cell 2).
    pad_heads: bool = False
    # long-seq handling: chunked online-softmax block size (pure-jnp flash)
    q_block: int = 512
    kv_block: int = 1024


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int                          # dense FFN hidden (0 for pure ssm / moe-only)
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    activation: str = "swiglu"         # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # hybrid interleave: string over {'a','m'} of length `period`; layer i uses
    # pattern[i % period]. Empty = homogeneous.
    layer_pattern: str = ""
    # encoder-decoder (whisper): n_enc_layers encoder layers (bidirectional)
    n_enc_layers: int = 0
    frontend: str = "none"             # none | stub_audio | stub_patch
    # dtype policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    # memory policy
    remat: str = "full"                # full | none
    scan_layers: bool = True
    # sequence-parallel residual stream (Megatron SP): activations between
    # blocks are sharded over the model axis along seq, so norms/adds run
    # 1/model_size of the replicated traffic. Gathers happen where a block
    # needs the full sequence.
    sp_residual: bool = False
    # block-schedule IR (core/schedule.py): "" keeps the scanned
    # layer-at-a-time forward; "sequential" runs the IR in program order
    # (differencing baseline); "overlap" lets the scheduler legally reorder
    # segment emission across block boundaries. Numerics are identical in
    # all three — the IR only permutes emission over the same dataflow.
    block_schedule: str = ""

    # -- derived helpers ----------------------------------------------------
    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.every_k_layers) == self.moe.layer_offset

    def layer_kind(self, i: int) -> str:
        if not self.layer_pattern:
            return "m" if self.family == "ssm" else "a"
        return self.layer_pattern[i % len(self.layer_pattern)]

    def param_count(self) -> int:
        """Total parameter count (approximate, matches init_params)."""
        d = self.d_model
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # lm head
        enc_layers = self.n_enc_layers
        for i in range(self.n_layers + enc_layers):
            is_enc = i >= self.n_layers
            kind = "a" if is_enc else self.layer_kind(i)
            if kind == "a" and self.attn is not None:
                a = self.attn
                q = d * a.n_heads * a.head_dim
                kv = 2 * d * a.n_kv_heads * a.head_dim
                o = a.n_heads * a.head_dim * d
                total += q + kv + o
                if a.qkv_bias:
                    total += (a.n_heads + 2 * a.n_kv_heads) * a.head_dim
                if not is_enc and self.n_enc_layers and i < self.n_layers:
                    total += q + kv + o                  # cross-attention
            elif kind == "m" and self.ssm is not None:
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.d_state + nh)  # in_proj(z,x)+B,C,dt
                total += s.conv_width * (d_in + 2 * s.d_state)
                total += nh + nh                          # A_log, D
                total += d_in * d                         # out_proj
            if (not is_enc) and self.is_moe_layer(i):
                m = self.moe
                total += d * m.num_experts                # router
                ne = m.num_experts + m.num_shared_experts
                total += ne * self.ffn_params(m.d_expert)
            elif self.d_ff > 0:
                total += self.ffn_params(self.d_ff)
            total += 2 * d                                # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        full_e = m.num_experts
        total = self.param_count()
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        per_expert = self.ffn_params(m.d_expert)
        total -= n_moe_layers * (full_e - m.top_k) * per_expert
        return total

    def ffn_params(self, hidden: int) -> int:
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * self.d_model * hidden


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode
    microbatch: int = 0                # 0 = no grad accumulation (train only)
    # paged KV cache (decode/serving shapes): page_size > 0 switches the
    # decode cache to the block-table layout — K/V pooled as n_pages shared
    # fixed-size pages (page 0 = null page) instead of one seq_len region
    # per slot, so seq_len becomes a per-request budget. n_pages includes
    # the null page; 0 = parity capacity (slots * seq_len/page_size + 1).
    page_size: int = 0
    n_pages: int = 0

    @property
    def paged(self) -> bool:
        return self.page_size > 0

    @property
    def max_blocks(self) -> int:
        assert self.page_size > 0 and self.seq_len % self.page_size == 0
        return self.seq_len // self.page_size

    def pages_total(self) -> int:
        return self.n_pages or self.global_batch * self.max_blocks + 1


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SMOKE_SHAPE = ShapeConfig("smoke", 64, 4, "train")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; else reason for the documented skip."""
    if shape.name == "long_500k":
        subquad = cfg.family in ("ssm", "hybrid")
        if not subquad:
            return False, ("pure full-attention arch: O(S) KV read per decoded "
                           "token at S=524288 exceeds the HBM envelope and the "
                           "assignment marks long_500k sub-quadratic-only")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs(include_smoke: bool = False) -> List[str]:
    import repro.configs  # noqa: F401
    names = sorted(_REGISTRY)
    if not include_smoke:
        names = [n for n in names if not n.endswith("-smoke")]
    return names


ASSIGNED_ARCHS = [
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "llava-next-34b",
    "phi3-medium-14b",
    "nemotron-4-340b",
    "qwen2-0.5b",
    "qwen1.5-4b",
    "whisper-small",
    "jamba-v0.1-52b",
    "mamba2-780m",
]

PAPER_ARCHS = ["mixtral-8x7b", "qwen2-moe-2.7b", "phi3.5-moe"]


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build a reduced same-family smoke config."""
    changes: Dict = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.layer_pattern))),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.attn is not None:
        changes["attn"] = dataclasses.replace(
            cfg.attn, n_heads=4,
            n_kv_heads=max(1, 4 * cfg.attn.n_kv_heads // cfg.attn.n_heads),
            head_dim=32, q_block=32, kv_block=32)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8), d_expert=64,
            ep=1, wire_dim=64 if cfg.moe.wire_dim else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk_size=16)
    if cfg.n_enc_layers:
        changes["n_enc_layers"] = 2
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
