"""Quickstart: the Comet MoE block as a composable JAX module.

Builds a small MoE FFN, runs the three transports (naive baseline, coarse
FasterMoE-style pipeline, comet fine-grained overlap) and shows they are
numerically identical — the schedule changes, the math doesn't. Then shows
the adaptive workload assignment picking the layer-1 column decomposition.

Run:  PYTHONPATH=src python examples/quickstart.py
(To see the multi-device collective schedule, run the same through
 `python -m repro.launch.selftest --devices 8`.)
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.adaptive import TPU_V5E, MoEShape, choose_n_col, layer_times
from repro.core.moe_layer import moe_ffn
from repro.parallel.mesh import AxisCtx


def main():
    cfg = get_config("granite-moe-3b-a800m-smoke")
    E, d, f = cfg.moe.num_experts, cfg.d_model, cfg.moe.d_expert
    print(f"model: {cfg.name}  E={E} top_k={cfg.moe.top_k} d={d} d_expert={f}")

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.1,
        "experts": {
            "w_gate": jax.random.normal(ks[1], (1, E, d, f)) * 0.05,
            "w_up": jax.random.normal(ks[2], (1, E, d, f)) * 0.05,
            "w_down": jax.random.normal(ks[3], (1, E, f, d)) * 0.05,
        },
    }
    x = jax.random.normal(ks[4], (4, 32, d), jnp.float32)

    outs = {}
    for impl in ("naive", "coarse", "comet"):
        mcfg = dataclasses.replace(cfg.moe, impl=impl)
        y, aux = jax.jit(lambda xx: moe_ffn(cfg, mcfg, params, xx, AxisCtx()))(x)
        outs[impl] = y
        print(f"impl={impl:7s} out={y.shape} aux_loss={float(aux):.5f}")

    err = float(jnp.max(jnp.abs(outs["comet"] - outs["naive"])))
    print(f"max |comet - naive| = {err:.2e}  (identical math, different schedule)")

    # adaptive workload assignment (paper §3.2.2, TPU knobs)
    print("\nadaptive layer-1 N-decomposition (paper Fig. 6/8):")
    for M in (1024, 4096, 16384, 65536):
        s = MoEShape(M=M, N=4096, K=14336, E=8, topk=2, ep=8, etp=1)
        n_col = choose_n_col(TPU_V5E, s)
        t = layer_times(TPU_V5E, s)
        print(f"  M={M:6d}  n_col={n_col}  per-chunk gemm={t['t_chunk_compute']*1e6:7.1f}us"
              f"  per-hop ici={t['t_hop']*1e6:7.1f}us"
              f"  balance={t['dispatch_balance']:.2f}")


if __name__ == "__main__":
    main()
