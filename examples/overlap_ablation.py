"""Overlap-mechanism ablation: sweep input length M and print each
mechanism's simulated single-layer latency + how much communication each
hides (the paper's Fig. 10/11, runnable at any shape).

Run:  PYTHONPATH=src python examples/overlap_ablation.py --hw h100_nvlink
      PYTHONPATH=src python examples/overlap_ablation.py --hw tpu_v5e --tpu
"""
import argparse

from repro.core.adaptive import HW, MoEShape
from repro.analysis.simulator import (MECHANISMS, sim_comet, sim_fastermoe,
                                      sim_megatron, sim_tutel)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="h100_nvlink", choices=sorted(HW))
    ap.add_argument("--tpu", action="store_true",
                    help="model comet without SM-donation derate (TPU DMA)")
    ap.add_argument("--N", type=int, default=4096)
    ap.add_argument("--K", type=int, default=14336)
    ap.add_argument("--E", type=int, default=8)
    ap.add_argument("--topk", type=int, default=2)
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--etp", type=int, default=1)
    args = ap.parse_args()
    hw = HW[args.hw]

    print(f"hw={hw.name}  experts {args.N}x{args.K}  E={args.E} "
          f"topk={args.topk}  EP{args.ep}xTP{args.etp}")
    print(f"{'M':>7s} {'megatron':>10s} {'fastermoe':>10s} {'tutel':>10s} "
          f"{'comet':>10s} {'speedup':>8s} {'hidden%':>8s} {'n_col':>6s}")
    for M in (1024, 2048, 4096, 8192, 16384, 32768, 65536):
        s = MoEShape(M=M, N=args.N, K=args.K, E=args.E, topk=args.topk,
                     ep=args.ep, etp=args.etp)
        t_m = sim_megatron(hw, s)["total"]
        t_f = (sim_fastermoe(hw, s)["total"] if args.etp == 1 else
               float("nan"))
        t_t = sim_tutel(hw, s)["total"]
        c = sim_comet(hw, s, tpu=args.tpu)
        hide = 100 * c["overlapped"] / max(c["comm"], 1e-12)
        best_base = min(x for x in (t_m, t_f, t_t) if x == x)
        print(f"{M:7d} {t_m*1e6:9.0f}u {t_f*1e6:9.0f}u {t_t*1e6:9.0f}u "
              f"{c['total']*1e6:9.0f}u {best_base/c['total']:7.2f}x "
              f"{hide:7.1f}% {c['n_col']:6d}")


if __name__ == "__main__":
    main()
